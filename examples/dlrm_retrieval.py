"""DLRM × IPGM: the paper's motivating deployment.

A DLRM-style two-tower produces item embeddings; the IPGM index serves
candidate retrieval while items churn (ads expire, new ads arrive) — the
exact online setting of the paper's §1. Brute-force scoring via the Pallas
``score_topk`` kernel provides the exactness reference.

    PYTHONPATH=src python examples/dlrm_retrieval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as reg
from repro.core import IndexParams, IPGMIndex, SearchParams
from repro.models import dlrm as dlrm_mod

rng = np.random.default_rng(0)
spec = reg.get_arch("dlrm-rm2")
cfg = spec.smoke_config()
params = dlrm_mod.init_params(jax.random.PRNGKey(0), cfg)
D = cfg.bot_mlp[-1]

# --- item corpus: embeddings from the DLRM bottom tower ---
n_items = 1500
item_dense = rng.normal(size=(n_items, cfg.n_dense)).astype(np.float32)
item_emb = np.asarray(dlrm_mod._mlp(params["bot"], jnp.asarray(item_dense),
                                    final_act=True))

index = IPGMIndex(
    IndexParams(capacity=2048, dim=D, d_out=12, metric="ip",
                search=SearchParams(pool_size=32, max_steps=96, num_starts=2)),
    strategy="global",
)
ids = index.insert(item_emb)

# --- user queries via the same tower ---
user_dense = rng.normal(size=(32, cfg.n_dense)).astype(np.float32)
user_emb = np.asarray(dlrm_mod._mlp(params["bot"], jnp.asarray(user_dense),
                                    final_act=True))

# graph-based retrieval vs brute-force (Pallas kernel) ground truth
graph_ids, _ = index.query(user_emb, k=10)
bf_scores, bf_ids = dlrm_mod.retrieval_scores(
    jnp.asarray(user_emb), jnp.asarray(item_emb), 10)
overlap = np.mean([
    len(set(np.asarray(graph_ids)[i]) & set(np.asarray(bf_ids)[i])) / 10
    for i in range(32)
])
print(f"graph-vs-bruteforce top-10 overlap: {overlap:.3f}")

# --- ad churn: expire 300 items, insert 300 fresh ones ---
index.delete(np.asarray(ids)[:300])
fresh_dense = rng.normal(size=(300, cfg.n_dense)).astype(np.float32)
fresh_emb = np.asarray(dlrm_mod._mlp(params["bot"], jnp.asarray(fresh_dense),
                                     final_act=True))
index.insert(fresh_emb)
print(f"recall@10 after ad churn: {index.recall(user_emb, k=10):.3f}")
print(index.stats())
