"""End-to-end driver (the paper's kind: online ANN serving).

Runs the full GRAPH-MAINTENANCE workload — batched deletes, inserts and
queries streaming against a live index — with per-phase latency accounting,
comparing the GLOBAL strategy against MASK on the same stream.

    PYTHONPATH=src python examples/online_ann_serving.py [--scale 2000]
"""
import argparse

from repro.launch.serve import serve_online

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1500)
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()

    for strategy in ("global", "mask"):
        print(f"\n=== strategy: {strategy} ===")
        serve_online(
            dataset="sift",
            strategy=strategy,
            n_base=args.scale,
            n_steps=args.steps,
            batch_size=max(args.scale // 10, 10),
            n_queries=min(256, args.scale),
        )
