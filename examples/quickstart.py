"""Quickstart: stream queries, inserts and GLOBAL-reconnect deletes through
one device-resident session, and watch recall survive the churn.

The session API (DESIGN.md §7) dispatches every op asynchronously through a
single jitted, state-donating step — ops return handles, the host syncs on
``flush()`` / ``handle.result()``.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import IndexParams, MaintenanceParams, SearchParams, Session

rng = np.random.default_rng(0)

# 1. a session starting at a 2k-slot capacity tier; max_capacity arms the
#    growth engine (DESIGN.md §9) so net-positive insert traffic grows the
#    index through geometric tiers instead of refusing once the tier fills
params = IndexParams(
    capacity=2048, dim=64, d_out=12,
    search=SearchParams(pool_size=32, max_steps=96, num_starts=2),
    maintenance=MaintenanceParams(strategy="global",  # paper's recommendation
                                  max_capacity=65536),
)
session = Session(params)

# 2. insert a base set — `insert` returns a handle immediately; `.result()`
#    blocks and hands back the assigned ids
X = rng.normal(size=(1000, 64)).astype(np.float32)
ids = session.insert(X).result()
print("inserted:", session.stats())

# 3. query — same deal: dispatch now, consume whenever
Q = rng.normal(size=(64, 64)).astype(np.float32)
found_ids, scores = session.query(Q, k=10).result()
print(f"recall@10 before churn: {session.recall(Q, k=10):.3f}")

# 4. online churn: delete 200 + insert 200 fresh, dispatched back-to-back
#    with ONE synchronization point — GLOBAL reconnect repairs the
#    in-neighbors of every deleted vertex by re-searching the graph
session.delete(ids[:200])
session.insert(rng.normal(size=(200, 64)).astype(np.float32))
session.flush()
print(f"recall@10 after churn:  {session.recall(Q, k=10):.3f}")

# 5. net growth: push past the 2048-slot tier — the session grows to the
#    next tier at the insert boundary (one recompile), nothing refuses
session.insert(rng.normal(size=(1500, 64)).astype(np.float32))
st = session.stats()
print(f"after net growth: capacity={st['capacity']} "
      f"n_grows={st['n_grows']} n_refused={st['n_refused']}")
print("timers:", session.timers.to_dict())

# 6. the per-op facade (`IPGMIndex`) keeps the seed API working and is
#    parity-tested bit-exact against the session — see tests/test_session.py
