"""Quickstart: stream queries, inserts and deletes through a TWO-TIER
online index — a small exact fresh tier absorbing writes in front of a
large device-resident main tier, with a streaming merge draining fresh
items into main in bounded chunks behind the stream (DESIGN.md §12).

Every op dispatches asynchronously and returns a handle; the host syncs on
``flush()`` / ``handle.result()``. Queries fan out to both tiers — the
device beam engine serves main, an exact host scan serves fresh — and the
fan-in unions the two top-k lists by external id.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (IndexParams, MaintenanceParams, SearchParams,
                        TieredSession)

rng = np.random.default_rng(0)

# 1. the main tier starts at a 2k-slot capacity tier; max_capacity arms the
#    growth engine (DESIGN.md §9) so merge drains grow it through geometric
#    tiers instead of refusing. The merge_* thresholds arm the streaming-
#    merge auto-trigger: once the fresh tier is half full (or main is 25%
#    tombstones) the next mutation starts a merge that advances one bounded
#    chunk per insert/delete — queries never wait on merge work.
params = IndexParams(
    capacity=2048, dim=64, d_out=12,
    search=SearchParams(pool_size=32, max_steps=96, num_starts=2),
    maintenance=MaintenanceParams(strategy="mask",  # main-tier tombstones
                                  merge_fresh_threshold=0.5,
                                  merge_tombstone_threshold=0.25,
                                  max_capacity=65536),
)
session = TieredSession(params, fresh_capacity=256)

# 2. insert a base set in fresh-tier-sized waves — each wave lands in the
#    fresh tier; auto-triggered merges drain earlier waves into main while
#    later waves stream in (a wave outrunning the merge simply finishes the
#    drain synchronously — deterministic backpressure, nothing refuses)
X = rng.normal(size=(1000, 64)).astype(np.float32)
ids = np.concatenate([
    session.insert(X[lo:lo + 256]).result() for lo in range(0, 1000, 256)])
print("inserted:", session.stats())

# 3. query — one fan-out over both tiers, deduplicated by external id
Q = rng.normal(size=(64, 64)).astype(np.float32)
found_ids, scores = session.query(Q, k=10).result()
print(f"recall@10 before churn: {session.recall(Q, k=10):.3f}")

# 4. online churn: deletes route by residency — fresh-resident ids
#    hard-delete from the small tier, main-resident ids become tombstones
#    in main's mask bitmap and are reclaimed by the next merge's
#    compaction phase
session.delete(ids[:200])
session.insert(rng.normal(size=(200, 64)).astype(np.float32))
session.flush()
print(f"recall@10 after churn:  {session.recall(Q, k=10):.3f}")

# 5. net growth: keep streaming past the main tier's 2048 slots — merge
#    drains grow the main tier at the chunk boundary (one recompile per
#    tier), the fresh tier never grows (merge catch-up is its backpressure)
for lo in range(0, 1500, 250):
    session.insert(rng.normal(size=(250, 64)).astype(np.float32))
session.flush()
st = session.stats()
print(f"after net growth: n_alive={st['n_alive']} "
      f"main_capacity={st['main_capacity']} n_merges={st['n_merges']} "
      f"n_refused={st['n_refused']}")
print("timers:", session.timers.to_dict())
