"""Quickstart: build an online ANN index, query it, delete with GLOBAL
reconnect, and watch recall survive the churn.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import IndexParams, IPGMIndex, SearchParams

rng = np.random.default_rng(0)

# 1. an index with capacity for 2k vectors of dim 64
params = IndexParams(
    capacity=2048, dim=64, d_out=12,
    search=SearchParams(pool_size=32, max_steps=96, num_starts=2),
)
index = IPGMIndex(params, strategy="global")  # the paper's recommended repair

# 2. insert a base set
X = rng.normal(size=(1000, 64)).astype(np.float32)
ids = index.insert(X)
print("inserted:", index.stats())

# 3. query
Q = rng.normal(size=(64, 64)).astype(np.float32)
found_ids, scores = index.query(Q, k=10)
print(f"recall@10 before churn: {index.recall(Q, k=10):.3f}")

# 4. online churn: delete 200, insert 200 fresh — GLOBAL reconnect repairs
#    the in-neighbors of every deleted vertex by re-searching the graph
index.delete(np.asarray(ids)[:200])
index.insert(rng.normal(size=(200, 64)).astype(np.float32))
print(f"recall@10 after churn:  {index.recall(Q, k=10):.3f}")
print("timers:", index.timers)
