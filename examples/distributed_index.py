"""Sharded online index on 8 simulated devices — the production layout.

Shard-per-device subgraphs, routed inserts, fan-out queries with
hierarchical top-k merge, GLOBAL delete repair running shard-locally.
Must set the device count before jax initializes.

    PYTHONPATH=src python examples/distributed_index.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.params import IndexParams, SearchParams  # noqa: E402
from repro.distributed.ann import (  # noqa: E402
    DistParams,
    init_sharded_state,
    make_delete_step,
    make_insert_step,
    make_query_step,
)

mesh = jax.make_mesh((4, 2), ("data", "model"))
dp = DistParams(index=IndexParams(
    capacity=128, dim=32, d_out=8,
    search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
))
rng = np.random.default_rng(0)

with jax.set_mesh(mesh):
    state = init_sharded_state(dp, mesh)
    X = rng.normal(size=(400, 32)).astype(np.float32)
    state, gids = make_insert_step(dp, mesh)(
        state, jnp.asarray(X), jnp.arange(400, dtype=jnp.int32),
        jax.random.PRNGKey(0),
    )
    print("inserted:", int((np.asarray(gids) >= 0).sum()), "across",
          mesh.devices.size, "shards")

    Q = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    ids, scores = make_query_step(dp, mesh)(state, Q, jax.random.PRNGKey(1))
    print("query results (global ids):", np.asarray(ids)[0, :5])

    state = make_delete_step(dp, mesh, "global")(
        state, jnp.asarray(np.asarray(gids)[:100]), jax.random.PRNGKey(2),
    )
    print("alive after GLOBAL delete of 100:",
          int(np.asarray(jax.device_get(state.alive)).sum()))
