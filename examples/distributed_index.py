"""Sharded online index on 8 simulated devices — the production layout.

Shard-per-device subgraphs, routed inserts, fan-out queries with
hierarchical top-k merge, GLOBAL delete repair running shard-locally.
Must set the device count before jax initializes.

    PYTHONPATH=src python examples/distributed_index.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.core.params import IndexParams, SearchParams  # noqa: E402
from repro.distributed.ann import DistParams, ShardedSession  # noqa: E402

mesh = jax.make_mesh((4, 2), ("data", "model"))
dp = DistParams(index=IndexParams(
    capacity=128, dim=32, d_out=8,
    search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
))
rng = np.random.default_rng(0)

with compat.use_mesh(mesh):
    # the sharded session owns the stacked per-shard state (donated through
    # every update step) and dispatches ops async — flush() to synchronize
    sess = ShardedSession(dp, mesh, strategy="global", seed=0)
    X = rng.normal(size=(400, 32)).astype(np.float32)
    gids = sess.insert(X, np.arange(400))
    print("inserted:", int((np.asarray(gids) >= 0).sum()), "across",
          mesh.devices.size, "shards")

    Q = rng.normal(size=(16, 32)).astype(np.float32)
    ids, scores = sess.query(Q)
    print("query results (global ids):", np.asarray(ids)[0, :5])

    sess.delete(np.asarray(gids)[:100])
    sess.flush()
    print("alive after GLOBAL delete of 100:", sess.n_alive())
    print("timers:", sess.timers.to_dict())
