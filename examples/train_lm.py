"""Train a registry LM end-to-end with checkpoint/restart fault tolerance.

Smoke config trains in ~a minute on CPU; pass --full for the real
qwen3-1.7b config (needs accelerators).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import tempfile

from repro.launch.train import train_lm

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        print("=== phase 1: train with a simulated preemption ===")
        train_lm(args.arch, smoke=not args.full, steps=args.steps,
                 ckpt_dir=ckpt, ckpt_every=20, preempt_at=args.steps // 2)
        print("=== phase 2: resume from the checkpoint ===")
        out = train_lm(args.arch, smoke=not args.full, steps=args.steps,
                       ckpt_dir=ckpt, resume=True)
        print(f"final loss: {out['final_loss']:.4f}")
