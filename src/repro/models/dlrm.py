"""DLRM RM2 (Naumov et al. 2019) — embedding bags + dot interaction + MLPs.

JAX has no native EmbeddingBag: lookups are ``jnp.take`` over stacked tables
+ masked mean pooling (multi-hot), which IS the system's embedding layer
(kernel_taxonomy §RecSys). Tables are stacked ``[n_sparse, rows, dim]`` so
table-wise model parallelism is a single sharding annotation on axis 0.

``retrieval_cand`` (1 query × 10⁶ candidates) routes through the Pallas
``score_topk`` kernel — the same brute-force scorer the ANN index uses,
which is exactly the paper's serving integration (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    n_rows: int = 1_000_000        # rows per table
    nnz: int = 1                   # multi-hot ids per field (padded)
    bot_mlp: tuple[int, ...] = (512, 256, 64)
    top_mlp: tuple[int, ...] = (512, 512, 256, 1)

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def _mlp_init(key, d_in, widths):
    layers = []
    for w in widths:
        k, key = jax.random.split(key)
        layers.append(dense_init(k, d_in, w))
        d_in = w
    return layers


def _mlp(layers, x, *, final_act=False):
    for i, lp in enumerate(layers):
        x = x @ lp["w"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init_params(key, cfg: DLRMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    top_in = cfg.n_interact + cfg.bot_mlp[-1]
    return {
        "tables": jax.random.normal(
            k1, (cfg.n_sparse, cfg.n_rows, cfg.embed_dim), jnp.float32
        ) * (1.0 / cfg.embed_dim**0.5),
        "bot": _mlp_init(k2, cfg.n_dense, cfg.bot_mlp),
        "top": _mlp_init(k3, top_in, cfg.top_mlp),
    }


def embedding_bag(
    tables: jax.Array,   # [F, R, D]
    ids: jax.Array,      # i32[B, F, nnz]
    mask: jax.Array,     # bool[B, F, nnz]
) -> jax.Array:
    """Mean-pooled multi-hot lookup → [B, F, D] (manual EmbeddingBag)."""
    F = tables.shape[0]
    f_idx = jnp.arange(F)[None, :, None]                     # [1, F, 1]
    rows = tables[f_idx, ids]                                # [B, F, nnz, D]
    rows = jnp.where(mask[..., None], rows, 0.0)
    cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1)
    return jnp.sum(rows, axis=2) / cnt


def forward(params, batch: dict, cfg: DLRMConfig) -> jax.Array:
    """batch = {dense f32[B,13], sparse_ids i32[B,F,nnz], sparse_mask bool}
    → logits f32[B]."""
    dense_feat = batch["dense"]
    emb = embedding_bag(params["tables"], batch["sparse_ids"],
                        batch["sparse_mask"])                # [B, F, D]
    bot = _mlp(params["bot"], dense_feat, final_act=True)    # [B, D]
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)      # [B, F+1, D]
    # dot-product feature interaction (lower triangle, no diagonal)
    zz = jnp.einsum("bfd,bgd->bfg", z, z)                    # [B, F+1, F+1]
    f = z.shape[1]
    iu, ju = jnp.tril_indices(f, k=-1)
    inter = zz[:, iu, ju]                                    # [B, f(f-1)/2]
    top_in = jnp.concatenate([inter, bot], axis=1)
    return _mlp(params["top"], top_in)[:, 0]


def bce_loss(params, batch: dict, cfg: DLRMConfig) -> jax.Array:
    logits = forward(params, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_scores(
    query_emb: jax.Array,       # f32[B, D] user/query tower output
    candidates: jax.Array,      # f32[M, D] item embeddings
    k: int,
    *,
    use_pallas: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Top-k candidate scoring — the ANN-serving hot path (ties into IPGM)."""
    csq = jnp.sum(candidates.astype(jnp.float32) ** 2, axis=-1)
    if use_pallas:
        from repro.kernels import ops
        return ops.score_topk(candidates, csq, query_emb, k, metric="ip")
    from repro.kernels.ref import ref_score_topk
    return ref_score_topk(candidates, csq, query_emb, k, "ip")
