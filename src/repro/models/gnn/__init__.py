from repro.models.gnn.common import GraphData, segment_mean, segment_softmax

__all__ = ["GraphData", "segment_mean", "segment_softmax"]
