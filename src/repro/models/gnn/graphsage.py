"""GraphSAGE (Hamilton et al. 2017) — mean aggregator, full-graph + sampled.

Full-graph: h'_i = act(W_self·h_i + W_nbr·mean_{j∈N(i)} h_j).
Minibatch: layered fanout blocks from the neighbor sampler
(data/graph_sampler.py) — hop-h features aggregated with a masked fixed-
fanout mean (the padded-dense regime: [B, fanout, F] tensors, MXU-friendly).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphData, segment_mean
from repro.models.layers import dense, dense_init


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    sample_sizes: tuple[int, ...] = (25, 10)


def init_params(key, cfg: SAGEConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    layers = []
    for i in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        layers.append({
            "w_self": dense_init(k1, dims[i], dims[i + 1]),
            "w_nbr": dense_init(k2, dims[i], dims[i + 1]),
        })
    return {"layers": layers}


def forward(params, g: GraphData, cfg: SAGEConfig) -> jax.Array:
    """Full-graph forward → logits [N, n_classes]."""
    h = g.x
    for i, lp in enumerate(params["layers"]):
        msgs = h[g.senders]
        agg = segment_mean(msgs, g.receivers, g.edge_mask, g.n_nodes)
        h = dense(lp["w_self"], h) + dense(lp["w_nbr"], agg)
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
        h = jnp.where(g.node_mask[:, None], h, 0.0)
    return h


def forward_sampled(params, blocks: dict, cfg: SAGEConfig) -> jax.Array:
    """Sampled minibatch forward.

    blocks = {
      "feats":  [f32[B·Π(f_1..f_h), d_in] for h = n_layers .. 0]   hop feats
      "masks":  [bool[...] matching]                                validity
    }
    hop ordering: feats[0] = deepest hop (B·f1·f2 nodes), feats[-1] = targets.
    Aggregation folds the innermost fanout axis per layer.
    """
    feats = blocks["feats"]
    masks = blocks["masks"]
    fans = list(cfg.sample_sizes)
    hs = [f for f in feats]  # hs[0] = deepest hop, hs[-1] = target nodes
    for li, lp in enumerate(params["layers"]):
        new_hs, new_masks = [], []
        D = len(hs) - 1
        for depth in range(len(hs) - 1):
            # transition hop (D-depth) → (D-depth-1) uses fanout[D-depth-1]
            fan = fans[D - depth - 1]
            tgt, nbr = hs[depth + 1], hs[depth]
            m = masks[depth].reshape(tgt.shape[0], fan)
            nbrs = nbr.reshape(tgt.shape[0], fan, -1)
            cnt = jnp.maximum(jnp.sum(m, axis=1, keepdims=True), 1.0)
            agg = jnp.sum(jnp.where(m[..., None], nbrs, 0.0), axis=1) / cnt
            h = dense(lp["w_self"], tgt) + dense(lp["w_nbr"], agg)
            if li < cfg.n_layers - 1:
                h = jax.nn.relu(h)
            new_hs.append(h)
            new_masks.append(masks[depth + 1])
        hs, masks = new_hs, new_masks
    return hs[0]  # [B, n_classes]
