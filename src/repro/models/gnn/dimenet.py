"""DimeNet (Gasteiger et al. 2020) — directional message passing.

The triplet/quadruplet-gather kernel regime (kernel_taxonomy §GNN): messages
live on *edges*; each interaction block aggregates over triplets (k→j→i)
with a radial-Bessel × angular basis and a bilinear contraction, then
scatter-sums back to edges.

Triplet lists are built by the data pipeline with a ``max_triplets`` cap
(Σ deg² explodes on power-law graphs — DESIGN.md §Arch-applicability);
angles are computed in-model from node positions. Non-molecular shapes get
surrogate 3D positions from the pipeline.

Faithful simplifications (documented): radial basis = spherical Bessel
sin(nπd/c)/d as in the paper; angular basis = Chebyshev cos(lθ) instead of
full spherical harmonics (same triplet compute pattern / FLOP structure).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphData, scatter_sum
from repro.models.layers import dense, dense_init


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_in: int = 16          # node (atom-type) feature dim
    cutoff: float = 5.0
    n_targets: int = 1


def init_params(key, cfg: DimeNetConfig):
    ks = jax.random.split(key, 6 + cfg.n_blocks)
    d, nr, ns, nb = cfg.d_hidden, cfg.n_radial, cfg.n_spherical, cfg.n_bilinear
    blocks = []
    for i in range(cfg.n_blocks):
        bk = jax.random.split(ks[6 + i], 6)
        blocks.append({
            "w_msg": dense_init(bk[0], d, d),
            "w_rbf": dense_init(bk[1], nr, d),
            "w_sbf": dense_init(bk[2], ns * nr, nb),
            "bilinear": jax.random.normal(bk[3], (nb, d, d)) * (1.0 / d),
            "w_out1": dense_init(bk[4], d, d),
            "w_out2": dense_init(bk[5], d, d),
        })
    return {
        "embed_node": dense_init(ks[0], cfg.d_in, d),
        "embed_edge": dense_init(ks[1], 2 * d + nr, d),
        "out_rbf": dense_init(ks[2], nr, d),
        "out1": dense_init(ks[3], d, d),
        "out2": dense_init(ks[4], d, cfg.n_targets),
    }, {"blocks": blocks}


def _bessel_rbf(dist, n_radial, cutoff):
    """sin(nπ d/c) / d — the paper's radial basis."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(dist, 1e-6)[:, None]
    env = (2.0 / cutoff) ** 0.5
    return env * jnp.sin(n * jnp.pi * d / cutoff) / d


def _angular_basis(cos_angle, n_spherical):
    """Chebyshev cos(lθ) basis via recurrence (surrogate for SH)."""
    t0 = jnp.ones_like(cos_angle)
    t1 = cos_angle
    out = [t0, t1]
    for _ in range(n_spherical - 2):
        out.append(2.0 * cos_angle * out[-1] - out[-2])
    return jnp.stack(out[:n_spherical], axis=-1)             # [T, ns]


def forward(
    params_pair,
    g: GraphData,
    triplets: dict,       # {"edge_kj": i32[T], "edge_ji": i32[T], "mask": bool[T]}
    cfg: DimeNetConfig,
) -> jax.Array:
    """→ per-graph targets f32[G] (energy-style regression)."""
    params, blocks = params_pair
    N, E = g.n_nodes, g.n_edges
    pos = g.positions
    vec = pos[g.senders] - pos[g.receivers]                  # edge j→i vector
    dist = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, -1), 1e-12))
    rbf = _bessel_rbf(dist, cfg.n_radial, cfg.cutoff)        # [E, nr]
    rbf = jnp.where(g.edge_mask[:, None], rbf, 0.0)

    # ---- triplet geometry: angle at j between (k→j) and (j→i) ----
    e_kj, e_ji, t_mask = triplets["edge_kj"], triplets["edge_ji"], triplets["mask"]
    v_kj = -vec[e_kj]                                        # k→j direction
    v_ji = vec[e_ji]
    num = jnp.sum(v_kj * v_ji, axis=-1)
    den = jnp.maximum(
        jnp.linalg.norm(v_kj, axis=-1) * jnp.linalg.norm(v_ji, axis=-1), 1e-9
    )
    cos_a = jnp.clip(num / den, -1.0, 1.0)
    sbf = _angular_basis(cos_a, cfg.n_spherical)             # [T, ns]
    sbf = sbf[:, :, None] * rbf[e_kj][:, None, :]            # [T, ns, nr]
    sbf = sbf.reshape(sbf.shape[0], -1)
    sbf = jnp.where(t_mask[:, None], sbf, 0.0)

    # ---- embedding block ----
    hx = jax.nn.silu(dense(params["embed_node"], g.x))       # [N, d]
    m = jax.nn.silu(dense(
        params["embed_edge"],
        jnp.concatenate([hx[g.senders], hx[g.receivers], rbf], axis=-1),
    ))                                                        # [E, d]

    node_out = scatter_sum(
        jnp.where(g.edge_mask[:, None],
                  m * dense(params["out_rbf"], rbf), 0.0),
        g.receivers, N,
    )

    # ---- interaction blocks: directional triplet aggregation ----
    for bp in blocks["blocks"]:
        m_kj = jax.nn.silu(dense(bp["w_msg"], m))[e_kj]      # [T, d]
        a = dense(bp["w_sbf"], sbf)                          # [T, nb]
        # bilinear: t_bd = Σ_b a[t,b] · (m_kj W_b)  (paper eq. 9)
        inter = jnp.einsum("tb,bde,td->te", a, bp["bilinear"], m_kj)
        inter = jnp.where(t_mask[:, None], inter, 0.0)
        agg = scatter_sum(inter, e_ji, E)                    # [E, d]
        m = m + jax.nn.silu(
            dense(bp["w_out1"], m * dense(bp["w_rbf"], rbf) + agg)
        )
        node_out = node_out + scatter_sum(
            jnp.where(g.edge_mask[:, None],
                      jax.nn.silu(dense(bp["w_out2"], m)), 0.0),
            g.receivers, N,
        )

    # ---- readout: per-graph sum ----
    h = jax.nn.silu(dense(params["out1"], node_out))
    per_node = dense(params["out2"], h)[:, 0]                # [N]
    per_node = jnp.where(g.node_mask, per_node, 0.0)
    n_graphs = g.targets.shape[0]
    return jax.ops.segment_sum(per_node, g.graph_ids, num_segments=n_graphs)


def build_triplets(senders, receivers, n_edges: int, max_triplets: int):
    """Host-side triplet builder: for each edge (j→i), pair with incoming
    edges (k→j), k ≠ i. Returns padded index arrays (numpy)."""
    import numpy as np

    senders = np.asarray(senders)
    receivers = np.asarray(receivers)
    in_edges: dict[int, list[int]] = {}
    for eid in range(len(senders)):
        in_edges.setdefault(int(receivers[eid]), []).append(eid)
    e_kj, e_ji = [], []
    for eid in range(len(senders)):
        j, i = int(senders[eid]), int(receivers[eid])
        for kj in in_edges.get(j, ()):
            if int(senders[kj]) != i:
                e_kj.append(kj)
                e_ji.append(eid)
                if len(e_kj) >= max_triplets:
                    break
        if len(e_kj) >= max_triplets:
            break
    T = len(e_kj)
    pad = max_triplets - T
    return {
        "edge_kj": np.asarray(e_kj + [0] * pad, np.int32),
        "edge_ji": np.asarray(e_ji + [0] * pad, np.int32),
        "mask": np.asarray([True] * T + [False] * pad, bool),
    }
