"""GatedGCN (Bresson & Laurent 2018; Dwivedi et al. benchmark config).

16 layers, d=70, gated edge aggregation with residuals. The benchmark's
BatchNorm is replaced by LayerNorm (masked-static-shape friendly; noted in
DESIGN.md §Arch-applicability).

  e'_ij = e_ij + ReLU(LN(A h_i + B h_j + C e_ij))
  h'_i  = h_i + ReLU(LN(U h_i + Σ_j σ(e'_ij) ⊙ (V h_j) / (Σ_j σ(e'_ij)+ε)))
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphData, scatter_sum
from repro.models.layers import dense, dense_init, layernorm, layernorm_init


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_in: int = 64
    d_edge_in: int = 8
    d_hidden: int = 70
    n_classes: int = 10


def init_params(key, cfg: GatedGCNConfig):
    k_in, k_e, key = jax.random.split(key, 3)
    d = cfg.d_hidden
    layers = []
    for _ in range(cfg.n_layers):
        ks = jax.random.split(key, 6)
        key = ks[5]
        layers.append({
            "A": dense_init(ks[0], d, d), "B": dense_init(ks[1], d, d),
            "C": dense_init(ks[2], d, d), "U": dense_init(ks[3], d, d),
            "V": dense_init(ks[4], d, d),
            "ln_h": layernorm_init(d), "ln_e": layernorm_init(d),
        })
    k_out, _ = jax.random.split(key)
    return {
        "embed_h": dense_init(k_in, cfg.d_in, d),
        "embed_e": dense_init(k_e, cfg.d_edge_in, d),
        "out": dense_init(k_out, d, cfg.n_classes),
        "layers": layers,
    }


def forward(params, g: GraphData, cfg: GatedGCNConfig) -> jax.Array:
    N = g.n_nodes
    h = dense(params["embed_h"], g.x)
    e = dense(params["embed_e"], g.edge_attr)
    for lp in params["layers"]:
        hi, hj = h[g.senders], h[g.receivers]
        e_new = dense(lp["A"], hi) + dense(lp["B"], hj) + dense(lp["C"], e)
        e = e + jax.nn.relu(layernorm(lp["ln_e"], e_new))
        gate = jax.nn.sigmoid(e)
        gate = jnp.where(g.edge_mask[:, None], gate, 0.0)
        num = scatter_sum(gate * dense(lp["V"], hi), g.receivers, N)
        den = scatter_sum(gate, g.receivers, N)
        agg = num / (den + 1e-6)
        h = h + jax.nn.relu(layernorm(lp["ln_h"], dense(lp["U"], h) + agg))
        h = jnp.where(g.node_mask[:, None], h, 0.0)
    return dense(params["out"], h)
