"""GAT (Veličković et al. 2018) — SDDMM edge scores → segment softmax → SpMM.

Cora config: 2 layers, 8 heads × d=8 hidden (ELU), single-head output layer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphData, scatter_sum, segment_softmax
from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    negative_slope: float = 0.2


def init_params(key, cfg: GATConfig):
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        H = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append({
            "w": dense_init(k1, d_in, H * d_out)["w"].reshape(d_in, H, d_out),
            "a_src": jax.random.normal(k2, (H, d_out)) * 0.1,
            "a_dst": jax.random.normal(k3, (H, d_out)) * 0.1,
        })
        d_in = d_out if last else H * d_out
    return {"layers": layers}


def forward(params, g: GraphData, cfg: GATConfig) -> jax.Array:
    h = g.x
    N = g.n_nodes
    for i, lp in enumerate(params["layers"]):
        last = i == cfg.n_layers - 1
        hp = jnp.einsum("nf,fhd->nhd", h, lp["w"])            # [N, H, d]
        # SDDMM-style edge scores from source/dest attention vectors
        s_src = jnp.sum(hp * lp["a_src"][None], axis=-1)      # [N, H]
        s_dst = jnp.sum(hp * lp["a_dst"][None], axis=-1)
        e = s_src[g.senders] + s_dst[g.receivers]             # [E, H]
        e = jax.nn.leaky_relu(e, cfg.negative_slope)
        alpha = segment_softmax(e, g.receivers, g.edge_mask, N)  # [E, H]
        msgs = hp[g.senders] * alpha[..., None]               # [E, H, d]
        agg = scatter_sum(
            jnp.where(g.edge_mask[:, None, None], msgs, 0.0), g.receivers, N
        )                                                      # [N, H, d]
        if last:
            h = jnp.mean(agg, axis=1)                          # head average
        else:
            h = jax.nn.elu(agg).reshape(N, -1)                 # head concat
        h = jnp.where(g.node_mask[:, None], h, 0.0)
    return h
