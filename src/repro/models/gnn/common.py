"""GNN substrate: static-shape graph batches + segment message passing.

JAX sparse is BCOO-only, so message passing is implemented directly over an
edge list with ``jax.ops.segment_sum`` / ``segment_max`` — this IS the
system's SpMM/SDDMM layer (kernel_taxonomy §GNN). All shapes are static:
graphs are padded to (n_nodes, n_edges[, n_triplets]) with validity masks;
padded edges point at node 0 with mask=False and contribute zeros.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "x", "senders", "receivers", "node_mask", "edge_mask", "labels",
        "label_mask", "positions", "edge_attr", "graph_ids", "targets",
    ],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class GraphData:
    """One (possibly merged/padded) graph batch."""

    x: jax.Array            # f32[N, F] node features
    senders: jax.Array      # i32[E]
    receivers: jax.Array    # i32[E]
    node_mask: jax.Array    # bool[N]
    edge_mask: jax.Array    # bool[E]
    labels: jax.Array       # i32[N] node labels (classification) or zeros
    label_mask: jax.Array   # bool[N] which nodes are supervised
    positions: jax.Array    # f32[N, 3] (geometric models; zeros otherwise)
    edge_attr: jax.Array    # f32[E, De] (gatedgcn; zeros otherwise)
    graph_ids: jax.Array    # i32[N] graph membership (batched small graphs)
    targets: jax.Array      # f32[G] graph-level regression targets

    @property
    def n_nodes(self) -> int:
        return self.x.shape[0]

    @property
    def n_edges(self) -> int:
        return self.senders.shape[0]


def make_graph(
    x, senders, receivers, *, labels=None, label_mask=None, node_mask=None,
    edge_mask=None, positions=None, edge_attr=None, d_edge=8, graph_ids=None,
    targets=None, n_graphs=1,
) -> GraphData:
    N = x.shape[0]
    E = senders.shape[0]
    return GraphData(
        x=jnp.asarray(x, jnp.float32),
        senders=jnp.asarray(senders, jnp.int32),
        receivers=jnp.asarray(receivers, jnp.int32),
        node_mask=(jnp.ones(N, bool) if node_mask is None
                   else jnp.asarray(node_mask)),
        edge_mask=(jnp.ones(E, bool) if edge_mask is None
                   else jnp.asarray(edge_mask)),
        labels=(jnp.zeros(N, jnp.int32) if labels is None
                else jnp.asarray(labels, jnp.int32)),
        label_mask=(jnp.ones(N, bool) if label_mask is None
                    else jnp.asarray(label_mask)),
        positions=(jnp.zeros((N, 3), jnp.float32) if positions is None
                   else jnp.asarray(positions, jnp.float32)),
        edge_attr=(jnp.zeros((E, d_edge), jnp.float32) if edge_attr is None
                   else jnp.asarray(edge_attr, jnp.float32)),
        graph_ids=(jnp.zeros(N, jnp.int32) if graph_ids is None
                   else jnp.asarray(graph_ids, jnp.int32)),
        targets=(jnp.zeros((n_graphs,), jnp.float32) if targets is None
                 else jnp.asarray(targets, jnp.float32)),
    )


def scatter_sum(messages: jax.Array, dst: jax.Array, n: int) -> jax.Array:
    """Σ over incoming edges — the message-passing primitive."""
    return jax.ops.segment_sum(messages, dst, num_segments=n)


def segment_mean(messages, dst, mask, n) -> jax.Array:
    m = jnp.where(mask[:, None], messages, 0.0)
    tot = jax.ops.segment_sum(m, dst, num_segments=n)
    cnt = jax.ops.segment_sum(mask.astype(jnp.float32), dst, num_segments=n)
    return tot / jnp.maximum(cnt, 1.0)[:, None]


def segment_softmax(scores, dst, mask, n) -> jax.Array:
    """Edge softmax per receiving node (GAT): numerically stable.

    scores: [E] or [E, H]; mask: bool[E].
    """
    m = mask if scores.ndim == 1 else mask[:, None]
    s = jnp.where(m, scores, -jnp.inf)
    smax = jax.ops.segment_max(s, dst, num_segments=n)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    e = jnp.where(m, jnp.exp(s - smax[dst]), 0.0)
    z = jax.ops.segment_sum(e, dst, num_segments=n)
    return e / jnp.maximum(z[dst], 1e-16)


def degree(dst, mask, n) -> jax.Array:
    return jax.ops.segment_sum(mask.astype(jnp.float32), dst, num_segments=n)
