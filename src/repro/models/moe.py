"""Routed mixture-of-experts FFN — capacity-based scatter dispatch.

Dispatch mechanism: GShard/Switch *semantics* (top-k routing, capacity
factor, token dropping) but implemented with scatter/gather instead of the
classic one-hot dispatch einsum. The one-hot einsum costs
``N·E·C·d`` MXU FLOPs (≈26× the useful expert FLOPs at 4k seq); the scatter
implementation moves the same bytes (``N·topk·d``) with *zero* matmul
amplification, so the roofline compute term stays honest and the dominant
cost is the expert GEMMs themselves (``E·C·d·ff``), exactly
``capacity_factor×`` the model FLOPs.

Sharding: token/row dim sharded over (pod, data); expert hidden dim ``ff``
sharded over model (TP inside each expert — every device holds a slice of
every expert). EP (experts over model) is a config switch explored in the
§Perf log.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    capacity_factor: float = 1.25
    n_shared: int = 0          # llama4-style always-on shared expert(s)
    gated: bool = True         # SwiGLU experts
    ep_axis: str | None = "data"  # §Perf A2: pin expert buffers to the axis
                                  # the expert weights shard over, so GSPMD
                                  # moves TOKENS (a2a) instead of gathering
                                  # expert weights. None → let GSPMD choose.


def _constrain(x, spec):
    """with_sharding_constraint that degrades to a no-op outside a mesh."""
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec)
        )
    except (ValueError, RuntimeError, TypeError):
        return x


def init_moe(key, cfg: MoEConfig):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    n_in = 2 * f if cfg.gated else f
    p = {
        "router": dense_init(k1, d, E)["w"],
        "w_in": jax.random.truncated_normal(k2, -2, 2, (E, d, n_in), jnp.float32)
        * (1.0 / d) ** 0.5,
        "w_out": jax.random.truncated_normal(k3, -2, 2, (E, f, d), jnp.float32)
        * (1.0 / f) ** 0.5,
    }
    if cfg.n_shared:
        p["shared_in"] = (
            jax.random.truncated_normal(k4, -2, 2, (d, n_in * cfg.n_shared),
                                        jnp.float32) * (1.0 / d) ** 0.5
        )
        p["shared_out"] = (
            jax.random.truncated_normal(k5, -2, 2, (f * cfg.n_shared, d),
                                        jnp.float32) * (1.0 / f) ** 0.5
        )
    return p


def _expert_ffn(x, w_in, w_out, gated: bool, dtype):
    h = jnp.einsum("ecd,edf->ecf", x, w_in.astype(dtype))
    if gated:
        u, g = jnp.split(h, 2, axis=-1)
        h = u * jax.nn.silu(g)
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w_out.astype(dtype))


def moe_ffn(params, x: jax.Array, cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: [..., d] → (y: [..., d], aux_loss scalar).

    Flattens leading dims to N tokens; capacity C = N·top_k·cf / E.
    Over-capacity tokens are dropped (their residual passes through — the
    GShard convention).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    dtype = x.dtype
    xf = x.reshape(-1, d)
    N = xf.shape[0]
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(N * K * cfg.capacity_factor) // E)

    logits = (xf.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # [N, E]
    gate_w, gate_e = jax.lax.top_k(probs, K)                  # [N, K]
    if K > 1:
        gate_w = gate_w / jnp.maximum(
            jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9
        )

    # load-balancing aux loss (Switch eq. 4)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_e[:, 0], E), axis=0) / N
    ) * E if K == 1 else jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_e, E), axis=(0, 1)) / (N * K)
    ) * E
    aux = jnp.sum(me * ce) * E

    # ---- position of each (token, k) within its expert buffer ----
    flat_e = gate_e.reshape(-1)                               # [N*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # [N*K, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot            # exclusive count
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C

    # ---- scatter tokens into per-expert buffers [E, C, d] ----
    xs = jnp.repeat(xf, K, axis=0)                            # [N*K, d]
    se = jnp.where(keep, flat_e, 0)
    ss = jnp.where(keep, slot, 0)
    buf = jnp.zeros((E, C, d), dtype)
    buf = buf.at[se, ss].add(
        jnp.where(keep[:, None], xs, 0).astype(dtype)
    )
    if cfg.ep_axis:
        # expert-parallel placement: buffers co-located with the expert
        # weights' shard axis → the scatter above becomes the token a2a and
        # the expert GEMMs run with STATIONARY weights (no param all-gather)
        buf = _constrain(buf, (cfg.ep_axis, None, None))

    y = _expert_ffn(buf, params["w_in"], params["w_out"], cfg.gated, dtype)
    if cfg.ep_axis:
        y = _constrain(y, (cfg.ep_axis, None, None))

    # ---- gather back + gate-weighted combine ----
    out_rows = y[se, ss]                                      # [N*K, d]
    out_rows = jnp.where(keep[:, None], out_rows, 0)
    w = gate_w.reshape(-1)[:, None].astype(dtype)
    combined = jnp.sum((out_rows * w).reshape(N, K, d), axis=1)

    if cfg.n_shared:
        h = xf.astype(dtype) @ params["shared_in"].astype(dtype)
        if cfg.gated:
            u, g = jnp.split(h, 2, axis=-1)
            h = u * jax.nn.silu(g)
        else:
            h = jax.nn.gelu(h)
        combined = combined + h @ params["shared_out"].astype(dtype)

    return combined.reshape(orig_shape), aux
