"""Decoder-only LM family — one config covers all 5 assigned transformers.

Features (per-arch knobs in configs/):
  GQA (n_kv_heads < n_heads), decoupled d_head, RoPE w/ per-arch theta,
  qk-norm (qwen3), attention + final logit softcaps (gemma2), alternating
  local/global layer patterns (gemma2 sliding window, llama4 chunked iRoPE
  with NoPE-on-global), MoE FFN (phi3.5-moe top-2, llama4-scout top-1 +
  shared expert), sandwich norms (gemma2), tied embeddings.

Execution model: layers are stacked per *pattern position* and scanned over
period groups (HLO stays O(period), not O(L)); attention is blockwise
flash-style (O(block) memory — see layers.py) so 32k prefill and 500k decode
lower within per-device HBM; the LM head loss is sequence-chunked so
[B, S, vocab] logits are never materialized.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_mod


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # attention
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_scale: float | None = None                # None → d_head ** -0.5
    attn_softcap: float | None = None
    final_softcap: float | None = None
    window: int | None = None                      # local attention width
    layer_pattern: tuple[str, ...] = ("global",)   # period pattern
    rope_on_global: bool = True                    # False → NoPE on global (iRoPE)
    sandwich_norm: bool = False                    # gemma2 post-norms
    embed_scale: bool = False                      # gemma scales by sqrt(d)
    # ffn
    moe: moe_mod.MoEConfig | None = None
    # execution
    compute_dtype: Any = jnp.bfloat16
    block_q: int = 512
    block_kv: int = 512
    xent_chunk: int = 1024
    scan_layers: bool = True

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.period == 0
        return self.n_layers // self.period

    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6·N·D roofline)."""
        d, f, H, Hkv, dh = (
            self.d_model, self.d_ff, self.n_heads, self.n_kv_heads, self.d_head
        )
        attn = d * H * dh + 2 * d * Hkv * dh + H * dh * d
        if self.moe is not None:
            m = self.moe
            n_in = 2 * f if m.gated else f
            ffn = d * m.n_experts + m.n_experts * (d * n_in + f * d)
            if m.n_shared:
                ffn += d * n_in * m.n_shared + f * m.n_shared * d
        else:
            ffn = 3 * d * f  # SwiGLU
        return self.n_layers * (attn + ffn) + self.vocab * d

    def n_active_params(self) -> int:
        """Activated parameters per token (MoE top-k) — 6·N_active·D."""
        if self.moe is None:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        m = self.moe
        H, Hkv, dh = self.n_heads, self.n_kv_heads, self.d_head
        attn = d * H * dh + 2 * d * Hkv * dh + H * dh * d
        n_in = 2 * f if m.gated else f
        ffn = d * m.n_experts + m.top_k * (d * n_in + f * d)
        if m.n_shared:
            ffn += d * n_in * m.n_shared + f * m.n_shared * d
        return self.n_layers * (attn + ffn) + self.vocab * d


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: TransformerConfig):
    ks = jax.random.split(key, 8)
    d, H, Hkv, dh, f = (
        cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff
    )
    p = {
        "ln_attn": L.rmsnorm_init(d),
        "wq": L.dense_init(ks[0], d, H * dh),
        "wk": L.dense_init(ks[1], d, Hkv * dh),
        "wv": L.dense_init(ks[2], d, Hkv * dh),
        "wo": L.dense_init(ks[3], H * dh, d),
        "ln_ffn": L.rmsnorm_init(d),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(dh)
        p["k_norm"] = L.rmsnorm_init(dh)
    if cfg.sandwich_norm:
        p["ln_attn_post"] = L.rmsnorm_init(d)
        p["ln_ffn_post"] = L.rmsnorm_init(d)
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks[4], cfg.moe)
    else:
        p["w_gate"] = L.dense_init(ks[4], d, f)
        p["w_up"] = L.dense_init(ks[5], d, f)
        p["w_down"] = L.dense_init(ks[6], f, d)
    return p


def init_params(key, cfg: TransformerConfig):
    ke, kl, kf = jax.random.split(key, 3)
    # stack layers per pattern position: [n_groups, ...] pytrees
    def stack_for_position(p_idx):
        keys = jax.random.split(jax.random.fold_in(kl, p_idx), cfg.n_groups)
        return jax.vmap(lambda k: _init_layer(k, cfg))(keys)

    return {
        "embed": jax.random.truncated_normal(
            ke, -2, 2, (cfg.vocab, cfg.d_model), jnp.float32
        ) * (1.0 / cfg.d_model) ** 0.5,
        "positions": {
            f"p{i}": stack_for_position(i) for i in range(cfg.period)
        },
        "ln_final": L.rmsnorm_init(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attention(p, h, cfg: TransformerConfig, kind: str, *, q_offset=0,
               kv_cache=None, cache_len=None):
    """Self-attention sublayer. Returns (out, (k, v)) — k/v for cache build."""
    B, S, d = h.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.compute_dtype
    q = L.dense(p["wq"], h, dtype=dt).reshape(B, S, H, dh)
    k = L.dense(p["wk"], h, dtype=dt).reshape(B, S, Hkv, dh)
    v = L.dense(p["wv"], h, dtype=dt).reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        k = L.rmsnorm(p["k_norm"], k)
    use_rope = cfg.rope_on_global or kind == "local"
    if use_rope:
        if kv_cache is not None:
            pos = cache_len[:, None] + jnp.arange(S)[None, :]  # [B, S]
        else:
            pos = jnp.arange(S)[None, :] + q_offset
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)

    window = cfg.window if kind == "local" else None
    if kv_cache is None:
        o = L.blockwise_attention(
            q, k, v, causal=True, window=window, q_offset=q_offset,
            block_q=cfg.block_q, block_kv=cfg.block_kv,
            attn_softcap=cfg.attn_softcap, scale=cfg.attn_scale,
        )
    else:
        kc, vc = kv_cache  # [B, Smax, Hkv, dh]
        b_idx = jnp.arange(B)
        kc = kc.at[b_idx, cache_len].set(k[:, 0])
        vc = vc.at[b_idx, cache_len].set(v[:, 0])
        o = L.decode_attention(
            q, kc, vc, cache_len + 1, window=window,
            attn_softcap=cfg.attn_softcap, scale=cfg.attn_scale,
        )
        k, v = kc, vc
    o = o.reshape(B, S, H * dh)
    return L.dense(p["wo"], o, dtype=dt), (k, v)


def _ffn(p, h, cfg: TransformerConfig):
    dt = cfg.compute_dtype
    if cfg.moe is not None:
        return moe_mod.moe_ffn(p["moe"], h.astype(dt), cfg.moe)
    g = L.dense(p["w_gate"], h, dtype=dt)
    u = L.dense(p["w_up"], h, dtype=dt)
    return L.dense(p["w_down"], jax.nn.silu(g) * u, dtype=dt), jnp.float32(0)


def _block(p, h, cfg: TransformerConfig, kind: str, **kw):
    a_in = L.rmsnorm(p["ln_attn"], h)
    a_out, kv = _attention(p, a_in, cfg, kind, **kw)
    if cfg.sandwich_norm:
        a_out = L.rmsnorm(p["ln_attn_post"], a_out)
    h = h + a_out
    f_in = L.rmsnorm(p["ln_ffn"], h)
    f_out, aux = _ffn(p, f_in, cfg)
    if cfg.sandwich_norm:
        f_out = L.rmsnorm(p["ln_ffn_post"], f_out)
    return h + f_out, kv, aux


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, tokens: jax.Array, cfg: TransformerConfig,
            *, return_cache_pad: int = 0):
    """tokens i32[B, S] → (hidden f32[B, S, d], aux_loss, cache | None).

    ``return_cache_pad > 0`` allocates decode KV caches of that length and
    fills the first S positions (prefill path).
    """
    B, S = tokens.shape
    dt = cfg.compute_dtype
    h = params["embed"][tokens].astype(dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)

    def group_body(carry, group_params):
        h, aux = carry
        kvs = []
        for i, kind in enumerate(cfg.layer_pattern):
            h, kv, a = _block(group_params[f"p{i}"], h, cfg, kind)
            aux = aux + a
            kvs.append(kv)
        return (h, aux), (kvs if return_cache_pad else None)

    groups = params["positions"]
    if cfg.scan_layers:
        (h, aux), kv_stacked = jax.lax.scan(
            group_body, (h, jnp.float32(0)), groups
        )
    else:
        aux = jnp.float32(0)
        kv_all = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda x: x[g], groups)
            (h, aux), kvs = group_body((h, aux), gp)
            kv_all.append(kvs)
        kv_stacked = kv_all

    h = L.rmsnorm(params["ln_final"], h)

    cache = None
    if return_cache_pad:
        pad = return_cache_pad

        def to_cache(x):  # [G, B, S, Hkv, dh] → padded [G, B, pad, Hkv, dh]
            return jnp.pad(x, ((0, 0), (0, 0), (0, pad - S), (0, 0), (0, 0)))

        cache = {
            "kv": jax.tree.map(to_cache, kv_stacked),
            "len": jnp.full((B,), S, jnp.int32),
        }
    return h, aux, cache


def logits_from_hidden(params, h: jax.Array, cfg: TransformerConfig):
    logit = h.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    if cfg.final_softcap is not None:
        logit = L.softcap(logit, cfg.final_softcap)
    return logit


def chunked_xent(params, h, labels, mask, cfg: TransformerConfig):
    """Sequence-chunked LM cross-entropy — never materializes [B,S,V]."""
    B, S, d = h.shape
    c = min(cfg.xent_chunk, S)
    assert S % c == 0
    hc = h.reshape(B, S // c, c, d).swapaxes(0, 1)        # [n, B, c, d]
    lc = labels.reshape(B, S // c, c).swapaxes(0, 1)
    mc = mask.reshape(B, S // c, c).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        hh, ll, mm = xs
        logits = logits_from_hidden(params, hh, cfg)      # [B, c, V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = jnp.where(mm, lse - gold, 0.0)
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(
        jax.checkpoint(chunk_loss), jnp.float32(0), (hc, lc, mc)
    )
    return total / jnp.maximum(jnp.sum(mask), 1)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """Decode KV cache pytree (used as ShapeDtypeStruct input in dry-runs)."""
    shape = (cfg.n_groups, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "kv": [
            (jnp.zeros(shape, cfg.compute_dtype), jnp.zeros(shape, cfg.compute_dtype))
            for _ in range(cfg.period)
        ],
        "len": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cache, tokens: jax.Array, cfg: TransformerConfig):
    """One-token decode: tokens i32[B, 1] → (logits f32[B, V], new cache)."""
    B = tokens.shape[0]
    dt = cfg.compute_dtype
    h = params["embed"][tokens].astype(dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)
    cache_len = cache["len"]

    def group_body(h, xs):
        group_params, kv_group = xs
        new_kvs = []
        for i, kind in enumerate(cfg.layer_pattern):
            kv = kv_group[i]
            hh, new_kv, _ = _block(
                group_params[f"p{i}"], h, cfg, kind,
                kv_cache=kv, cache_len=cache_len,
            )
            h = hh
            new_kvs.append(new_kv)
        return h, new_kvs

    h, new_kv = jax.lax.scan(
        group_body, h, (params["positions"], cache["kv"])
    )
    h = L.rmsnorm(params["ln_final"], h)
    logits = logits_from_hidden(params, h[:, 0], cfg)
    return logits, {"kv": new_kv, "len": cache_len + 1}
