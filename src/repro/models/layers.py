"""Shared neural-net layers for the architecture zoo (pure JAX, no flax).

Parameters are plain nested dicts of arrays; every layer is a pair of
``init(key, ...) -> params`` and a pure apply function. Initializers match
standard practice (trunc-normal fan-in); dtype policy: params fp32 (cast at
use), activations bf16-able via the ``compute_dtype`` argument of the model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None):
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return {
        "w": jax.random.truncated_normal(key, -2, 2, (d_in, d_out), jnp.float32)
        * scale
    }


def dense(params, x, *, dtype=None):
    w = params["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    return x @ w


def rmsnorm_init(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}  # (1 + scale) convention


def rmsnorm(params, x, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x, *, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def softcap(x, cap: float):
    """Gemma-2 style tanh logit capping."""
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, d_head]; positions: broadcastable to [..., S]."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., None, :]                     # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure JAX, O(block) memory.
# ---------------------------------------------------------------------------

def _attn_block(q, k, v, qpos, kpos, *, scale, causal, window, attn_softcap):
    """One (q-block, kv-block) tile with running-softmax statistics.

    q: [B, bq, Hq, dh]  k/v: [B, bk, Hkv, dh]; GQA via head grouping.
    Returns (scores-exp-sum m, l, o) update pieces handled by caller.
    """
    B, bq, Hq, dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, bq, Hkv, g, dh)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale                                                # [B,Hkv,g,bq,bk]
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    mask = jnp.ones((bq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    mask &= (kpos >= 0)[None, :]  # padding blocks
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    return s


def blockwise_attention(
    q: jax.Array,            # [B, Sq, Hq, dh]
    k: jax.Array,            # [B, Sk, Hkv, dh]
    v: jax.Array,            # [B, Sk, Hkv, dh]
    *,
    causal: bool = True,
    window: int | None = None,   # local/sliding width (None = full)
    q_offset: int | jax.Array = 0,  # absolute position of q[0]
    block_q: int = 512,
    block_kv: int = 512,
    attn_softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Memory-efficient attention: scan over q-blocks × kv-blocks with running
    max/sum (never materializes [Sq, Sk]).

    When ``window`` is static, only ceil((window+block_q)/block_kv)+1 kv
    blocks are touched per q block (true sub-quadratic compute — this is the
    gemma2/llama4 local path and the long-context enabler).
    """
    B, Sq0, Hq, dh = q.shape
    Sk0 = k.shape[1]
    Hkv = k.shape[2]
    g = Hq // Hkv
    scale = (dh ** -0.5) if scale is None else scale
    block_q = min(block_q, Sq0)
    block_kv = min(block_kv, Sk0)
    # pad ragged tails; padded keys are masked via kpos = -1
    pq, pk = (-Sq0) % block_q, (-Sk0) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq, Sk = Sq0 + pq, Sk0 + pk
    nq, nk = Sq // block_q, Sk // block_kv

    if window is not None:
        n_kv_blocks = min(nk, (window + block_q) // block_kv + 1)
    else:
        n_kv_blocks = nk

    kpos_all = jnp.where(jnp.arange(Sk) < Sk0, jnp.arange(Sk), -1)
    qpos_all = jnp.arange(Sq) + q_offset

    def q_block_body(qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * block_q, block_q, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(qpos_all, qi * block_q, block_q)

        # kv block range: for windowed attention start near the diagonal
        if window is not None:
            # first kv position possibly visible to this q block
            lo = qi * block_q + q_offset - window + 1
            lo = jnp.clip(lo, 0, Sk - n_kv_blocks * block_kv)
            k0 = (lo // block_kv).astype(jnp.int32)
        else:
            k0 = jnp.asarray(0, jnp.int32)

        def kv_step(carry, j):
            m, l, o = carry
            kj = k0 + j
            kb = jax.lax.dynamic_slice_in_dim(k, kj * block_kv, block_kv, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, kj * block_kv, block_kv, axis=1)
            kpos = jax.lax.dynamic_slice_in_dim(kpos_all, kj * block_kv, block_kv)
            s = _attn_block(
                qb, kb, vb, qpos, kpos, scale=scale, causal=causal,
                window=window, attn_softcap=attn_softcap,
            )                                                 # [B,Hkv,g,bq,bk]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(
                jnp.isfinite(m), jnp.exp(m - m_safe), jnp.zeros_like(m)
            )
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            o_new = o * corr[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hkv, g, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, block_q), jnp.float32)
        o0 = jnp.zeros((B, Hkv, g, block_q, dh), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, o0), jnp.arange(n_kv_blocks)
        )
        o = o / jnp.maximum(l[..., None], 1e-30)
        # [B,Hkv,g,bq,dh] → [B,bq,Hq,dh]
        return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, block_q, Hq, dh)

    out = jax.lax.map(q_block_body, jnp.arange(nq))          # [nq,B,bq,Hq,dh]
    out = jnp.transpose(out, (1, 0, 2, 3, 4)).reshape(B, Sq, Hq, dh)
    return out[:, :Sq0].astype(q.dtype)


def decode_attention(
    q: jax.Array,        # [B, 1, Hq, dh]
    k_cache: jax.Array,  # [B, S, Hkv, dh]
    v_cache: jax.Array,  # [B, S, Hkv, dh]
    cache_len: jax.Array,  # i32[B] — valid prefix length per sequence
    *,
    window: int | None = None,
    attn_softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Single-token attention against a (possibly windowed) KV cache."""
    B, S, Hkv, dh = k_cache.shape
    Hq = q.shape[2]
    g = Hq // Hkv
    scale = (dh ** -0.5) if scale is None else scale
    qg = q.reshape(B, Hkv, g, dh)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    kpos = jnp.arange(S)[None, :]                     # [1,S]
    valid = kpos < cache_len[:, None]
    if window is not None:
        valid &= kpos >= (cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, dh).astype(q.dtype)
