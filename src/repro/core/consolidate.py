"""Tombstone consolidation — fixes MASK's unbounded growth (§5.2).

The paper observes that MASK "space grows continuously as the stream
performs, which may cause inevitable memory issues". Production systems
(FreshDiskANN's streaming merge) periodically *consolidate*: physically
remove tombstoned vertices while repairing connectivity with the best
available strategy — MASK's cheap O(1) deletes between consolidations,
GLOBAL-quality graph afterwards.

Since the consolidation-engine rewrite (DESIGN.md §8) the pass is a
first-class device-resident subsystem: :func:`consolidate_chunk_impl` is a
traceable, fixed-shape compaction step built on the shared delete repair
appliers (``delete.REPAIR_APPLIERS``) and the bulk scatter primitives —
repair plans for a chunk of tombstones computed via the batched beam
engine, applied in grouped scatters, freed slots returned to the allocator
(``present=False`` → reusable by ``insert``). It runs inside the session as
the ``OP_CONSOLIDATE`` op-IR branch (``core/ops.py``), auto-triggered by
``MaintenanceParams.consolidate_threshold`` at delete/flush boundaries
(``core/session.py``) and per-shard by ``ShardedSession``.

The host-side helpers below keep the legacy surface: ``consolidate`` /
``maybe_consolidate`` route an ``IPGMIndex`` or ``Session`` through the
jitted pass; ``consolidate_reference`` is the pre-rewrite revive-then-delete
path, now exception-safe (state/strategy roll back if repair raises) and
kept as the semantic parity oracle (``tests/test_serving.py``).

The op's cross-layer wiring — the reserved ``CONSOLIDATE_KEY_STREAM``,
the ``JR_CONSOLIDATE`` journal code with its cseq dedup counter, the
``pre-consolidate``/``post-consolidate`` crash points, and the
``consolidate_counter`` checkpoint contract — is declared once on the
``CONSOLIDATE`` entry of the maintenance-op registry (``core/maint.py``,
DESIGN.md §14); the session, journal replay, fault harness, and stats
layers all derive from that entry.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delete as delete_mod
from repro.core.graph import NULL, GraphState
from repro.core.params import IndexParams


def masked_fraction(state: GraphState) -> float:
    """Tombstone share of the traversable graph (host-side, synchronizes)."""
    n_masked = float(jnp.sum(state.masked))
    n_present = float(jnp.sum(state.present))
    return n_masked / max(n_present, 1.0)


def consolidate_chunk_impl(
    state: GraphState,
    ids: jax.Array,       # i32[B]  tombstone slots (NULL padded)
    valid: jax.Array,     # bool[B]
    key: jax.Array,
    params: IndexParams,
) -> tuple[GraphState, jax.Array]:
    """Traceable compaction of one tombstone chunk (the §8 device pass).

    Lanes that are not actual tombstones (``present & ~alive``) are dropped,
    so the step is idempotent and safe against stale frames. Phases:

      1. repair — the configured ``consolidate_strategy``'s vectorized
         applier rewires every surviving in-neighbor's row (LOCAL splice /
         GLOBAL re-search via the batched beam engine; "pure" skips repair).
         Tombstones are already non-alive, so repair searches and
         SELECT-NEIGHBORS can never re-link them.
      2. scrub + free — ``_finalize_removal`` NULLs every edge into the
         chunk and clears ``present``, returning the slots to the allocator
         (``size`` was already decremented when MASK tombstoned them).

    Returns (state, n_consolidated i32[]).
    """
    strategy = params.maintenance.consolidate_strategy
    valid = valid & (ids != NULL)
    safe = jnp.where(valid, ids, 0)
    valid = valid & state.masked[safe]
    dead = delete_mod._dead_mask(state, ids, valid)
    if strategy != "pure":
        state = delete_mod.REPAIR_APPLIERS[strategy](
            state, ids, valid, dead, key, params
        )
    state = delete_mod._finalize_removal(state, ids, valid)
    return state, jnp.sum(valid).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Host-side drivers (legacy surface) — route through the session's jitted
# pass; accept an IPGMIndex (``.session``) or a Session directly.
# ---------------------------------------------------------------------------

def _session_of(index):
    return getattr(index, "session", index)


def consolidate(index, *, strategy: str | None = None,
                chunk: int | None = None) -> int:
    """Physically remove every tombstone through the jitted compaction pass.

    ``strategy=None`` inherits the configured
    ``MaintenanceParams.consolidate_strategy`` (same default as
    ``IPGMIndex.consolidate``). Returns the number of consolidated vertices.
    Synchronous: the session is flushed before returning, so the caller
    observes the compacted state.
    """
    sess = _session_of(index)
    n = sess.consolidate(strategy=strategy, chunk=chunk)
    sess.flush()
    return n


def maybe_consolidate(index, *, threshold: float = 0.2,
                      strategy: str | None = None) -> int:
    """Consolidate when tombstones exceed ``threshold`` of the graph.

    One-shot host-side check; for a standing policy set
    ``MaintenanceParams.consolidate_threshold`` and let the session
    auto-trigger at delete/flush boundaries instead (DESIGN.md §8).
    """
    if masked_fraction(_session_of(index).state) >= threshold:
        return consolidate(index, strategy=strategy)
    return 0


def consolidate_reference(index, *, strategy: str = "global") -> int:
    """The pre-rewrite revive-then-delete pass — the parity oracle.

    Tombstones are temporarily revived (``alive=True``) so the delete
    strategy's precheck accepts them, then deleted for real. Exception-safe:
    the index's state and strategy are snapshotted up front and rolled back
    if the repair raises, so a failed pass can no longer leave the index
    half-revived with a foreign strategy installed. Semantically equivalent
    to :func:`consolidate` (same alive/present sets, invariant-clean graph);
    edge-level results differ because the repair searches draw from the
    delete op-key chain rather than the consolidation chain.
    """
    sess = _session_of(index)
    sess.flush()
    state = sess.state
    masked_ids = np.flatnonzero(np.asarray(state.masked))
    if masked_ids.size == 0:
        return 0
    # rollback anchor: a deep copy — the delete path donates the live
    # buffers, so the snapshot must own its memory
    snapshot = jax.tree.map(jnp.copy, state)
    old_strategy = index.strategy
    try:
        # revive → alive so the strategy's precheck accepts the batch
        alive = state.alive.at[jnp.asarray(masked_ids)].set(True)
        index.state = dataclasses.replace(
            state, alive=alive,
            size=state.size + jnp.asarray(masked_ids.size, jnp.int32),
        )
        index.strategy = strategy
        index.delete(masked_ids)
    except BaseException:
        index.state = snapshot
        raise
    finally:
        index.strategy = old_strategy
    return int(masked_ids.size)
