"""Tombstone consolidation — fixes MASK's unbounded growth (§5.2).

The paper observes that MASK "space grows continuously as the stream
performs, which may cause inevitable memory issues". Production systems
(FreshDiskANN's streaming merge) periodically *consolidate*: physically
remove tombstoned vertices while repairing connectivity with the best
available strategy. This module implements that pass — MASK's cheap O(1)
deletes between consolidations, GLOBAL-quality graph afterwards — giving
the latency/quality trade-off knob a deployment actually runs.
"""
from __future__ import annotations

import numpy as np

from repro.core import delete as delete_mod
from repro.core.graph import GraphState
from repro.core.maintenance import IPGMIndex


def masked_fraction(state: GraphState) -> float:
    import jax.numpy as jnp
    n_masked = float(jnp.sum(state.masked))
    n_present = float(jnp.sum(state.present))
    return n_masked / max(n_present, 1.0)


def consolidate(index: IPGMIndex, *, strategy: str = "global",
                chunk: int | None = None) -> int:
    """Physically remove every tombstone, repairing edges with ``strategy``.

    Returns the number of consolidated vertices. Tombstones are temporarily
    revived (alive=True) so the repair delete path's precheck accepts them;
    their in/out edges are then rewired exactly as a fresh delete would.
    """
    import dataclasses

    import jax.numpy as jnp

    state = index.state
    masked_ids = np.flatnonzero(np.asarray(state.masked))
    if masked_ids.size == 0:
        return 0
    # revive → alive so the strategy's precheck accepts the batch
    alive = state.alive.at[jnp.asarray(masked_ids)].set(True)
    index.state = dataclasses.replace(
        state, alive=alive,
        size=state.size + jnp.asarray(masked_ids.size, jnp.int32),
    )
    old_strategy = index.strategy
    index.strategy = strategy
    try:
        index.delete(masked_ids)
    finally:
        index.strategy = old_strategy
    return int(masked_ids.size)


def maybe_consolidate(index: IPGMIndex, *, threshold: float = 0.2,
                      strategy: str = "global") -> int:
    """Consolidate when tombstones exceed ``threshold`` of the graph."""
    if masked_fraction(index.state) >= threshold:
        return consolidate(index, strategy=strategy)
    return 0
