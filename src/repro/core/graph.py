"""Fixed-capacity proximity-graph state — the TPU-native index layout.

The paper's adjacency lists / reverse graph become dense, fixed-degree
``int32`` arrays so every operation is a gather/scatter (no pointer chasing).

Invariants maintained by every public op (property-tested in
``tests/test_graph_invariants.py``):

  I1  G' == reverse(G): edge (u→v) is in ``adj[u]`` iff u is in ``radj[v]``.
      Edge insertion REFUSES (drops the edge) when ``radj[v]`` is full, so
      the invariant never breaks (see DESIGN.md §2, bounded in-degree).
  I2  adjacency entries are either -1 or the id of a *present* slot.
  I3  a slot is ``alive`` ⇒ it is ``present``; MASK-deleted slots are
      present but not alive (traversable, never reported).
  I4  no self-edges, no duplicate entries within a row.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

NULL = -1  # padding id for empty adjacency entries


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "vectors", "sqnorms", "adj", "radj", "alive", "present", "size",
    ],
    meta_fields=["capacity", "dim", "d_out", "d_in", "metric"],
)
@dataclasses.dataclass(frozen=True)
class GraphState:
    """Pytree holding the full index (one shard of it when distributed)."""

    # --- data ---
    vectors: jax.Array   # f32[capacity, dim]
    sqnorms: jax.Array   # f32[capacity]            ||x||^2 cache (L2 metric)
    adj: jax.Array       # i32[capacity, d_out]     out-neighbors, NULL padded
    radj: jax.Array      # i32[capacity, d_in]      in-neighbors,  NULL padded
    alive: jax.Array     # bool[capacity]           reportable as a result
    present: jax.Array   # bool[capacity]           traversable (alive | masked)
    size: jax.Array      # i32                      number of alive slots
    # --- static metadata ---
    capacity: int
    dim: int
    d_out: int
    d_in: int
    metric: str          # "l2" | "ip" | "cos"

    @property
    def masked(self) -> jax.Array:
        """MASK-tombstoned slots: traversable but not reportable."""
        return self.present & ~self.alive


def init_graph(
    capacity: int,
    dim: int,
    *,
    d_out: int = 16,
    d_in: int | None = None,
    metric: str = "l2",
    dtype: Any = jnp.float32,
) -> GraphState:
    if metric not in ("l2", "ip", "cos"):
        raise ValueError(f"unknown metric {metric!r}")
    d_in = 2 * d_out if d_in is None else d_in
    return GraphState(
        vectors=jnp.zeros((capacity, dim), dtype),
        sqnorms=jnp.zeros((capacity,), jnp.float32),
        adj=jnp.full((capacity, d_out), NULL, jnp.int32),
        radj=jnp.full((capacity, d_in), NULL, jnp.int32),
        alive=jnp.zeros((capacity,), bool),
        present=jnp.zeros((capacity,), bool),
        size=jnp.asarray(0, jnp.int32),
        capacity=capacity,
        dim=dim,
        d_out=d_out,
        d_in=d_in,
        metric=metric,
    )


# ---------------------------------------------------------------------------
# Row-level edge surgery. All helpers are jit-safe (static shapes) and keep
# rows compact-from-the-left is NOT required: rows may have NULL holes; every
# consumer masks on ``entry != NULL``.
# ---------------------------------------------------------------------------

def row_insert(row: jax.Array, value: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Insert ``value`` into the first NULL hole of ``row``.

    Returns (new_row, inserted?). Refuses (inserted=False) when the row is
    full or the value is already there (keeps I1/I4 cheaply).
    """
    already = jnp.any(row == value)
    holes = row == NULL
    has_hole = jnp.any(holes)
    pos = jnp.argmax(holes)  # first hole
    do = has_hole & ~already
    new_row = jnp.where(
        do & (jnp.arange(row.shape[0]) == pos), value, row
    )
    return new_row, do | already  # "already present" counts as success


def row_remove(row: jax.Array, value: jax.Array) -> jax.Array:
    """Remove every occurrence of ``value`` from ``row`` (→ NULL)."""
    return jnp.where(row == value, NULL, row)


def add_edge(state: GraphState, u: jax.Array, v: jax.Array) -> GraphState:
    """Add directed edge u→v, updating radj; refuses if either row is full.

    The refusal is atomic: the edge lands in both adj[u] and radj[v] or in
    neither (invariant I1).
    """
    new_adj_row, ok_a = row_insert(state.adj[u], v)
    new_radj_row, ok_r = row_insert(state.radj[v], u)
    ok = ok_a & ok_r & (u != v) & (u != NULL) & (v != NULL)
    adj = state.adj.at[u].set(jnp.where(ok, new_adj_row, state.adj[u]))
    radj = state.radj.at[v].set(jnp.where(ok, new_radj_row, state.radj[v]))
    return dataclasses.replace(state, adj=adj, radj=radj)


def remove_edge(state: GraphState, u: jax.Array, v: jax.Array) -> GraphState:
    adj = state.adj.at[u].set(row_remove(state.adj[u], v))
    radj = state.radj.at[v].set(row_remove(state.radj[v], u))
    return dataclasses.replace(state, adj=adj, radj=radj)


def set_out_edges(state: GraphState, u: jax.Array, targets: jax.Array) -> GraphState:
    """Replace the full out-neighborhood of ``u`` with ``targets``.

    ``targets`` is i32[d_out], NULL padded. Reverse rows of both the old and
    new targets are fixed up. Edges whose reverse row is full are dropped
    (refused) to keep I1. Implemented as remove-all + loop of add_edge over
    the (small, static) degree — executes inside jit.
    """
    d_out = state.d_out

    def rm_one(i, st):
        old = st.adj[u, i]
        return jax.lax.cond(
            old != NULL, lambda s: remove_edge(s, u, old), lambda s: s, st
        )

    state = jax.lax.fori_loop(0, d_out, rm_one, state)

    def add_one(i, st):
        tgt = targets[i]
        return jax.lax.cond(
            tgt != NULL, lambda s: add_edge(s, u, tgt), lambda s: s, st
        )

    return jax.lax.fori_loop(0, min(d_out, targets.shape[0]), add_one, state)


# ---------------------------------------------------------------------------
# Whole-graph vectorized edge scrubbing — used by batched deletes. O(cap·deg)
# but a single fused gather/where, no per-edge loop.
# ---------------------------------------------------------------------------

def scrub_edges_to(state: GraphState, dead: jax.Array) -> GraphState:
    """NULL-out every adjacency entry pointing into the ``dead`` mask.

    ``dead``: bool[capacity]. Clears both directions plus the dead rows
    themselves, preserving I1 globally.
    """
    safe_adj = jnp.where(state.adj == NULL, 0, state.adj)
    adj = jnp.where((state.adj != NULL) & dead[safe_adj], NULL, state.adj)
    safe_radj = jnp.where(state.radj == NULL, 0, state.radj)
    radj = jnp.where((state.radj != NULL) & dead[safe_radj], NULL, state.radj)
    # dead rows lose all their edges too
    adj = jnp.where(dead[:, None], NULL, adj)
    radj = jnp.where(dead[:, None], NULL, radj)
    return dataclasses.replace(state, adj=adj, radj=radj)


def free_slots(state: GraphState, ids: jax.Array, valid: jax.Array) -> GraphState:
    """Mark slots fully removed (not present, not alive).

    ``.min`` combine keeps duplicate-index scatters exact: invalid lanes park
    at index 0 writing True, which can never flip a slot.
    """
    safe = jnp.where(valid, ids, 0)
    n_freed = jnp.sum(valid & state.alive[safe])
    alive = state.alive.at[safe].min(~valid)
    present = state.present.at[safe].min(~valid)
    return dataclasses.replace(
        state, alive=alive, present=present, size=state.size - n_freed.astype(jnp.int32)
    )


def next_free_slot(state: GraphState) -> jax.Array:
    """First non-present slot (freelist head). capacity if full."""
    return jnp.argmin(state.present)  # False < True; full graph → 0 (caller checks)


def graph_stats(state: GraphState) -> dict[str, jax.Array]:
    out_deg = jnp.sum(state.adj != NULL, axis=1)
    in_deg = jnp.sum(state.radj != NULL, axis=1)
    p = state.present
    return {
        "n_alive": jnp.sum(state.alive),
        "n_present": jnp.sum(p),
        "n_masked": jnp.sum(state.masked),
        "avg_out_degree": jnp.sum(jnp.where(p, out_deg, 0)) / jnp.maximum(jnp.sum(p), 1),
        "avg_in_degree": jnp.sum(jnp.where(p, in_deg, 0)) / jnp.maximum(jnp.sum(p), 1),
        "max_in_degree": jnp.max(jnp.where(p, in_deg, 0)),
    }
