"""Proximity-graph state — the TPU-native index layout.

The paper's adjacency lists / reverse graph become dense, fixed-degree
``int32`` arrays so every operation is a gather/scatter (no pointer chasing).
Arrays are sized to a *capacity tier*: shapes are static inside any one
compiled program, and the growth engine (DESIGN.md §9) moves the state to a
larger tier with :func:`grow_state` — slot ids never move, new slots arrive
empty (NULL rows, zero vectors, not present), so every graph invariant below
is preserved verbatim by growth.

Invariants maintained by every public op (property-tested in
``tests/test_graph_invariants.py``):

  I1  G' == reverse(G): edge (u→v) is in ``adj[u]`` iff u is in ``radj[v]``.
      Scalar edge insertion REFUSES (drops the edge) when ``radj[v]`` is
      full; the bulk primitives instead keep the first ``d_in`` in-edges by
      deterministic rank and drop the overflow from ``adj`` too — either
      way the invariant never breaks (DESIGN.md §2/§4, bounded in-degree).
  I2  adjacency entries are either -1 or the id of a *present* slot.
  I3  a slot is ``alive`` ⇒ it is ``present``; MASK-deleted slots are
      present but not alive (traversable, never reported).
  I4  no self-edges, no duplicate entries within a row.
  I5  compressed-scoring sync (DESIGN.md §10): for every *present* slot,
      ``(codes[i], scales[i]) == quantize_rows(vectors[i])`` exactly; for
      every non-present slot the codes row and scale are zero. Every mutator
      that writes ``vectors`` quantizes in the same transaction; every path
      that frees a slot scrubs its codes (``vectors`` of freed slots keep
      stale bytes — codes do not, so the invariant is checkable).
  I6  insertion stamps: every *present* slot carries the monotone stamp it
      was assigned at insertion (``0 ≤ stamps[i] < clock``); every
      non-present slot has ``stamps[i] == -1``. Stamps order slots by
      insertion age (merge drain order, OP_REFINE staleness pick) and are
      scrubbed — never recycled — when a slot is freed.
  I7  staleness stamps (DESIGN.md §15): ``touch[i]`` is the ``tclock`` value
      at the last time slot i's *out-row* was rewritten through the batched
      appliers, or -1; every non-present slot has ``touch[i] == -1`` and
      every stamp is ``< tclock``. The vectorized paths maintain touch; the
      scalar reference paths (``insert_one``/``set_out_edges``) leave it at
      -1 — a -1 stamp just means "maximally stale", so OP_REFINE's
      lowest-touch pick remains correct and the B=1 parity suites (which
      compare explicit field lists) are unaffected.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

NULL = -1  # padding id for empty adjacency entries


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "vectors", "sqnorms", "codes", "scales", "adj", "radj", "alive",
        "present", "size", "stamps", "clock", "touch", "tclock",
    ],
    meta_fields=["capacity", "dim", "d_out", "d_in", "metric"],
)
@dataclasses.dataclass(frozen=True)
class GraphState:
    """Pytree holding the full index (one shard of it when distributed)."""

    # --- data ---
    vectors: jax.Array   # f32[capacity, dim]
    sqnorms: jax.Array   # f32[capacity]            ||x||^2 cache (L2 metric)
    codes: jax.Array     # i8[capacity, dim]        per-row int8 vector codes
    scales: jax.Array    # f32[capacity]            per-row dequant scales
    adj: jax.Array       # i32[capacity, d_out]     out-neighbors, NULL padded
    radj: jax.Array      # i32[capacity, d_in]      in-neighbors,  NULL padded
    alive: jax.Array     # bool[capacity]           reportable as a result
    present: jax.Array   # bool[capacity]           traversable (alive | masked)
    size: jax.Array      # i32                      number of alive slots
    stamps: jax.Array    # i32[capacity]            insertion stamp (-1 = empty)
    clock: jax.Array     # i32                      next stamp to hand out
    touch: jax.Array     # i32[capacity]            out-row write stamp (-1 = empty)
    tclock: jax.Array    # i32                      next touch stamp to hand out
    # --- static metadata ---
    capacity: int
    dim: int
    d_out: int
    d_in: int
    metric: str          # "l2" | "ip" | "cos"

    @property
    def masked(self) -> jax.Array:
        """MASK-tombstoned slots: traversable but not reportable."""
        return self.present & ~self.alive


def init_graph(
    capacity: int,
    dim: int,
    *,
    d_out: int = 16,
    d_in: int | None = None,
    metric: str = "l2",
    dtype: Any = jnp.float32,
) -> GraphState:
    if metric not in ("l2", "ip", "cos"):
        raise ValueError(f"unknown metric {metric!r}")
    d_in = 2 * d_out if d_in is None else d_in
    return GraphState(
        vectors=jnp.zeros((capacity, dim), dtype),
        sqnorms=jnp.zeros((capacity,), jnp.float32),
        codes=jnp.zeros((capacity, dim), jnp.int8),
        scales=jnp.zeros((capacity,), jnp.float32),
        adj=jnp.full((capacity, d_out), NULL, jnp.int32),
        radj=jnp.full((capacity, d_in), NULL, jnp.int32),
        alive=jnp.zeros((capacity,), bool),
        present=jnp.zeros((capacity,), bool),
        size=jnp.asarray(0, jnp.int32),
        stamps=jnp.full((capacity,), -1, jnp.int32),
        clock=jnp.asarray(0, jnp.int32),
        touch=jnp.full((capacity,), -1, jnp.int32),
        tclock=jnp.asarray(0, jnp.int32),
        capacity=capacity,
        dim=dim,
        d_out=d_out,
        d_in=d_in,
        metric=metric,
    )


# ---------------------------------------------------------------------------
# Capacity growth (DESIGN.md §9) — the shape-family move between tiers.
# ---------------------------------------------------------------------------

def grow_state(state: GraphState, new_capacity: int, *, axis: int = 0) -> GraphState:
    """Pad every per-slot array of ``state`` to ``new_capacity`` slots.

    Existing slots keep their ids and contents byte-exactly; the new slots
    are empty — zero vectors/sqnorms, NULL adjacency rows, not alive, not
    present — so they are immediately visible to the allocator as free and
    invisible to every traversal (I1–I4 hold trivially on exit). ``size`` is
    unchanged. The returned state lives in a new shape family: the next
    dispatch through any shape-specialized jitted step (``apply_ops_step``,
    ``delete_batch``, ...) compiles once for the new tier.

    ``axis`` is the capacity axis — 0 for a local state, 1 for the stacked
    per-shard layout of ``ShardedSession`` (every shard grows in lockstep so
    the stack stays one shape family).
    """
    cap = state.capacity
    if new_capacity < cap:
        raise ValueError(
            f"grow_state cannot shrink: {cap} -> {new_capacity}")
    if new_capacity == cap:
        return state
    extra = new_capacity - cap

    def pad(x: jax.Array, fill) -> jax.Array:
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, extra)
        return jnp.pad(x, pads, constant_values=fill)

    return dataclasses.replace(
        state,
        vectors=pad(state.vectors, 0),
        sqnorms=pad(state.sqnorms, 0.0),
        codes=pad(state.codes, 0),
        scales=pad(state.scales, 0.0),
        adj=pad(state.adj, NULL),
        radj=pad(state.radj, NULL),
        alive=pad(state.alive, False),
        present=pad(state.present, False),
        stamps=pad(state.stamps, -1),
        touch=pad(state.touch, -1),
        capacity=new_capacity,
    )


def next_capacity_tier(
    capacity: int,
    needed: int,
    growth_factor: float,
    max_capacity: int | None,
) -> int:
    """Smallest geometric tier ≥ ``needed`` slots, clipped to ``max_capacity``.

    Tiers are ``capacity · growth_factor^k`` (ceil), so a stream that grows
    monotonically recompiles at most ``ceil(log_factor(final/initial))``
    times regardless of how the demand arrives. Returns the current capacity
    unchanged when it already covers ``needed`` or growth is capped out.
    """
    new = capacity
    while new < needed and (max_capacity is None or new < max_capacity):
        new = max(math.ceil(new * growth_factor), new + 1)
    if max_capacity is not None:
        new = min(new, max_capacity)
    return max(new, capacity)


# ---------------------------------------------------------------------------
# Row-level edge surgery. All helpers are jit-safe (static shapes) and keep
# rows compact-from-the-left is NOT required: rows may have NULL holes; every
# consumer masks on ``entry != NULL``.
# ---------------------------------------------------------------------------

def row_insert(row: jax.Array, value: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Insert ``value`` into the first NULL hole of ``row``.

    Returns (new_row, inserted?). Refuses (inserted=False) when the row is
    full or the value is already there (keeps I1/I4 cheaply).
    """
    already = jnp.any(row == value)
    holes = row == NULL
    has_hole = jnp.any(holes)
    pos = jnp.argmax(holes)  # first hole
    do = has_hole & ~already
    new_row = jnp.where(
        do & (jnp.arange(row.shape[0]) == pos), value, row
    )
    return new_row, do | already  # "already present" counts as success


def row_remove(row: jax.Array, value: jax.Array) -> jax.Array:
    """Remove every occurrence of ``value`` from ``row`` (→ NULL)."""
    return jnp.where(row == value, NULL, row)


def add_edge(state: GraphState, u: jax.Array, v: jax.Array) -> GraphState:
    """Add directed edge u→v, updating radj; refuses if either row is full.

    The refusal is atomic: the edge lands in both adj[u] and radj[v] or in
    neither (invariant I1).
    """
    new_adj_row, ok_a = row_insert(state.adj[u], v)
    new_radj_row, ok_r = row_insert(state.radj[v], u)
    ok = ok_a & ok_r & (u != v) & (u != NULL) & (v != NULL)
    adj = state.adj.at[u].set(jnp.where(ok, new_adj_row, state.adj[u]))
    radj = state.radj.at[v].set(jnp.where(ok, new_radj_row, state.radj[v]))
    return dataclasses.replace(state, adj=adj, radj=radj)


def remove_edge(state: GraphState, u: jax.Array, v: jax.Array) -> GraphState:
    adj = state.adj.at[u].set(row_remove(state.adj[u], v))
    radj = state.radj.at[v].set(row_remove(state.radj[v], u))
    return dataclasses.replace(state, adj=adj, radj=radj)


def set_out_edges(state: GraphState, u: jax.Array, targets: jax.Array) -> GraphState:
    """Replace the full out-neighborhood of ``u`` with ``targets``.

    ``targets`` is i32[d_out], NULL padded. Reverse rows of both the old and
    new targets are fixed up. Edges whose reverse row is full are dropped
    (refused) to keep I1. Implemented as remove-all + loop of add_edge over
    the (small, static) degree — executes inside jit.
    """
    d_out = state.d_out

    def rm_one(i, st):
        old = st.adj[u, i]
        return jax.lax.cond(
            old != NULL, lambda s: remove_edge(s, u, old), lambda s: s, st
        )

    state = jax.lax.fori_loop(0, d_out, rm_one, state)

    def add_one(i, st):
        tgt = targets[i]
        return jax.lax.cond(
            tgt != NULL, lambda s: add_edge(s, u, tgt), lambda s: s, st
        )

    return jax.lax.fori_loop(0, min(d_out, targets.shape[0]), add_one, state)


# ---------------------------------------------------------------------------
# Bulk edge primitives (DESIGN.md §4) — the scatter-based application path of
# the vectorized update engine. Instead of per-edge add/remove chains, callers
# compute whole out-rows, scatter them into ``adj`` in one shot, and have the
# affected reverse rows recomputed from ``adj`` in a single sort/segment pass.
# ---------------------------------------------------------------------------

def rebuild_radj_rows(state: GraphState, touched: jax.Array) -> GraphState:
    """Recompute ``radj[v]`` from ``adj`` for every v in the ``touched`` mask.

    ``touched``: bool[capacity]. One vectorized pass: flatten ``adj`` into
    (src, dst) edge lists, rank each in-edge within its destination by a
    stable sort on dst (rank order == flat ``adj`` order == (src id, slot)
    lexicographic), and scatter the first ``d_in`` per destination into the
    cleared touched rows.

    Bounded in-degree (DESIGN.md §2) becomes deterministic
    **truncation-by-rank** here: in-edges ranked ≥ ``d_in`` are dropped from
    ``adj`` as well, so I1 holds exactly. This replaces the scalar path's
    refuse-the-newcomer rule — under in-degree pressure the two paths keep
    different (equally sized) edge subsets, which the parity suite bounds.

    Untouched rows are byte-identical on exit. Scatter-free: rows are
    *gathered* out of the sorted edge list (XLA scatter serializes per
    update on CPU; segment gathers stay vectorized).
    """
    cap, d_out, d_in = state.capacity, state.d_out, state.d_in
    src = jnp.broadcast_to(
        jnp.arange(cap, dtype=jnp.int32)[:, None], (cap, d_out)
    ).reshape(-1)
    dst = state.adj.reshape(-1)
    E = dst.shape[0]
    ok = (dst != NULL) & touched[jnp.maximum(dst, 0)]
    # stable sort on dst (invalid lanes sink past every real id): the
    # in-edges of v occupy the contiguous segment [start[v], end[v]), in
    # (source id, slot) lexicographic order — the truncation rank order
    key_dst = jnp.where(ok, dst, cap)
    order = jnp.argsort(key_dst, stable=True)
    sorted_key = key_dst[order]
    sorted_src = src[order]
    vids = jnp.arange(cap, dtype=key_dst.dtype)
    start = jnp.searchsorted(sorted_key, vids, side="left")
    end = jnp.searchsorted(sorted_key, vids, side="right")
    # gather the first d_in in-edges of every touched row
    idx = start[:, None] + jnp.arange(d_in)[None, :]
    take = (idx < end[:, None]) & touched[:, None]
    vals = jnp.where(take, sorted_src[jnp.clip(idx, 0, E - 1)], NULL)
    radj = jnp.where(touched[:, None], vals, state.radj)
    # drop forward edges whose reverse overflowed (keeps I1 exact):
    # per-lane rank = sorted position − segment start
    inv = jnp.argsort(order)  # lane → sorted position
    rank = inv - start[jnp.clip(key_dst, 0, cap - 1)]
    drop = ok & (rank >= d_in)
    adj = jnp.where(drop, NULL, dst).reshape(cap, d_out)
    return dataclasses.replace(state, adj=adj, radj=radj)


def apply_row_updates(
    state: GraphState,
    us: jax.Array,        # i32[R]        rows to replace (unique where valid)
    new_rows: jax.Array,  # i32[R, d_out] sanitized new out-rows, NULL padded
    valid: jax.Array,     # bool[R]
) -> GraphState:
    """Incremental scatter-based edge application (the hot-path applier).

    Writes the forward rows with one OOB-dropping scatter and *patches*
    ``radj`` instead of recomputing it: removals are found by testing every
    reverse entry against its (possibly rewritten) source row — pure
    gathers — and additions are grouped by destination with one small sort
    over the R·d_out addition lanes, then slotted into the NULL holes of
    their reverse rows via a cumsum ranking. No sort over the full edge
    table (XLA's O(cap·d_out) sort/scatter is what made the naive rebuild
    CPU-bound).

    Bounded in-degree: existing in-edges keep priority; additions are
    admitted into the remaining holes in deterministic group order and
    **refused** beyond that (the forward entry is dropped too, so I1 holds
    exactly — same semantics family as scalar ``add_edge`` refusal, minus
    the sequential arrival order).

    ``new_rows`` must already be sanitized (no self edges / dups /
    non-present targets) — use ``set_out_edges_batch`` for the checked
    wrapper. Valid ``us`` must be unique.
    """
    cap, d_out, d_in = state.capacity, state.d_out, state.d_in
    R = us.shape[0]
    valid = valid & (us != NULL)
    su = jnp.where(valid, us, 0)
    wsu = jnp.where(valid, us, cap)  # OOB parks invalid lanes (mode="drop")
    old_rows = jnp.where(valid[:, None], state.adj[su], NULL)
    new_rows = jnp.where(valid[:, None], new_rows, NULL)

    # ---- removals: reverse entry (v, i) = u dies iff u's row was rewritten
    # and v is no longer in it (I1 guarantees the entry matched adj before)
    row_of = jnp.full((cap + 1,), -1, jnp.int32).at[wsu].set(
        jnp.arange(R, dtype=jnp.int32), mode="drop"
    )[:cap]
    rv = state.radj
    r_idx = jnp.where(rv != NULL, row_of[jnp.maximum(rv, 0)], -1)
    nr = new_rows[jnp.maximum(r_idx, 0)]          # [cap, d_in, d_out]
    still = jnp.any(nr == jnp.arange(cap)[:, None, None], axis=2)
    radj1 = jnp.where((r_idx >= 0) & ~still, NULL, rv)

    # ---- additions: edges in new_rows but not old_rows, grouped by dest —
    # one sort over R·d_out lanes only
    add_m = (new_rows != NULL) & ~jnp.any(
        new_rows[:, :, None] == old_rows[:, None, :], axis=2
    )
    src = jnp.broadcast_to(su[:, None], (R, d_out)).reshape(-1)
    dst = new_rows.reshape(-1)
    add_flat = add_m.reshape(-1)
    E = dst.shape[0]
    key_dst = jnp.where(add_flat, dst, cap)
    order = jnp.argsort(key_dst, stable=True)
    sorted_key = key_dst[order]
    sorted_src = src[order]
    vids = jnp.arange(cap, dtype=key_dst.dtype)
    start = jnp.searchsorted(sorted_key, vids, side="left")
    end = jnp.searchsorted(sorted_key, vids, side="right")
    idx = start[:, None] + jnp.arange(d_in)[None, :]
    add_rows = jnp.where(
        idx < end[:, None], sorted_src[jnp.clip(idx, 0, E - 1)], NULL
    )                                              # [cap, d_in] rank order

    # admit additions into the holes left after removals; refuse the rest.
    # A lane's group rank is its position in add_rows[v] (sources are unique
    # per destination), so refusal is a compare — no inverse-permutation sort
    holes = d_in - jnp.sum(radj1 != NULL, axis=1)  # [cap]
    ar = add_rows[jnp.clip(new_rows, 0, cap - 1)]  # [R, d_out, d_in]
    match = ar == su[:, None, None]
    past_holes = (
        jnp.arange(d_in)[None, None, :]
        >= holes[jnp.clip(new_rows, 0, cap - 1)][:, :, None]
    )
    # refused: admitted past the holes, or ranked ≥ d_in (never grouped)
    refused = add_m & (
        jnp.any(match & past_holes, axis=2) | ~jnp.any(match, axis=2)
    )
    final_rows = jnp.where(refused, NULL, new_rows)
    adj = state.adj.at[wsu].set(final_rows, mode="drop")

    # fill the holes, in group-rank order (hole h takes addition h; holes
    # are counted by a per-row cumsum, so no per-row sort is needed)
    isnull = radj1 == NULL
    hole_rank = jnp.cumsum(isnull.astype(jnp.int32), axis=1) - 1
    fill = jnp.take_along_axis(
        add_rows, jnp.clip(hole_rank, 0, d_in - 1), axis=1
    )
    radj2 = jnp.where(isnull, fill, radj1)
    # staleness stamps (I7): every rewritten out-row takes the current tclock
    # (OP_REFINE picks the lowest-touch alive slots); one bump per call keeps
    # within-batch ties broken by slot id, deterministically
    touch = state.touch.at[wsu].set(state.tclock, mode="drop")
    return dataclasses.replace(
        state, adj=adj, radj=radj2, touch=touch, tclock=state.tclock + 1
    )


def set_out_edges_batch(
    state: GraphState,
    us: jax.Array,        # i32[R]        rows to replace (unique where valid)
    targets: jax.Array,   # i32[R, d_out] new out-rows, NULL padded
    valid: jax.Array,     # bool[R]       rows to actually apply
) -> GraphState:
    """Replace the out-neighborhoods of all ``us`` rows in one scatter.

    The batched twin of ``set_out_edges``: rows are sanitized (self edges,
    in-row duplicates, non-present targets → NULL) and applied through
    ``apply_row_updates`` (one forward scatter + incremental reverse-row
    patch). Valid rows must be unique — duplicate row ids in one call make
    the scatter order undefined.
    """
    valid = valid & (us != NULL)
    su = jnp.where(valid, us, 0)
    tg = targets[:, : state.d_out]
    if tg.shape[1] < state.d_out:
        pad = jnp.full((tg.shape[0], state.d_out - tg.shape[1]), NULL, jnp.int32)
        tg = jnp.concatenate([tg, pad], axis=1)
    tv = (tg != NULL) & valid[:, None]
    tv = tv & state.present[jnp.where(tv, tg, 0)]
    tg = jnp.where(tv & (tg != su[:, None]), tg, NULL)
    # in-row dedup (keep first occurrence)
    eq = tg[:, :, None] == tg[:, None, :]
    eq = eq & (tg != NULL)[:, :, None]
    first = jnp.argmax(eq, axis=2) == jnp.arange(tg.shape[1])[None, :]
    tg = jnp.where(first, tg, NULL)
    return apply_row_updates(state, us, tg, valid)


def pack_rows(rows: jax.Array) -> jax.Array:
    """Compact non-NULL entries of each row to the left, preserving order."""
    order = jnp.argsort(rows == NULL, axis=1, stable=True)
    return jnp.take_along_axis(rows, order, axis=1)


def group_by_destination(
    src: jax.Array,       # i32[E]  edge sources
    dst: jax.Array,       # i32[E]  edge destinations
    valid: jax.Array,     # bool[E]
    capacity: int,
    max_per_row: int,
) -> tuple[jax.Array, jax.Array]:
    """Scatter an edge list into per-destination rows.

    Returns (rows i32[capacity, max_per_row] NULL padded, touched
    bool[capacity]). Edges ranked ≥ ``max_per_row`` within their destination
    are dropped (rank order = input order, deterministic). The grouping
    engine behind back-link application and LOCAL splice batching.
    Scatter-free (segment gather from the sorted edge list).
    """
    E = dst.shape[0]
    key_dst = jnp.where(valid, dst, capacity)
    order = jnp.argsort(key_dst, stable=True)
    sorted_key = key_dst[order]
    sorted_src = jnp.where(valid, src, NULL)[order]
    vids = jnp.arange(capacity, dtype=key_dst.dtype)
    start = jnp.searchsorted(sorted_key, vids, side="left")
    end = jnp.searchsorted(sorted_key, vids, side="right")
    idx = start[:, None] + jnp.arange(max_per_row)[None, :]
    take = idx < end[:, None]
    rows = jnp.where(take, sorted_src[jnp.clip(idx, 0, E - 1)], NULL)
    return rows.astype(jnp.int32), end > start


# ---------------------------------------------------------------------------
# Whole-graph vectorized edge scrubbing — used by batched deletes. O(cap·deg)
# but a single fused gather/where, no per-edge loop.
# ---------------------------------------------------------------------------

def scrub_edges_to(state: GraphState, dead: jax.Array) -> GraphState:
    """NULL-out every adjacency entry pointing into the ``dead`` mask.

    ``dead``: bool[capacity]. Clears both directions plus the dead rows
    themselves, preserving I1 globally.
    """
    safe_adj = jnp.where(state.adj == NULL, 0, state.adj)
    adj = jnp.where((state.adj != NULL) & dead[safe_adj], NULL, state.adj)
    safe_radj = jnp.where(state.radj == NULL, 0, state.radj)
    radj = jnp.where((state.radj != NULL) & dead[safe_radj], NULL, state.radj)
    # dead rows lose all their edges too
    adj = jnp.where(dead[:, None], NULL, adj)
    radj = jnp.where(dead[:, None], NULL, radj)
    return dataclasses.replace(state, adj=adj, radj=radj)


def free_slots(state: GraphState, ids: jax.Array, valid: jax.Array) -> GraphState:
    """Mark slots fully removed (not present, not alive).

    ``.min`` combine keeps duplicate-index scatters exact: invalid lanes park
    at index 0 writing True, which can never flip a slot.
    """
    safe = jnp.where(valid, ids, 0)
    n_freed = jnp.sum(valid & state.alive[safe])
    alive = state.alive.at[safe].min(~valid)
    present = state.present.at[safe].min(~valid)
    # freed slots scrub their compressed codes (invariant I5); the boolean
    # mask + where is collision-free under duplicate/parked lanes
    freed = jnp.zeros((state.capacity,), bool).at[safe].max(valid)
    return dataclasses.replace(
        state, alive=alive, present=present,
        codes=jnp.where(freed[:, None], 0, state.codes),
        scales=jnp.where(freed, 0.0, state.scales),
        stamps=jnp.where(freed, -1, state.stamps),
        touch=jnp.where(freed, -1, state.touch),
        size=state.size - n_freed.astype(jnp.int32),
    )


def mask_to_slots(mask: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """Compact the lowest ``n`` set positions of ``mask`` into a fixed frame.

    Returns (ids i32[n] NULL padded, valid bool[n]): the ≤ n lowest True
    indices of ``mask`` in ascending order, valid lanes first. The
    fixed-shape bridge from a data-dependent slot set (e.g. the tombstone
    mask consumed by a CONSOLIDATE micro-batch) to a batched op frame —
    jit-safe, one ``top_k`` over negated ids.
    """
    cap = mask.shape[0]
    take = min(n, cap)
    sentinel = jnp.int32(-cap - 1)
    score = jnp.where(mask, -jnp.arange(cap, dtype=jnp.int32), sentinel)
    vals, ids = jax.lax.top_k(score, take)  # largest score = lowest set id
    valid = vals > sentinel
    ids = jnp.where(valid, ids, NULL).astype(jnp.int32)
    if n > cap:
        ids = jnp.concatenate([ids, jnp.full((n - cap,), NULL, jnp.int32)])
        valid = jnp.concatenate([valid, jnp.zeros((n - cap,), bool)])
    return ids, valid


def next_free_slot(state: GraphState) -> jax.Array:
    """First non-present slot (freelist head). capacity if full."""
    return jnp.argmin(state.present)  # False < True; full graph → 0 (caller checks)


def graph_stats(state: GraphState) -> dict[str, jax.Array]:
    out_deg = jnp.sum(state.adj != NULL, axis=1)
    in_deg = jnp.sum(state.radj != NULL, axis=1)
    p = state.present
    return {
        "n_alive": jnp.sum(state.alive),
        "n_present": jnp.sum(p),
        "n_masked": jnp.sum(state.masked),
        "avg_out_degree": jnp.sum(jnp.where(p, out_deg, 0)) / jnp.maximum(jnp.sum(p), 1),
        "avg_in_degree": jnp.sum(jnp.where(p, in_deg, 0)) / jnp.maximum(jnp.sum(p), 1),
        "max_in_degree": jnp.max(jnp.where(p, in_deg, 0)),
    }
