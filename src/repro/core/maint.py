"""Maintenance-op registry — the one place a maintenance op declares itself.

Every background maintenance pass (consolidate §8, grow §9, merge §12,
refine §15) needs the same five pieces of wiring:

  1. a **PRNG key stream** isolated from the op-key chain, so firing the op
     never shifts the keys of logical stream ops (timing invariance);
  2. a **journal record code** for explicit invocations, deduplicated on
     replay by a cseq-style counter snapshot;
  3. a **checkpoint-counter contract**: which host counters are persisted in
     checkpoint extras and restored on ``restore()``/``recover()``;
  4. registered **crash points** for the fault-injection harness
     (``repro.testing.faults`` composes its closed registries from here);
  5. **phase-timer fields** surfaced uniformly in ``Session.stats()`` and
     ``run_workload`` summaries.

Before this module each op hand-rolled all five across session.py, ops.py,
faults.py, journal replay, and the checkpoint extras — adding a fourth op
meant touching every layer again.  Now an op is one :class:`MaintOp` entry;
session/tiered/sharded plumbing and the fault registry iterate the registry
instead of naming ops.

This module is a **leaf**: it imports nothing from the rest of ``repro`` so
that ``repro.testing.faults`` (imported by production modules) can build its
crash-point registry from here without an import cycle.  The numeric
constants below are the single source of truth; ``repro.core.ops``
re-exports them under their historical names, and their values are frozen —
journal files and checkpoints written before this refactor must replay
bit-exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

# --- op codes (static-dispatch-only maintenance ops in the session op IR) ---
# OP_QUERY..OP_NOOP (0..3) live in repro.core.ops; maintenance codes are
# declared here because the registry entries reference them.
OP_CONSOLIDATE = 4
OP_REFINE = 5

# --- journal record codes (JR_META=16 / JR_FLUSH=17 live in ops.py) ---
JR_CONSOLIDATE = 18
JR_GROW = 19
JR_MERGE = 20
JR_REFINE = 21

# --- PRNG key streams (fold_in ids far outside the op-counter range) ---
CONSOLIDATE_KEY_STREAM = 0x7FFFFFFF
MERGE_KEY_STREAM = 0x7FFFFFFE
REFINE_KEY_STREAM = 0x7FFFFFFD


@dataclasses.dataclass(frozen=True)
class MaintOp:
    """Declarative record of one maintenance op's cross-layer obligations.

    ``replay`` is the journal-replay hook: ``replay(session, record) ->
    bool`` returns True when the record was re-executed and False when the
    cseq-style dedup decided the restored checkpoint already subsumes it.
    Auto-triggered passes are never journaled — replaying the surrounding
    JR_FLUSH / stream ops re-derives them deterministically.
    """

    name: str
    tier: str  # "session" | "tiered"
    journal_code: int
    replay: Callable[[Any, Any], bool]
    op_code: int | None = None  # static-dispatch code in the op IR, if any
    key_stream: int | None = None  # fold_in stream id, if the op draws keys
    counter_attr: str | None = None  # host counter attr; snapshot as cseq
    extra_key: str | None = None  # checkpoint-extras key for counter_attr
    # extra (attr, extras-key) pairs persisted/restored alongside the counter
    state_attrs: tuple[tuple[str, str], ...] = ()
    crash_points: tuple[str, ...] = ()
    sharded_crash_points: tuple[str, ...] = ()
    time_field: str | None = None  # PhaseTimers "*_s" field
    count_field: str | None = None  # PhaseTimers "n_*" field


def maint_key(base_key: jax.Array, op: MaintOp, counter: int) -> jax.Array:
    """Key for ``op``'s ``counter``-th draw: isolated from the op-key chain.

    ``fold_in(fold_in(base, stream), counter)`` — the stream id lives at the
    top of the int32 range so maintenance keys can never collide with
    per-op keys (which fold the op counter directly).
    """
    if op.key_stream is None:
        raise ValueError(f"maintenance op {op.name!r} declares no key stream")
    return jax.random.fold_in(jax.random.fold_in(base_key, op.key_stream), counter)


# --- journal replay hooks -------------------------------------------------
# Hooks call public session methods only; dedup mirrors the pre-refactor
# replay logic bit-for-bit (see tests/test_recovery.py's literal-code test).


def _replay_consolidate(sess: Any, rec: Any) -> bool:
    if rec.cseq < sess._consolidate_counter:
        return False  # restored checkpoint already includes this pass
    sess.consolidate(strategy=rec.aux.get("strategy"), chunk=rec.aux.get("chunk"))
    return True


def _replay_grow(sess: Any, rec: Any) -> bool:
    target = int(rec.aux["new_capacity"])
    if target <= sess.state.capacity:
        return False  # restored checkpoint already grown past this
    sess.grow(target)
    return True


def _replay_refine(sess: Any, rec: Any) -> bool:
    if rec.cseq < sess._refine_counter:
        return False
    sess.refine(n=rec.aux.get("n"), chunk=rec.aux.get("chunk"))
    return True


def _replay_merge(sess: Any, rec: Any) -> bool:
    if rec.cseq < sess._merges_done:
        return False
    sess.merge()
    return True


# --- the registry ---------------------------------------------------------

CONSOLIDATE = MaintOp(
    name="consolidate",
    tier="session",
    journal_code=JR_CONSOLIDATE,
    replay=_replay_consolidate,
    op_code=OP_CONSOLIDATE,
    key_stream=CONSOLIDATE_KEY_STREAM,
    counter_attr="_consolidate_counter",
    extra_key="consolidate_counter",
    crash_points=("pre-consolidate", "post-consolidate"),
    sharded_crash_points=("sharded-consolidate-pass",),
    time_field="consolidate_s",
    count_field="n_consolidations",
)

GROW = MaintOp(
    name="grow",
    tier="session",
    journal_code=JR_GROW,
    replay=_replay_grow,
    # no op_code / key_stream: growth is pure pytree padding, draws no keys;
    # no counter: replay dedups on the capacity recorded in the journal aux.
    crash_points=("pre-grow", "post-grow"),
    sharded_crash_points=("sharded-pre-grow", "sharded-post-grow"),
    time_field="grow_s",
    count_field="n_grows",
)

REFINE = MaintOp(
    name="refine",
    tier="session",
    journal_code=JR_REFINE,
    replay=_replay_refine,
    op_code=OP_REFINE,
    key_stream=REFINE_KEY_STREAM,
    counter_attr="_refine_counter",
    extra_key="refine_counter",
    # _refine_wear (update rows dispatched since the last pass) must survive
    # checkpoints so auto-trigger decisions replay deterministically.
    state_attrs=(("_refine_wear", "refine_wear"),),
    crash_points=("refine-begin", "refine-step"),
    time_field="refine_s",
    count_field="n_refines",
)

MERGE = MaintOp(
    name="merge",
    tier="tiered",
    journal_code=JR_MERGE,
    replay=_replay_merge,
    key_stream=MERGE_KEY_STREAM,
    counter_attr="_merges_done",
    extra_key="merges_done",
    crash_points=(
        "merge-begin",
        "merge-compact-step",
        "merge-drain-step",
        "pre-merge-swap",
        "post-merge-swap",
    ),
    time_field="merge_s",
    count_field="n_merges",
)

REGISTRY: tuple[MaintOp, ...] = (CONSOLIDATE, GROW, REFINE, MERGE)
SESSION_OPS: tuple[MaintOp, ...] = tuple(o for o in REGISTRY if o.tier == "session")
TIERED_OPS: tuple[MaintOp, ...] = tuple(o for o in REGISTRY if o.tier == "tiered")

_BY_JOURNAL_CODE = {o.journal_code: o for o in REGISTRY}


def by_journal_code(code: int) -> MaintOp | None:
    """The registered op that journals under ``code``, or None."""
    return _BY_JOURNAL_CODE.get(code)


def crash_points(tier: str) -> tuple[str, ...]:
    """All crash points declared by ``tier``'s ops, in registry order."""
    out: list[str] = []
    for op in REGISTRY:
        if op.tier == tier:
            out.extend(op.crash_points)
    return tuple(out)


def sharded_crash_points() -> tuple[str, ...]:
    """Crash points declared for per-shard variants, in registry order."""
    out: list[str] = []
    for op in REGISTRY:
        out.extend(op.sharded_crash_points)
    return tuple(out)
