"""GRAPH-MAINTENANCE (Alg 3) — the per-op back-compat facade.

The online index's primary surface is the streaming :class:`~repro.core.
session.Session` (DESIGN.md §7): device-resident state, unified op IR,
donated update steps, async dispatch. ``IPGMIndex`` survives as a thin
synchronous facade over a session — each method dispatches one op through
the same jitted ``apply_ops`` step and flushes immediately, preserving the
seed API (eager results, per-op timer attribution, ``query_chunk``-padded
query shapes) for existing call-sites. New code should drive a ``Session``
directly; ``run_workload`` compiles an (op, payload) stream onto either.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable

import numpy as np

from repro.core import metrics
from repro.core.graph import GraphState
from repro.core.params import IndexParams
from repro.core.session import OpHandle, PhaseTimers, Session

__all__ = ["IPGMIndex", "PhaseTimers", "run_workload"]


class IPGMIndex:
    """Online proximity-graph index — thin per-op facade over a Session.

    Back-compat contract kept from the seed API: synchronous methods
    returning materialized results, ``strategy``/chunk-size constructor
    overrides, a settable ``state`` (used by ``consolidate``), and queries
    padded to ``params.query_chunk`` so any request size runs one compiled
    shape. Everything else — dispatch, donation, timers, checkpointing —
    lives in the underlying :class:`Session` (``self.session``).
    """

    def __init__(
        self,
        params: IndexParams,
        *,
        strategy: str | None = None,
        seed: int = 0,
        delete_chunk: int | None = None,
        insert_chunk: int | None = None,
        state: GraphState | None = None,
        checkpoint_dir=None,
    ):
        mp = params.maintenance
        mp = dataclasses.replace(
            mp,
            strategy=strategy if strategy is not None else mp.strategy,
            insert_chunk=insert_chunk if insert_chunk is not None
            else mp.insert_chunk,
            delete_chunk=delete_chunk if delete_chunk is not None
            else mp.delete_chunk,
        )
        params = dataclasses.replace(params, maintenance=mp)
        # per-branch trace-time dispatch: the facade's op type is always
        # known host-side, so it skips the full-switch compile
        self.session = Session(
            params, seed=seed, state=state, checkpoint_dir=checkpoint_dir,
            unified_dispatch=False,
        )

    # -- session passthroughs ---------------------------------------------
    @property
    def params(self) -> IndexParams:
        return self.session.params

    @property
    def strategy(self) -> str:
        return self.session.strategy

    @strategy.setter
    def strategy(self, value: str) -> None:
        self.session.strategy = value

    @property
    def state(self) -> GraphState:
        return self.session.state

    @state.setter
    def state(self, value: GraphState) -> None:
        self.session.set_state(value)

    @property
    def timers(self) -> PhaseTimers:
        return self.session.timers

    def _set_maintenance(self, **kw) -> None:
        p = self.session.params
        self.session.params = dataclasses.replace(
            p, maintenance=dataclasses.replace(p.maintenance, **kw)
        )

    @property
    def insert_chunk(self) -> int:
        return self.session.params.maintenance.insert_chunk

    @insert_chunk.setter
    def insert_chunk(self, value: int) -> None:
        self._set_maintenance(insert_chunk=int(value))

    @property
    def delete_chunk(self) -> int:
        return self.session.params.maintenance.delete_chunk

    @delete_chunk.setter
    def delete_chunk(self, value: int) -> None:
        self._set_maintenance(delete_chunk=int(value))

    # -- operations (Alg 3 branches), each = dispatch + flush --------------
    def query(self, queries, k: int | None = None):
        """Batched ANN query. Returns (ids i32[B,k], scores f32[B,k]).

        Padded to ``query_chunk``-shaped micro-batches (the legacy
        compile-shape contract); results are bit-identical to the streaming
        session's — per-item PRNG folds make query results invariant to
        chunk shape (DESIGN.md §7).
        """
        h = self.session.query(queries, k=k,
                               chunk=self.session.params.query_chunk)
        self.session.flush()
        return h.result()

    def insert(self, vectors):
        """Insert a batch of vectors; returns their assigned ids."""
        h = self.session.insert(vectors)
        self.session.flush()
        return h.result()

    def delete(self, ids) -> None:
        """Delete a batch of vertex ids with the configured strategy."""
        self.session.delete(ids)
        self.session.flush()

    def consolidate(self, *, strategy: str | None = None,
                    chunk: int | None = None) -> int:
        """Physically remove every tombstone (jitted compaction, §8).

        Synchronous like the other facade ops: dispatch + flush. Returns
        the number of consolidated vertices.
        """
        n = self.session.consolidate(strategy=strategy, chunk=chunk)
        self.session.flush()
        return n

    def rebuild_from_alive(self) -> None:
        """ReBuild baseline: reconstruct the whole graph from alive vectors."""
        self.session.rebuild_from_alive()

    # -- reporting ---------------------------------------------------------
    def ground_truth(self, queries, k: int):
        return self.session.ground_truth(queries, k)

    def recall(self, queries, k: int) -> float:
        return self.session.recall(queries, k)

    def stats(self) -> dict:
        return self.session.stats()


def run_workload(
    index: IPGMIndex | Session,
    workload: Iterable[tuple[str, object]],
    k: int = 10,
) -> list[dict]:
    """Drive an (op, payload) stream — Alg 3's outer loop as a stream compiler.

    ops: ("query", Q[B,dim]) | ("insert", X[B,dim]) | ("delete", ids[B])
       | ("rebuild", None) | ("consolidate", None)

    Given a :class:`Session`, the whole stream is dispatched up front
    (async, op-IR micro-batches) and results are consumed in order —
    host-side bookkeeping overlaps device execution, and a final
    ``{"op": "summary"}`` record carries ``session.timers.to_dict()``.
    Given an :class:`IPGMIndex`, ops run synchronously one at a time (the
    legacy per-op path, no summary record — kept for facade parity runs).

    Every record reports ``seconds``, ``n`` and ``ops_per_s``; query records
    add ``recall`` plus the ground-truth pass cost as ``gt_seconds``
    (excluded from ``seconds`` — QPS measures the index alone).
    """
    if isinstance(index, Session):
        return _run_workload_stream(index, workload, k)
    records = []
    for op, payload in workload:
        t0 = time.perf_counter()
        rec: dict = {"op": op}
        if op == "query":
            ids, _ = index.query(payload, k=k)
            rec["seconds"] = time.perf_counter() - t0
            rec["n"] = int(np.asarray(payload).shape[0])
            t_gt = time.perf_counter()
            _, true_ids = index.ground_truth(payload, k)
            rec["recall"] = float(metrics.recall_at_k(
                np.asarray(ids), true_ids, k))
            rec["gt_seconds"] = time.perf_counter() - t_gt
        elif op == "insert":
            index.insert(payload)
            rec["n"] = int(np.asarray(payload).shape[0])
        elif op == "delete":
            index.delete(payload)
            rec["n"] = int(np.asarray(payload).shape[0])
        elif op == "rebuild":
            index.rebuild_from_alive()
            rec["n"] = 1
        elif op == "consolidate":
            rec["n"] = index.consolidate()
        else:
            raise ValueError(op)
        if "seconds" not in rec:
            rec["seconds"] = time.perf_counter() - t0
        rec["ops_per_s"] = rec["n"] / rec["seconds"] if rec["seconds"] else 0.0
        records.append(rec)
    return records


def _run_workload_stream(
    session: Session, workload: Iterable[tuple[str, object]], k: int
) -> list[dict]:
    """Streaming driver: dispatch everything, then consume in order.

    Per-record ``seconds``/``ops_per_s`` measure *consume-side wait*: the
    first consumed record absorbs the whole device queue built up behind
    it, later records resolve nearly instantly. Per-op isolation is the
    legacy facade mode's job; stream-level throughput lives in the
    ``summary`` record.

    Ground truth for a query's recall is dispatched (async, no flush)
    right after the query op, against the session state *at that stream
    position* — a later update must not change what counts as a correct
    answer. The runtime keeps the snapshot's buffers alive across the
    subsequent donating update steps.
    """
    import jax.numpy as jnp

    t_start = time.perf_counter()
    staged: list[tuple[dict, OpHandle | None, object]] = []
    for op, payload in workload:
        rec: dict = {"op": op}
        gt = None
        if op == "query":
            h = session.query(payload, k=k)
            gt = metrics.brute_force_topk(
                session.state, jnp.asarray(payload), k
            )
            rec["n"] = int(np.asarray(payload).shape[0])
        elif op == "insert":
            h = session.insert(payload)
            rec["n"] = int(np.asarray(payload).shape[0])
        elif op == "delete":
            h = session.delete(payload)
            rec["n"] = int(np.asarray(payload).shape[0])
        elif op == "rebuild":
            t0 = time.perf_counter()
            session.rebuild_from_alive()  # host path — synchronizes
            rec["seconds"] = time.perf_counter() - t0
            h, rec["n"] = None, 1
        elif op == "consolidate":
            # syncs on the dispatched stream (exact tombstone count), then
            # dispatches the compaction micro-batches asynchronously
            t0 = time.perf_counter()
            rec["n"] = session.consolidate()
            rec["seconds"] = time.perf_counter() - t0
            h = None
        else:
            raise ValueError(op)
        staged.append((rec, h, gt))

    records = []
    for rec, h, gt in staged:
        t0 = time.perf_counter()
        if h is not None and rec["op"] == "query":
            ids, _ = h.result()
            rec["seconds"] = time.perf_counter() - t0
            t_gt = time.perf_counter()
            _, true_ids = gt
            rec["recall"] = float(metrics.recall_at_k(
                np.asarray(ids), np.asarray(true_ids), k))
            rec["gt_seconds"] = time.perf_counter() - t_gt
        elif h is not None:
            h.result()
            rec["seconds"] = time.perf_counter() - t0
        # (rebuild records carry their true synchronous dispatch-time cost)
        rec["ops_per_s"] = rec["n"] / rec["seconds"] if rec["seconds"] else 0.0
        records.append(rec)
    timers = session.flush()
    total = time.perf_counter() - t_start
    n_items = sum(r["n"] for r in records
                  if r["op"] not in ("rebuild", "consolidate"))
    records.append({
        "op": "summary",
        "n": n_items,
        "seconds": total,
        "ops_per_s": n_items / total if total else 0.0,
        "timers": timers.to_dict(),
    })
    return records
