"""GRAPH-MAINTENANCE (Alg 3) — the public online-index API.

``IPGMIndex`` is the host-level driver: it owns a jitted GraphState, chunks
workload operations into device-sized micro-batches, dispatches the delete
strategy, and keeps per-phase timing books (the paper's QPS / total-time
accounting). Everything device-side is functional and jit-compiled once per
(shape, params) combination.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delete as delete_mod
from repro.core import insert as insert_mod
from repro.core import metrics, rebuild, search
from repro.core.graph import NULL, GraphState, graph_stats, init_graph
from repro.core.params import IndexParams


@dataclasses.dataclass
class PhaseTimers:
    query_s: float = 0.0
    insert_s: float = 0.0
    delete_s: float = 0.0
    rebuild_s: float = 0.0
    n_queries: int = 0
    n_inserts: int = 0
    n_deletes: int = 0

    def total(self) -> float:
        return self.query_s + self.insert_s + self.delete_s + self.rebuild_s


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] == n:
        return x
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


class IPGMIndex:
    """Online proximity-graph index with pluggable delete strategy."""

    def __init__(
        self,
        params: IndexParams,
        *,
        strategy: str = "global",
        seed: int = 0,
        delete_chunk: int = 64,
        insert_chunk: int = 64,
        state: GraphState | None = None,
    ):
        known = delete_mod.STRATEGIES + delete_mod.REFERENCE_STRATEGIES
        if strategy not in known:
            raise ValueError(f"strategy must be one of {known}")
        self.params = params
        self.strategy = strategy
        self.delete_chunk = delete_chunk
        self.insert_chunk = insert_chunk
        self._key = jax.random.PRNGKey(seed)
        self.state = state if state is not None else init_graph(
            params.capacity, params.dim, d_out=params.d_out,
            d_in=params.eff_d_in, metric=params.metric,
        )
        self.timers = PhaseTimers()

    # -- key plumbing ------------------------------------------------------
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- operations (Alg 3 branches) --------------------------------------
    def query(self, queries, k: int | None = None):
        """Batched ANN query. Returns (ids i32[B,k], scores f32[B,k]).

        Each ``query_chunk``-sized micro-batch is one batched beam-engine
        call (``search.beam_search`` under ``search_batch``) — chunking
        bounds device intermediates. A ragged final chunk is padded up to
        ``query_chunk`` and the pad rows masked off, so *every* chunk runs
        the single compiled program for this (state, params) combination —
        no per-remainder-shape recompiles.
        """
        q = jnp.asarray(queries)
        chunk = self.params.query_chunk
        k = k if k is not None else self.params.search.pool_size
        ids_out, scores_out = [], []
        t0 = time.perf_counter()
        for lo in range(0, q.shape[0], chunk):
            part = q[lo:lo + chunk]
            n = part.shape[0]
            if n < chunk:
                part = jnp.concatenate(
                    [part, jnp.zeros((chunk - n, q.shape[1]), q.dtype)]
                )
            res = search.search_batch(
                self.state, part, self._next_key(), self.params.search
            )
            ids_out.append(res.ids[:n, :k])
            scores_out.append(res.scores[:n, :k])
        ids = jnp.concatenate(ids_out) if len(ids_out) > 1 else ids_out[0]
        scores = (
            jnp.concatenate(scores_out) if len(scores_out) > 1 else scores_out[0]
        )
        ids.block_until_ready()
        self.timers.query_s += time.perf_counter() - t0
        self.timers.n_queries += int(q.shape[0])
        return ids, scores

    def insert(self, vectors) -> jax.Array:
        """Insert a batch of vectors; returns their assigned ids.

        Chunked into ``insert_chunk``-sized micro-batches, each one call of
        the vectorized insert pipeline (``insert_mod.insert_batch``,
        DESIGN.md §4). The ragged final chunk is padded to ``insert_chunk``
        with masked lanes, so every chunk reuses the one compiled program.
        """
        v = np.asarray(vectors)
        if v.shape[0] == 0:
            return jnp.zeros((0,), jnp.int32)
        chunk = self.insert_chunk
        t0 = time.perf_counter()
        out = []
        for lo in range(0, v.shape[0], chunk):
            part = v[lo:lo + chunk]
            n = part.shape[0]
            padded = _pad_to(part, chunk, 0)
            valid = jnp.arange(chunk) < n
            self.state, ids = insert_mod.insert_batch(
                self.state, jnp.asarray(padded), valid, self._next_key(),
                self.params,
            )
            out.append(ids[:n])
        ids = jnp.concatenate(out) if len(out) > 1 else out[0]
        ids.block_until_ready()
        self.timers.insert_s += time.perf_counter() - t0
        self.timers.n_inserts += int(v.shape[0])
        return ids

    def delete(self, ids) -> None:
        """Delete a batch of vertex ids with the configured strategy."""
        arr = np.asarray(ids, dtype=np.int32)
        chunk = self.delete_chunk
        t0 = time.perf_counter()
        for lo in range(0, arr.shape[0], chunk):
            part = arr[lo:lo + chunk]
            n = part.shape[0]
            padded = _pad_to(part, chunk, NULL)
            valid = jnp.arange(chunk) < n
            self.state = delete_mod.delete_batch(
                self.state, jnp.asarray(padded), valid, self._next_key(),
                self.strategy, self.params,
            )
        jax.block_until_ready(self.state.adj)
        self.timers.delete_s += time.perf_counter() - t0
        self.timers.n_deletes += int(arr.shape[0])

    def rebuild_from_alive(self) -> None:
        """ReBuild baseline: reconstruct the whole graph from alive vectors."""
        t0 = time.perf_counter()
        alive = np.asarray(self.state.alive)
        vecs = np.asarray(self.state.vectors)[alive]
        n = vecs.shape[0]
        padded = np.zeros((self.params.capacity, self.params.dim), vecs.dtype)
        padded[:n] = vecs
        valid = jnp.arange(self.params.capacity) < n
        self.state = rebuild.bulk_knn_build(
            jnp.asarray(padded), valid, self.params
        )
        jax.block_until_ready(self.state.adj)
        self.timers.rebuild_s += time.perf_counter() - t0

    # -- reporting ---------------------------------------------------------
    def ground_truth(self, queries, k: int):
        return metrics.brute_force_topk(self.state, jnp.asarray(queries), k)

    def recall(self, queries, k: int) -> float:
        ids, _ = self.query(queries, k=k)
        _, true_ids = self.ground_truth(queries, k)
        return float(metrics.recall_at_k(ids, true_ids, k))

    def stats(self) -> dict:
        return {k: np.asarray(v).item() for k, v in graph_stats(self.state).items()}


def run_workload(
    index: IPGMIndex,
    workload: Iterable[tuple[str, object]],
    k: int = 10,
) -> list[dict]:
    """Drive a (op, payload) stream through the index — Alg 3's outer loop.

    ops: ("query", Q[B,dim]) | ("insert", X[B,dim]) | ("delete", ids[B])
       | ("rebuild", None)
    Returns one record per op with latency + (for queries) recall. The
    brute-force ground-truth pass backing the recall number is *not* part
    of the serving path, so its cost is reported as a separate
    ``gt_seconds`` field and excluded from ``seconds`` (QPS derived from
    ``seconds`` measures the index alone).
    """
    records = []
    for op, payload in workload:
        t0 = time.perf_counter()
        rec: dict = {"op": op}
        if op == "query":
            ids, _ = index.query(payload, k=k)
            jax.block_until_ready(ids)
            rec["seconds"] = time.perf_counter() - t0
            rec["n"] = int(np.asarray(payload).shape[0])
            t_gt = time.perf_counter()
            _, true_ids = index.ground_truth(payload, k)
            rec["recall"] = float(metrics.recall_at_k(ids, true_ids, k))
            rec["gt_seconds"] = time.perf_counter() - t_gt
        elif op == "insert":
            index.insert(payload)
            rec["n"] = int(np.asarray(payload).shape[0])
        elif op == "delete":
            index.delete(payload)
            rec["n"] = int(np.asarray(payload).shape[0])
        elif op == "rebuild":
            index.rebuild_from_alive()
            rec["n"] = 1
        else:
            raise ValueError(op)
        if "seconds" not in rec:
            rec["seconds"] = time.perf_counter() - t0
        records.append(rec)
    return records
