"""SELECT-NEIGHBORS (Alg 2) — diversity edge-selection heuristic, batched.

The rule (Malkov et al. 2014 / HNSW "heuristic"): scan candidates in order of
proximity to ``x``; keep ``y`` iff ``x`` is at least as close to ``y`` as any
already-selected neighbor ``z`` is (``||x-y|| <= min_z ||z-y||``, Alg 2 line
6; the standard ip-NSW generalization replaces distances with the similarity
``f``).

Metric care: the dominance test compares f(y, x) with f(y, z) — both must be
scored with *y in the query role* so the per-candidate norm offsets cancel
(for L2 scores ``2<a,b> - ||b||^2`` the offset is ``+||y||^2`` on both sides).
The candidate *ordering* instead puts x in the query role. Getting this wrong
silently breaks diversity selection for L2; the unit tests pin both.

TPU shape: candidates are a fixed-size pool (≤ pool_size), so the pairwise
candidate score matrix is a tiny fp32 matmul and the greedy scan is a
``fori_loop`` carrying a selection mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import distances
from repro.core.graph import NULL

NEG_INF = distances.NEG_INF


def select_neighbors(
    x_vec: jax.Array,       # f32[dim]   the vertex being (re)connected
    cand_ids: jax.Array,    # i32[n]     candidate ids (NULL padded)
    cand_vecs: jax.Array,   # f32[n,dim] gathered candidate vectors
    cand_valid: jax.Array,  # bool[n]    validity incl. the invalid set I
    d: int,                 # out-degree threshold
    metric: str,
    keep_pruned: bool = False,  # HNSW keepPrunedConnections: fill to d with
                                # the nearest dominated candidates
) -> jax.Array:
    """Returns i32[d] selected ids, NULL padded, proximity-descending."""
    n = cand_ids.shape[0]
    x32 = x_vec.astype(jnp.float32)
    v32 = cand_vecs.astype(jnp.float32)
    dots = v32 @ x32  # [n]

    if metric == "l2":
        order_key = 2.0 * dots - distances.sqnorm(v32)   # x as query
        chk_to_x = 2.0 * dots - distances.sqnorm(x32)    # y as query
    else:  # ip / cos
        order_key = dots
        chk_to_x = dots

    order_key = jnp.where(cand_valid, order_key, NEG_INF)
    okey_o, order = jax.lax.top_k(order_key, n)
    ids_o = jnp.where(okey_o > NEG_INF, cand_ids[order], NULL)
    vecs_o = v32[order]
    chk_o = chk_to_x[order]
    valid_o = ids_o != NULL

    # pair[i, j] = f(y_i as query, y_j) — same query role as chk_o[i]
    pair = distances.score_matrix(
        vecs_o, distances.sqnorm(vecs_o), vecs_o, metric
    )  # [n, n]

    def body(i, carry):
        selected, count = carry
        # y_i survives iff  f(y_i, x) >= f(y_i, z)  for every selected z
        dominated = jnp.any(selected & (pair[i] > chk_o[i]))
        take = valid_o[i] & ~dominated & (count < d)
        selected = selected.at[i].set(take)
        return selected, count + take.astype(jnp.int32)

    selected, n_sel = jax.lax.fori_loop(
        0, n, body, (jnp.zeros((n,), bool), jnp.asarray(0, jnp.int32))
    )

    # compact: first d selected (already proximity-ordered)
    rank = jnp.where(selected, okey_o, NEG_INF)
    top_scores, idx = jax.lax.top_k(rank, min(d, n))
    out = jnp.where(top_scores > NEG_INF, ids_o[idx], NULL)

    if keep_pruned:
        # fill remaining slots with the closest dominated candidates
        rank2 = jnp.where(valid_o & ~selected, okey_o, NEG_INF)
        fs, fi = jax.lax.top_k(rank2, min(d, n))
        fill = jnp.where(fs > NEG_INF, ids_o[fi], NULL)
        pos = jnp.arange(min(d, n))
        take_fill = jnp.clip(pos - n_sel, 0, min(d, n) - 1)
        out = jnp.where(pos < n_sel, out, fill[take_fill])

    if d > n:
        out = jnp.concatenate([out, jnp.full((d - n,), NULL, jnp.int32)])
    return out.astype(jnp.int32)


def select_from_pool(
    state,                 # GraphState
    x_vec: jax.Array,      # f32[dim]
    cand_ids: jax.Array,   # i32[n]
    d: int,
    exclude: jax.Array | None = None,  # i32[m] ids to exclude (invalid set I)
    require_alive: bool = True,
    keep_pruned: bool = True,  # system default (HNSW practice); the
                               # strict-paper heuristic is keep_pruned=False
) -> jax.Array:
    """Gather + validate a candidate pool from the graph, then select."""
    valid = cand_ids != NULL
    safe = jnp.where(valid, cand_ids, 0)
    if require_alive:
        valid = valid & state.alive[safe]
    else:
        valid = valid & state.present[safe]
    if exclude is not None:
        valid = valid & ~jnp.any(cand_ids[:, None] == exclude[None, :], axis=1)
    # dedupe within the pool (keep first occurrence)
    eq = cand_ids[:, None] == cand_ids[None, :]
    first = jnp.argmax(eq, axis=1) == jnp.arange(cand_ids.shape[0])
    valid = valid & first
    vecs = state.vectors[safe]
    return select_neighbors(x_vec, cand_ids, vecs, valid, d, state.metric,
                            keep_pruned=keep_pruned)
