"""Static hyper-parameters of the index (paper's k, d, plus TPU knobs)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Parameters of GREEDY-SEARCH (Alg 1) and the TPU execution model."""

    pool_size: int = 32      # paper's k: candidate priority-queue length (ef)
    max_steps: int = 96      # hard cap on while_loop trips (TPU bound); with
                             # beam_width=W each trip expands ≤ W entries
    num_starts: int = 2      # random entry points seeding the pool
    beam_width: int = 1      # W: unexpanded pool entries expanded per query
                             # per step ([B, W·d_out] candidate block);
                             # W=1 reproduces the classic best-first walk
    use_pallas: bool | None = None  # score the candidate block through the
                                    # fused Pallas gather kernel
                                    # (kernels.ops.gather_scores);
                                    # None → auto (on for TPU backends)
    quantized: bool = False  # walk the beam on int8 codes (asymmetric
                             # distance, DESIGN.md §10); fp32 rows are then
                             # only touched by the exact re-rank below.
                             # False (default) = the exact fp32 engine,
                             # which stays the parity oracle.
    rerank_depth: int = 0    # with quantized=True: exact fp32 re-rank of
                             # the top-r pool entries; the final top-k is
                             # reported from those r candidates ONLY, so
                             # keep r ≥ the k you consume. 0 = report
                             # compressed scores directly (no exact pass).

    def __post_init__(self):
        assert self.pool_size >= 1 and self.max_steps >= 1
        assert 1 <= self.num_starts <= self.pool_size
        assert 1 <= self.beam_width <= self.pool_size
        assert 0 <= self.rerank_depth <= self.pool_size


@dataclasses.dataclass(frozen=True)
class MaintenanceParams:
    """Update-path knobs of the online index (DESIGN.md §7/§8).

    ``strategy`` is the delete strategy (Alg 4–6 / §5.2); the chunk sizes are
    the op-IR micro-batch widths: every insert/delete stream is chopped into
    fixed-shape ``OpBatch``es of this many lanes (ragged tails padded with
    masked lanes), so one compiled ``apply_ops`` program serves any stream
    length. Keeping ``insert_chunk == delete_chunk`` lets a mixed stream run
    through a single compiled switch program (one shape family).

    Consolidation (DESIGN.md §8) is what makes MASK's tombstones sustainable
    on an unbounded stream: ``consolidate_threshold`` arms the session's
    auto-trigger (fires when masked/present crosses it; ``None`` disables),
    ``consolidate_strategy`` picks the repair used by the jitted compaction
    pass ("pure" = scrub only, "local"/"global" = Alg 5/6 repair of the
    survivors' rows, "rwalk" = random-walk replacement wiring), and
    ``consolidate_chunk`` is the tombstones-per-
    micro-batch width (``None`` → ``delete_chunk``, keeping the stream in
    one compiled shape family).

    Capacity growth (DESIGN.md §9) is what makes *net-growing* streams
    sustainable: ``max_capacity`` arms the session's auto-grow gate at
    insert-dispatch boundaries (``None`` keeps the legacy fixed-capacity
    contract — a full index refuses further inserts, now counted in
    ``PhaseTimers.n_refused``), and ``growth_factor`` is the geometric tier
    step (default ×2), so growing from capacity C to C' recompiles the op
    step at most ``ceil(log_factor(C'/C))`` times.
    """

    strategy: str = "global"   # "pure" | "mask" | "local" | "global" |
                               # "rwalk" (+ _reference)
    insert_chunk: int = 64
    delete_chunk: int = 64
    consolidate_threshold: float | None = None  # masked/present auto-trigger
    consolidate_strategy: str = "global"  # "pure"|"local"|"global"|"rwalk"
    consolidate_chunk: int | None = None        # None → delete_chunk
    # RWALK repair budget (core/delete.py): each surviving in-neighbor of a
    # deleted vertex runs a short beam-engine walk (beam_width=1, ``rwalk_
    # steps`` loop trips, ``rwalk_pool``-entry pool) seeded at ``rwalk_
    # starts`` random members of the deleted vertex's out-neighborhood and
    # splices ONE replacement edge from the walk pool. The defaults keep the
    # walk an order of magnitude cheaper than a GLOBAL re-search.
    rwalk_steps: int = 8
    rwalk_starts: int = 4
    rwalk_pool: int = 8
    growth_factor: float = 2.0                  # geometric capacity tier step
    max_capacity: int | None = None             # auto-grow ceiling; None = fixed
    # streaming-merge trigger gate (TieredSession, DESIGN.md §12): a merge
    # starts when the fresh tier's alive count crosses
    # ``merge_fresh_threshold`` × fresh capacity, or the main tier's
    # tombstone count crosses ``merge_tombstone_threshold`` × present count.
    # ``None`` disables that arm of the gate; ``merge_chunk`` is the items-
    # per-step drain/compact width (None → insert_chunk — one shape family).
    merge_fresh_threshold: float | None = None
    merge_tombstone_threshold: float | None = None
    merge_chunk: int | None = None
    # background refinement trigger gate (OP_REFINE, DESIGN.md §15): a
    # refine pass fires opportunistically at flush() boundaries once
    # ``refine_threshold`` update rows (insert + delete lanes) have been
    # dispatched since the last pass — "wear" is a pure function of the op
    # stream, so replay re-derives auto passes deterministically. ``None``
    # disables. ``refine_chunk`` is the slots-per-micro-batch width of one
    # pass (None → insert_chunk — one shape family with the stream).
    refine_threshold: int | None = None
    refine_chunk: int | None = None

    def __post_init__(self):
        assert self.insert_chunk >= 1 and self.delete_chunk >= 1
        assert self.consolidate_strategy in ("pure", "local", "global", "rwalk")
        assert self.rwalk_steps >= 1 and self.rwalk_starts >= 1
        assert self.rwalk_pool >= self.rwalk_starts
        assert (self.consolidate_threshold is None
                or 0.0 < self.consolidate_threshold <= 1.0)
        assert self.consolidate_chunk is None or self.consolidate_chunk >= 1
        assert self.growth_factor > 1.0
        assert self.max_capacity is None or self.max_capacity >= 1
        assert (self.merge_fresh_threshold is None
                or 0.0 < self.merge_fresh_threshold <= 1.0)
        assert (self.merge_tombstone_threshold is None
                or 0.0 < self.merge_tombstone_threshold <= 1.0)
        assert self.merge_chunk is None or self.merge_chunk >= 1
        assert self.refine_threshold is None or self.refine_threshold >= 1
        assert self.refine_chunk is None or self.refine_chunk >= 1


@dataclasses.dataclass(frozen=True)
class IndexParams:
    """Full index configuration (graph + search + maintenance).

    ``capacity`` is the *initial* capacity tier; with
    ``maintenance.max_capacity`` armed the live state may grow past it
    (DESIGN.md §9 — read the live tier off ``state.capacity``).
    """

    capacity: int
    dim: int
    d_out: int = 16            # paper's d: out-degree threshold
    d_in: int | None = None    # bounded in-degree (DESIGN.md §2); None → 2*d_out
    metric: str = "l2"
    search: SearchParams = dataclasses.field(default_factory=SearchParams)
    insert_search: SearchParams | None = None  # ef_construction; None → search
    bidirectional_insert: bool = True  # NSW/HNSW practice; strict-paper = False
    query_chunk: int = 256     # queries per batched-engine call on the
                               # legacy per-op facade (bounds the
                               # [chunk, pool+block] working set & compile
                               # shapes); streaming sessions chunk queries at
                               # the op-IR width instead (DESIGN.md §7)
    maintenance: MaintenanceParams = dataclasses.field(
        default_factory=MaintenanceParams
    )

    def __post_init__(self):
        # the growth ceiling must cover the initial tier: a ceiling below it
        # would also corrupt the sharded gid encoding, which strides global
        # ids by max_capacity when growth is armed (DESIGN.md §9)
        mc = self.maintenance.max_capacity
        assert mc is None or mc >= self.capacity, (
            f"maintenance.max_capacity ({mc}) must be >= the initial "
            f"capacity ({self.capacity})")

    @property
    def eff_d_in(self) -> int:
        if self.d_in is not None:
            return self.d_in
        # MIPS concentrates in-edges on large-norm hubs (the ip-NSW hub
        # problem) — give inner-product graphs more reverse headroom
        return (4 if self.metric in ("ip", "cos") else 2) * self.d_out

    @property
    def eff_insert_search(self) -> SearchParams:
        return self.insert_search if self.insert_search is not None else self.search
