"""OP_REFINE — continuous background refinement (DESIGN.md §15).

Graphs built incrementally under churn drift away from fresh-build quality:
early vertices selected their neighbors on a much smaller graph, and delete
repair only patches the rows adjacent to each deletion. The Dynamic
Exploration Graph (Hezel et al., PAPERS.md) shows that spending idle cycles
re-running neighbor selection on *stale* vertices recovers fresh-build
quality without downtime.

:func:`refine_chunk_impl` is the device pass: given a chunk of slots, it
re-searches each slot's own vector through the batched beam engine at
construction quality (``IndexParams.eff_insert_search`` — the same budget an
insert gets), re-runs SELECT-NEIGHBORS over the search pool *unioned with
the slot's current out-row* (good existing edges stay eligible), and
scatter-applies the winning rows through ``set_out_edges_batch``. Staleness
is the per-slot ``touch`` stamp maintained by ``graph.apply_row_updates``
(invariant I7): :func:`stalest_slots` picks the B lowest-touch alive slots,
and refining a slot bumps its stamp, so successive chunks sweep the graph
oldest-rows-first without any host bookkeeping.

Refinement never changes the alive/present sets, ``size``, vectors, codes,
or stamps — it rewires edges only. Its PRNG keys come from the dedicated
``REFINE_KEY_STREAM`` chain (registered in ``core/maint.py``), so firing a
refine pass never shifts the op-key chain of the logical stream (the same
timing-invariance contract as consolidate/merge).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import search, select
from repro.core.graph import NULL, GraphState, set_out_edges_batch
from repro.core.params import IndexParams


def stalest_slots(state: GraphState, n: int) -> tuple[jax.Array, jax.Array]:
    """The ≤ n stalest alive slots, in a fixed-shape frame.

    Returns (ids i32[n] NULL padded, valid bool[n]): alive slots ordered by
    ascending ``touch`` stamp, ties broken by lowest id (a -1 stamp — rows
    never written through the batched appliers — is maximally stale). The
    refine twin of ``graph.mask_to_slots``: one stable argsort over the
    capacity-sized stamp vector bridges the data-dependent "stalest" set
    into a jit-safe op frame.
    """
    cap = state.capacity
    take = min(n, cap)
    stale_key = jnp.where(state.alive, state.touch, jnp.int32(2**31 - 1))
    order = jnp.argsort(stale_key, stable=True).astype(jnp.int32)
    ids = order[:take]
    valid = state.alive[ids]
    ids = jnp.where(valid, ids, NULL)
    if n > cap:
        ids = jnp.concatenate([ids, jnp.full((n - cap,), NULL, jnp.int32)])
        valid = jnp.concatenate([valid, jnp.zeros((n - cap,), bool)])
    return ids, valid


def refine_chunk_impl(
    state: GraphState,
    ids: jax.Array,       # i32[B]  slots to refine (NULL padded)
    valid: jax.Array,     # bool[B]
    key: jax.Array,
    params: IndexParams,
) -> tuple[GraphState, jax.Array]:
    """Traceable refinement of one chunk of slots (the §15 device pass).

    Lanes that are not alive are dropped, so the step is idempotent and safe
    against stale frames. Phases (all batched, no per-item loops):

      1. search — ONE ``beam_search`` call over the chunk's own vectors at
         construction quality (``eff_insert_search``), exactly the budget an
         insert of the same vector would get today.
      2. select — vmapped SELECT-NEIGHBORS over (search pool ∪ current
         out-row): the current neighbors compete with the fresh candidates,
         so a refine can only keep or improve each edge under the pruning
         rule; dead/masked neighbors lose their seat to alive ones.
      3. apply — winning rows land in one ``set_out_edges_batch`` call
         (single forward scatter + incremental reverse patch), which also
         bumps the refined rows' ``touch`` stamps — the staleness sweep
         advances on-device.

    Returns (state, n_refined i32[]).
    """
    sp = params.eff_insert_search
    valid = valid & (ids != NULL)
    safe = jnp.where(valid, ids, 0)
    valid = valid & state.alive[safe]
    B = ids.shape[0]

    vecs = state.vectors[safe]
    starts = search.batch_entry_points(state, key, B, sp.num_starts)
    res = search.beam_search(state, vecs, starts, sp)

    cands = jnp.concatenate([res.ids, state.adj[safe]], axis=1)  # [B, K+d_out]
    new_rows = jax.vmap(
        lambda u, v, c: select.select_from_pool(
            state, v, c, params.d_out, exclude=u[None]
        )
    )(safe, vecs, cands)
    new_rows = jnp.where(valid[:, None], new_rows, NULL)

    state = set_out_edges_batch(state, ids, new_rows, valid)
    return state, jnp.sum(valid).astype(jnp.int32)
