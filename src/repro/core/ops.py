"""Unified op IR — the fixed-shape micro-batch language of the session API.

A mixed online stream (queries, inserts, deletes interleaved — the paper's
Alg 3 setting) is compiled into a sequence of :class:`OpBatch` micro-batches:
one fixed shape regardless of op type, so the whole stream dispatches through
ONE jitted step (:func:`apply_ops`) whose ``lax.switch`` selects the branch
on-device. The step takes the ``GraphState`` donated (``donate_argnums``), so
updates mutate the capacity-sized buffers in place instead of copying the
index every micro-batch, and queries alias it straight through
(DESIGN.md §7).

Op codes::

    OP_QUERY       (0)  payload = queries f32[B, dim]  → ids/scores [B, K]
    OP_INSERT      (1)  payload = vectors f32[B, dim]  → assigned ids in ids[:, 0]
    OP_DELETE      (2)  ids     = vertex ids i32[B]    → state change only
    OP_NOOP        (3)  padding op — state unchanged, empty results
    OP_CONSOLIDATE (4)  no operands — compacts up to B tombstones (the
                        lowest-id masked slots at this stream position,
                        DESIGN.md §8); consolidated ids ride in ids[:, 0].
    OP_REFINE      (5)  no operands — re-wires up to B of the stalest alive
                        slots at construction quality (DESIGN.md §15);
                        refined ids ride in ids[:, 0].

    The maintenance codes (OP_CONSOLIDATE, OP_REFINE — declared in
    ``core/maint.py``) are static-dispatch only: maintenance passes are
    always host-initiated (never a data-dependent stream op), so they are
    excluded from the traced switch — mixed-stream programs stay at four
    branches and sessions that never consolidate/refine never compile the
    repair machinery.

``valid`` masks the padded lanes of a ragged final micro-batch; ``offset``
is the micro-batch's global item offset within its op, which keys the
per-lane PRNG folds so results are invariant to chunking/padding
(``search.batch_entry_points``).

Dispatch modes:

  · ``static_op=None`` — ``op_code`` is traced and the branch is selected by
    ``lax.switch``: one compiled program executes ANY op at this shape
    family. This is the streaming session's mode — a mixed stream never
    recompiles between op types.
  · ``static_op=<code>`` — the branch is selected in Python at trace time,
    compiling only that branch. The per-op back-compat facade uses this so
    legacy call-sites don't pay the full-switch compile for ops they never
    issue.

Both modes run byte-identical branch code, so they are interchangeable
result-wise — the parity suite (tests/test_session.py) pins this.

Shape families & capacity tiers (DESIGN.md §9): ``apply_ops_step``'s jit
cache is keyed on the argument shapes, and every branch reads the index
size off ``state.capacity`` (never ``params.capacity``), so a session that
grows compiles exactly ONE new switch program per capacity tier and the op
encoding is untouched — op codes, micro-batch widths, key chains and
per-lane PRNG folds are all capacity-independent, which is what makes
logical streams growth-timing-invariant.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consolidate as consolidate_mod
from repro.core import delete as delete_mod
from repro.core import insert as insert_mod
from repro.core import maint
from repro.core import refine as refine_mod
from repro.core import search
from repro.core.graph import NULL, GraphState, mask_to_slots
from repro.core.params import IndexParams

# Maintenance op codes, journal codes, and PRNG stream ids are declared in
# the maintenance-op registry (core/maint.py) and re-exported here under
# their historical names — values are frozen for journal/checkpoint
# bit-compatibility. Maintenance keys are folded from
# fold_in(base_key, <op>.key_stream) + the op's own counter, NEVER from the
# op-key chain: auto-triggered maintenance must not shift the keys (and
# therefore the results) of subsequent stream ops.
from repro.core.maint import (  # noqa: F401  (re-exports)
    CONSOLIDATE_KEY_STREAM,
    JR_CONSOLIDATE,
    JR_GROW,
    JR_MERGE,
    JR_REFINE,
    MERGE_KEY_STREAM,
    OP_CONSOLIDATE,
    OP_REFINE,
    REFINE_KEY_STREAM,
)

OP_QUERY = 0
OP_INSERT = 1
OP_DELETE = 2
OP_NOOP = 3

OP_NAMES = {OP_QUERY: "query", OP_INSERT: "insert", OP_DELETE: "delete",
            OP_NOOP: "noop", OP_CONSOLIDATE: "consolidate",
            OP_REFINE: "refine"}

# Journal-only record codes (checkpoint/journal.py, DESIGN.md §11) — never
# dispatched to the device. Stream ops journal under their OP_* code above;
# these mark host-initiated events that replay must reproduce: the journal
# header, flush points (a maintenance trigger site), and *explicit*
# maintenance calls (auto-triggered maintenance is NOT journaled — the
# replayed op stream re-derives it from the same device-exact state). The
# maintenance record codes come from the registry above.
JR_META = 16
JR_FLUSH = 17

JR_NAMES = {JR_META: "meta", JR_FLUSH: "flush",
            **{op.journal_code: f"{op.name}!" for op in maint.REGISTRY}}


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["op_code", "payload", "ids", "valid", "offset"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class OpBatch:
    """One fixed-shape micro-batch of the op stream."""

    op_code: jax.Array   # i32[]       OP_* discriminator (traced)
    payload: jax.Array   # f32[B, dim] query/insert vectors (zeros for delete)
    ids: jax.Array       # i32[B]      delete targets (NULL elsewhere)
    valid: jax.Array     # bool[B]     real (non-padding) lanes
    offset: jax.Array    # i32[]       global item offset within the op


def make_op(
    op_code: int,
    chunk: int,
    dim: int,
    *,
    payload: np.ndarray | None = None,
    ids: np.ndarray | None = None,
    offset: int = 0,
) -> OpBatch:
    """Host-side encoder: pad one op slice up to the ``chunk`` shape."""
    n = payload.shape[0] if payload is not None else (
        ids.shape[0] if ids is not None else 0
    )
    if n > chunk:
        raise ValueError(f"op slice of {n} items exceeds chunk {chunk}")
    p = np.zeros((chunk, dim), np.float32)
    if payload is not None:
        p[:n] = payload
    i = np.full((chunk,), NULL, np.int32)
    if ids is not None:
        i[:n] = ids
    valid = np.arange(chunk) < n
    return OpBatch(
        op_code=jnp.asarray(op_code, jnp.int32),
        payload=jnp.asarray(p),
        ids=jnp.asarray(i),
        valid=jnp.asarray(valid),
        offset=jnp.asarray(offset, jnp.int32),
    )


def apply_ops(
    state: GraphState,
    batch: OpBatch,
    key: jax.Array,
    params: IndexParams,
    strategy: str,
    static_op: int | None = None,
) -> tuple[GraphState, jax.Array, jax.Array]:
    """Apply one op micro-batch. Returns (state, ids i32[B,K], scores f32[B,K]).

    Traceable; the session jits it with the state donated. ``key`` is the
    *op-level* key — shared by every micro-batch of one logical op, with
    ``batch.offset`` folding per-lane (chunking-invariant, DESIGN.md §7).
    """
    B = batch.payload.shape[0]
    K = params.search.pool_size
    sp = params.search
    empty_ids = jnp.full((B, K), NULL, jnp.int32)
    empty_scores = jnp.full((B, K), -jnp.inf, jnp.float32)

    def _noop(st: GraphState):
        return st, empty_ids, empty_scores

    def _query(st: GraphState):
        starts = search.batch_entry_points(
            st, key, B, sp.num_starts, offset=batch.offset
        )
        res = search.beam_search(st, batch.payload, starts, sp)
        ids = jnp.where(batch.valid[:, None], res.ids, NULL)
        scores = jnp.where(batch.valid[:, None], res.scores, -jnp.inf)
        return st, ids, scores

    def _insert(st: GraphState):
        st2, slots = insert_mod.insert_batch_impl(
            st, batch.payload, batch.valid, key, params,
            key_offset=batch.offset,
        )
        return st2, empty_ids.at[:, 0].set(slots), empty_scores

    def _delete(st: GraphState):
        st2 = delete_mod._STRATEGY_FNS[strategy](
            st, batch.ids, batch.valid, key, params
        )
        return st2, empty_ids, empty_scores

    def _consolidate(st: GraphState):
        # operand-free: the branch picks its own work — the B lowest-id
        # tombstones at this stream position — so chunked dispatch drains
        # the mask deterministically (DESIGN.md §8)
        tomb, tv = mask_to_slots(st.masked, B)
        st2, _ = consolidate_mod.consolidate_chunk_impl(
            st, tomb, tv, key, params
        )
        out_ids = empty_ids.at[:, 0].set(jnp.where(tv, tomb, NULL))
        return st2, out_ids, empty_scores

    def _refine(st: GraphState):
        # operand-free: the branch picks its own work — the B stalest alive
        # slots at this stream position — so chunked dispatch sweeps the
        # graph oldest-rows-first deterministically (DESIGN.md §15)
        tgt, tv = refine_mod.stalest_slots(st, B)
        st2, _ = refine_mod.refine_chunk_impl(st, tgt, tv, key, params)
        out_ids = empty_ids.at[:, 0].set(jnp.where(tv, tgt, NULL))
        return st2, out_ids, empty_scores

    if static_op == OP_CONSOLIDATE:
        # maintenance ops, host-initiated by definition: compiled on their
        # own, only by sessions that actually fire them (module docstring)
        return _consolidate(state)
    if static_op == OP_REFINE:
        return _refine(state)
    branches = (_query, _insert, _delete, _noop)
    if static_op is not None:
        # Python-level selection: compiles only this branch (facade mode)
        return branches[static_op](state)
    return jax.lax.switch(
        jnp.clip(batch.op_code, 0, len(branches) - 1), branches, state
    )


@functools.partial(
    jax.jit,
    static_argnames=("params", "strategy", "static_op"),
    donate_argnums=(0,),
)
def apply_ops_step(
    state: GraphState,
    batch: OpBatch,
    key: jax.Array,
    params: IndexParams,
    strategy: str,
    static_op: int | None = None,
) -> tuple[GraphState, jax.Array, jax.Array]:
    """The jitted, state-donating op step — the session's only device entry.

    Donation contract: the incoming ``state`` buffers are consumed (update
    branches overwrite them in place; the query/noop branches alias them
    into the returned state). Callers must drop every reference to the
    argument and hold only the returned state.
    """
    return apply_ops(state, batch, key, params, strategy, static_op)
