"""Recall + ground truth (§6 "Retrieval Recall")."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import distances
from repro.core.graph import NULL, GraphState


@functools.partial(jax.jit, static_argnames=("k",))
def brute_force_topk(
    state: GraphState, queries: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Exact top-k over alive vertices via the MXU score matrix.

    Returns (scores f32[B,k], ids i32[B,k]). Chunk queries at the call site
    if B·capacity is large.
    """
    s = distances.score_matrix(
        state.vectors, state.sqnorms, queries, state.metric
    )  # [B, capacity]
    s = jnp.where(state.alive[None, :], s, -jnp.inf)
    top_s, top_i = jax.lax.top_k(s, k)
    ids = jnp.where(top_s > -jnp.inf, top_i, NULL).astype(jnp.int32)
    return top_s, ids


def recall_at_k(
    found_ids: jax.Array,   # i32[B, >=k] search results (NULL padded)
    true_ids: jax.Array,    # i32[B, k]   ground truth
    k: int,
) -> jax.Array:
    """Mean |found ∩ true| / |true| over the batch (paper's recall)."""
    f = found_ids[:, :k]
    hits = (f[:, :, None] == true_ids[:, None, :]) & (true_ids[:, None, :] != NULL)
    n_hits = jnp.sum(jnp.any(hits, axis=1), axis=1)
    n_true = jnp.maximum(jnp.sum(true_ids != NULL, axis=1), 1)
    return jnp.mean(n_hits / n_true)
