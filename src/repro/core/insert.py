"""Vertex insertion (Alg 3, Insert branch): search → select → connect.

Deviation from the literal pseudocode (documented in DESIGN.md §2): Alg 3
line 10 only adds out-edges from the new vertex, which would leave fresh
vertices unreachable by greedy search. Following NSW/HNSW practice (which the
paper adapts its edge selection from), ``bidirectional_insert=True`` (default)
also links each selected neighbor back to the new vertex, re-running
SELECT-NEIGHBORS on the neighbor when its row is full ("shrink"). The
strict-paper variant is available via ``bidirectional_insert=False``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distances, search, select
from repro.core.graph import (
    NULL,
    GraphState,
    add_edge,
    next_free_slot,
    row_insert,
    set_out_edges,
)
from repro.core.params import IndexParams


def _connect_backward(state: GraphState, z: jax.Array, new_id: jax.Array) -> GraphState:
    """Add edge z→new, shrinking z's neighborhood if its row is full."""

    def simple(st: GraphState) -> GraphState:
        return add_edge(st, z, new_id)

    def shrink(st: GraphState) -> GraphState:
        cands = jnp.concatenate([st.adj[z], new_id[None]])
        picked = select.select_from_pool(
            st, st.vectors[z], cands, st.d_out, exclude=z[None],
            require_alive=False,  # keep existing (possibly masked) neighbors eligible
        )
        return set_out_edges(st, z, picked)

    row_full = ~jnp.any(state.adj[z] == NULL)
    return jax.lax.cond(row_full, shrink, simple, state)


def insert_one(
    state: GraphState,
    vec: jax.Array,        # f32[dim]
    key: jax.Array,
    params: IndexParams,
) -> tuple[GraphState, jax.Array]:
    """Insert one vector. Returns (state, new_id) — new_id == NULL if full."""
    sp = params.eff_insert_search
    slot = next_free_slot(state).astype(jnp.int32)
    ok = ~state.present[slot]

    # ---- ef-search for nearest candidates (alive-only results) via the
    # batched beam engine at B=1 — same compiled program family as queries
    # and GLOBAL repair (DESIGN.md §3) ----
    starts = search.entry_points(state, key, sp.num_starts)
    res = search.beam_search(state, vec[None], starts[None], sp)

    # ---- select diverse out-neighbors ----
    nbrs = select.select_from_pool(
        state, vec, res.ids[0], params.d_out, exclude=slot[None]
    )

    # ---- write the vertex ----
    vec_cast = vec.astype(state.vectors.dtype)
    if params.metric == "cos":
        vec_cast = distances.normalize(vec_cast)
    new_vectors = state.vectors.at[slot].set(
        jnp.where(ok, vec_cast, state.vectors[slot])
    )
    new_sqnorms = state.sqnorms.at[slot].set(
        jnp.where(ok, distances.sqnorm(vec_cast), state.sqnorms[slot])
    )
    state = dataclasses.replace(
        state,
        vectors=new_vectors,
        sqnorms=new_sqnorms,
        alive=state.alive.at[slot].set(jnp.where(ok, True, state.alive[slot])),
        present=state.present.at[slot].set(
            jnp.where(ok, True, state.present[slot])
        ),
        size=state.size + ok.astype(jnp.int32),
    )

    def do_connect(st: GraphState) -> GraphState:
        st = set_out_edges(st, slot, nbrs)
        if params.bidirectional_insert:
            def back(i, s):
                z = nbrs[i]
                return jax.lax.cond(
                    z != NULL,
                    lambda ss: _connect_backward(ss, z, slot),
                    lambda ss: ss,
                    s,
                )
            st = jax.lax.fori_loop(0, params.d_out, back, st)
        return st

    state = jax.lax.cond(ok, do_connect, lambda st: st, state)
    return state, jnp.where(ok, slot, NULL)


@functools.partial(jax.jit, static_argnames=("params",), donate_argnums=(0,))
def insert_batch(
    state: GraphState,
    vecs: jax.Array,      # f32[B, dim]
    valid: jax.Array,     # bool[B] — rows to actually insert
    key: jax.Array,
    params: IndexParams,
) -> tuple[GraphState, jax.Array]:
    """Sequential insertion of a batch (insert i+1 may link to insert i)."""
    B = vecs.shape[0]
    ids = jnp.full((B,), NULL, jnp.int32)

    def body(i, carry):
        st, out = carry
        k = jax.random.fold_in(key, i)

        def do(args):
            st_, out_ = args
            st2, nid = insert_one(st_, vecs[i], k, params)
            return st2, out_.at[i].set(nid)

        return jax.lax.cond(valid[i], do, lambda a: a, (st, out))

    state, ids = jax.lax.fori_loop(0, B, body, (state, ids))
    return state, ids
