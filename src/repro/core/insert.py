"""Vertex insertion (Alg 3, Insert branch): search → select → connect.

Deviation from the literal pseudocode (documented in DESIGN.md §2): Alg 3
line 10 only adds out-edges from the new vertex, which would leave fresh
vertices unreachable by greedy search. Following NSW/HNSW practice (which the
paper adapts its edge selection from), ``bidirectional_insert=True`` (default)
also links each selected neighbor back to the new vertex, re-running
SELECT-NEIGHBORS on the neighbor when its row is full ("shrink"). The
strict-paper variant is available via ``bidirectional_insert=False``.

``insert_batch`` is the **vectorized update engine** path (DESIGN.md §4):
the whole micro-batch is inserted by one batched pipeline — batched
free-slot allocation, ONE ``beam_search`` call against the pre-batch
snapshot (intra-batch members become candidates by appending the allocated
slot ids to every pool), vmapped SELECT-NEIGHBORS, and scatter-based edge
application (forward rows in one ``adj.at[slots].set``, back-link rows via
a grouped pack/shrink pass, reverse rows rebuilt in one sort/segment pass).
The pre-refactor sequential path is kept verbatim as
``insert_batch_reference`` — the parity oracle pinned by
``tests/test_update_parity.py`` (bit-exact at B=1; batch semantics differ
only in the documented snapshot-search / truncation-by-rank deviations).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distances, quantize, search, select
from repro.core.graph import (
    NULL,
    GraphState,
    add_edge,
    group_by_destination,
    next_free_slot,
    pack_rows,
    set_out_edges,
    set_out_edges_batch,
)
from repro.core.params import IndexParams


def _connect_backward(state: GraphState, z: jax.Array, new_id: jax.Array) -> GraphState:
    """Add edge z→new, shrinking z's neighborhood if its row is full."""

    def simple(st: GraphState) -> GraphState:
        return add_edge(st, z, new_id)

    def shrink(st: GraphState) -> GraphState:
        cands = jnp.concatenate([st.adj[z], new_id[None]])
        picked = select.select_from_pool(
            st, st.vectors[z], cands, st.d_out, exclude=z[None],
            require_alive=False,  # keep existing (possibly masked) neighbors eligible
        )
        return set_out_edges(st, z, picked)

    row_full = ~jnp.any(state.adj[z] == NULL)
    return jax.lax.cond(row_full, shrink, simple, state)


def insert_one(
    state: GraphState,
    vec: jax.Array,        # f32[dim]
    key: jax.Array,
    params: IndexParams,
) -> tuple[GraphState, jax.Array]:
    """Insert one vector. Returns (state, new_id) — new_id == NULL if full."""
    sp = params.eff_insert_search
    slot = next_free_slot(state).astype(jnp.int32)
    ok = ~state.present[slot]

    # ---- ef-search for nearest candidates (alive-only results) via the
    # batched beam engine at B=1 — same compiled program family as queries
    # and GLOBAL repair (DESIGN.md §3) ----
    starts = search.entry_points(state, key, sp.num_starts)
    res = search.beam_search(state, vec[None], starts[None], sp)

    # ---- select diverse out-neighbors ----
    nbrs = select.select_from_pool(
        state, vec, res.ids[0], params.d_out, exclude=slot[None]
    )

    # ---- write the vertex ----
    vec_cast = vec.astype(state.vectors.dtype)
    if params.metric == "cos":
        vec_cast = distances.normalize(vec_cast)
    new_vectors = state.vectors.at[slot].set(
        jnp.where(ok, vec_cast, state.vectors[slot])
    )
    new_sqnorms = state.sqnorms.at[slot].set(
        jnp.where(ok, distances.sqnorm(vec_cast), state.sqnorms[slot])
    )
    # codes land in the same transaction as the vector write (invariant I5)
    code_row, code_scale = quantize.quantize_rows(vec_cast)
    state = dataclasses.replace(
        state,
        vectors=new_vectors,
        sqnorms=new_sqnorms,
        codes=state.codes.at[slot].set(
            jnp.where(ok, code_row, state.codes[slot])
        ),
        scales=state.scales.at[slot].set(
            jnp.where(ok, code_scale, state.scales[slot])
        ),
        alive=state.alive.at[slot].set(jnp.where(ok, True, state.alive[slot])),
        present=state.present.at[slot].set(
            jnp.where(ok, True, state.present[slot])
        ),
        size=state.size + ok.astype(jnp.int32),
        stamps=state.stamps.at[slot].set(
            jnp.where(ok, state.clock, state.stamps[slot])
        ),
        clock=state.clock + ok.astype(jnp.int32),
    )

    def do_connect(st: GraphState) -> GraphState:
        st = set_out_edges(st, slot, nbrs)
        if params.bidirectional_insert:
            def back(i, s):
                z = nbrs[i]
                return jax.lax.cond(
                    z != NULL,
                    lambda ss: _connect_backward(ss, z, slot),
                    lambda ss: ss,
                    s,
                )
            st = jax.lax.fori_loop(0, params.d_out, back, st)
        return st

    state = jax.lax.cond(ok, do_connect, lambda st: st, state)
    return state, jnp.where(ok, slot, NULL)


# ---------------------------------------------------------------------------
# Vectorized batch insertion — the update engine's insert path (DESIGN.md §4)
# ---------------------------------------------------------------------------

def insert_batch_impl(
    state: GraphState,
    vecs: jax.Array,      # f32[B, dim]
    valid: jax.Array,     # bool[B] — rows to actually insert
    key: jax.Array,
    params: IndexParams,
    key_offset: jax.Array | int = 0,
) -> tuple[GraphState, jax.Array]:
    """Traceable body of the batched insert pipeline.

    Phases (all O(1) device dispatches, no per-item loops):
      1. allocate — every valid row gets a free slot up front (stable scan
         over ``~present``: the i-th valid row gets the i-th lowest free id,
         matching the sequential ``next_free_slot`` order).
      2. search — ONE ``beam_search`` call for the whole micro-batch against
         the *pre-batch snapshot* (new slots are not yet present, so pools
         hold pre-batch candidates only; per-row keys fold exactly like the
         reference path, so B=1 is bit-identical).
      3. write — vectors/norms/flags land with one OOB-dropping scatter.
      4. select — vmapped SELECT-NEIGHBORS over pools extended with the
         whole batch's slot ids (intra-batch candidates; the pairwise
         [B, B] block is scored inside the select, no separate pass).
      5. connect — back-links grouped by target (``group_by_destination``),
         computed as a vectorized pack (row has room) / vmapped
         shrink-select (row overflows) against a virtual post-forward view,
         then forward + back-link rows land in ONE ``set_out_edges_batch``
         call (single scatter + incremental reverse patch,
         ``graph.apply_row_updates``) — no sequential edge chains; I1 holds
         with deterministic addition refusal under in-degree pressure.
    """
    B = vecs.shape[0]
    sp = params.eff_insert_search
    d_out, cap = params.d_out, state.capacity

    # ---- phase 1: batched free-slot allocation ----
    free = ~state.present
    n_free = jnp.sum(free.astype(jnp.int32))
    free_order = jnp.argsort(~free, stable=True).astype(jnp.int32)
    alloc_rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
    ok = valid & (alloc_rank < n_free)
    slots = jnp.where(
        ok, free_order[jnp.where(ok, alloc_rank, 0)], NULL
    ).astype(jnp.int32)
    # OOB index parks invalid lanes: scatter mode="drop" makes them no-ops
    wslots = jnp.where(ok, slots, cap)

    # ---- phase 2: one ef-search for the whole batch (pre-batch snapshot).
    # Row i's search key folds the row's *global* stream index
    # (key_offset + i), so a padded final micro-batch searches exactly like
    # its unpadded twin (DESIGN.md §7) ----
    starts = search.batch_entry_points(
        state, key, B, sp.num_starts, offset=key_offset
    )
    res = search.beam_search(state, vecs, starts, sp)

    # ---- phase 3: write all vertices ----
    vec_cast = vecs.astype(state.vectors.dtype)
    if params.metric == "cos":
        vec_cast = distances.normalize(vec_cast)
    code_rows, code_scales = quantize.quantize_rows(vec_cast)
    state = dataclasses.replace(
        state,
        vectors=state.vectors.at[wslots].set(vec_cast, mode="drop"),
        sqnorms=state.sqnorms.at[wslots].set(
            distances.sqnorm(vec_cast), mode="drop"
        ),
        codes=state.codes.at[wslots].set(code_rows, mode="drop"),
        scales=state.scales.at[wslots].set(code_scales, mode="drop"),
        alive=state.alive.at[wslots].set(True, mode="drop"),
        present=state.present.at[wslots].set(True, mode="drop"),
        size=state.size + jnp.sum(ok).astype(jnp.int32),
        # stamps follow allocation rank, so batch order == sequential order
        stamps=state.stamps.at[wslots].set(
            state.clock + alloc_rank, mode="drop"
        ),
        clock=state.clock + jnp.sum(ok).astype(jnp.int32),
    )

    # ---- phase 4: vmapped SELECT-NEIGHBORS with intra-batch candidates ----
    slot_block = jnp.broadcast_to(slots[None, :], (B, B))
    cands = jnp.concatenate([res.ids, slot_block], axis=1)   # [B, K+B]
    nbrs = jax.vmap(
        lambda v, c, s: select.select_from_pool(
            state, v, c, d_out, exclude=s[None]
        )
    )(vecs, cands, slots)
    nbrs = jnp.where(ok[:, None], nbrs, NULL)

    # ---- phase 5: scatter-based edge application. Forward rows and
    # back-link rows are computed against a *virtual* post-forward view and
    # applied in ONE ``set_out_edges_batch`` call (one scatter + one
    # incremental reverse patch) ----
    if params.bidirectional_insert:
        # group back-link sources by their target z: bl[z] = new slots that
        # selected z. Per-row candidate budget d_out — a row keeps ≤ d_out
        # edges anyway, and the sequential path also never weighs more than
        # row+1 candidates per arrival (deviation bounded, B=1 unaffected).
        src = jnp.broadcast_to(slots[:, None], nbrs.shape).reshape(-1)
        dst = nbrs.reshape(-1)
        bl, touched_z = group_by_destination(src, dst, dst != NULL, cap, d_out)

        # compact frame: all work below happens on the ≤ B·d_out rows that
        # actually receive back-links (top_k indices are distinct)
        R_z = min(B * d_out, cap)
        _, zid = jax.lax.top_k(touched_z.astype(jnp.int32), R_z)
        z_ok = touched_z[zid]
        zv = jnp.where(z_ok, zid, 0).astype(jnp.int32)
        # virtual current row: a z that is itself a freshly inserted slot
        # sees its just-selected forward row (mutual intra-batch selection)
        row_of_slot = jnp.full((cap + 1,), -1, jnp.int32).at[wslots].set(
            jnp.arange(B, dtype=jnp.int32), mode="drop"
        )[:cap]
        sidx = row_of_slot[zv]
        old_z = jnp.where(
            (sidx >= 0)[:, None], nbrs[jnp.maximum(sidx, 0)], state.adj[zv]
        )                                                    # [R_z, d_out]
        bl_rows = bl[zv]                                     # [R_z, d_out]
        # mutual selection: the virtual row may already hold the back-link
        dup = jnp.any(
            bl_rows[:, :, None] == old_z[:, None, :], axis=2
        ) & (bl_rows != NULL)
        bl_rows = jnp.where(dup, NULL, bl_rows)
        comb = jnp.concatenate([old_z, bl_rows], axis=1)     # [R_z, 2·d_out]
        counts = jnp.sum(comb != NULL, axis=1)
        packed = pack_rows(comb)[:, :d_out]
        needs_shrink = counts > d_out
        shrunk = jax.vmap(
            lambda z, c: select.select_from_pool(
                state, state.vectors[z], c, d_out, exclude=z[None],
                require_alive=False,
            )
        )(zv, comb)
        z_rows = jnp.where(needs_shrink[:, None], shrunk, packed)

        # combined application; where z is itself a slot, the z row is the
        # complete (forward ∪ back-link) row and supersedes the slot lane
        slot_valid = ok & ~touched_z[jnp.where(ok, slots, 0)]
        us_all = jnp.concatenate([slots, zid.astype(jnp.int32)])
        rows_all = jnp.concatenate([nbrs, z_rows], axis=0)
        valid_all = jnp.concatenate([slot_valid, z_ok])
        state = set_out_edges_batch(state, us_all, rows_all, valid_all)
    else:
        state = set_out_edges_batch(state, slots, nbrs, ok)
    return state, slots


@functools.partial(jax.jit, static_argnames=("params",), donate_argnums=(0,))
def insert_batch(
    state: GraphState,
    vecs: jax.Array,      # f32[B, dim]
    valid: jax.Array,     # bool[B] — rows to actually insert
    key: jax.Array,
    params: IndexParams,
) -> tuple[GraphState, jax.Array]:
    """Vectorized batch insertion (one batched pipeline, DESIGN.md §4)."""
    return insert_batch_impl(state, vecs, valid, key, params)


# ---------------------------------------------------------------------------
# Reference sequential path — the pre-refactor implementation, kept as the
# parity oracle for tests/test_update_parity.py and the baseline rows of
# benchmarks/kernel_bench.py's update section. Do not optimize.
# ---------------------------------------------------------------------------

def insert_batch_reference_impl(
    state: GraphState,
    vecs: jax.Array,      # f32[B, dim]
    valid: jax.Array,     # bool[B]
    key: jax.Array,
    params: IndexParams,
) -> tuple[GraphState, jax.Array]:
    """Sequential insertion of a batch (insert i+1 may link to insert i)."""
    B = vecs.shape[0]
    ids = jnp.full((B,), NULL, jnp.int32)

    def body(i, carry):
        st, out = carry
        k = jax.random.fold_in(key, i)

        def do(args):
            st_, out_ = args
            st2, nid = insert_one(st_, vecs[i], k, params)
            return st2, out_.at[i].set(nid)

        return jax.lax.cond(valid[i], do, lambda a: a, (st, out))

    state, ids = jax.lax.fori_loop(0, B, body, (state, ids))
    return state, ids


@functools.partial(jax.jit, static_argnames=("params",))
def insert_batch_reference(
    state: GraphState,
    vecs: jax.Array,
    valid: jax.Array,
    key: jax.Array,
    params: IndexParams,
) -> tuple[GraphState, jax.Array]:
    return insert_batch_reference_impl(state, vecs, valid, key, params)
