"""GREEDY-SEARCH (Alg 1) — natively batched beam-search engine.

One ``while_loop`` carries *all* ``B`` query pools at once (DESIGN.md §3):
each step takes the top ``beam_width`` unexpanded pool entries per query
(``lax.top_k`` over the frontier), gathers their out-neighborhoods into a
``[B, W·d_out]`` candidate block, dedups it, scores the whole block in one
fused gather+dot — the Pallas kernel ``kernels.ops.gather_scores`` when
``use_pallas`` resolves true, a batched jnp matmul otherwise — and merges
into the pools with ``lax.top_k``. Every caller (query chunking, insert's
ef-search, GLOBAL delete repair, per-shard distributed fan-out, the serving
batcher) funnels into this single compiled program.

**The pool is the visited structure.** The pre-refactor engine kept a dense
``bool[capacity]`` visited bitmap per query — the batched equivalent
(``[B, capacity]``) is exactly what made the old vmap path memory-bound and
capacity-coupled. It is also redundant: scores are static and the pool is a
monotone top-K of everything scored, so a vertex that was evicted can never
re-enter (anything that evicted it still outranks it), and a vertex still in
the pool is caught by an ``O(K)`` membership test against the candidate
block. Dedup is therefore pool-membership + first-occurrence within the
block — O(B·C·K) packed compares instead of O(B·capacity) state. This keeps
pool evolution identical to the bitmap engine while making the per-query
working set independent of index capacity (the seed path slows ~3x going
from 1k to 16k vertices; this engine does not — see BENCH_search.json).

``beam_width=1`` reproduces the classic best-first walk bit-for-bit; the
pre-refactor per-query engine is kept below (``search_one_reference`` /
``search_batch_reference``) as the slow-path oracle the parity suite pins
the new engine against.

MASK semantics (§5.2): tombstoned vertices are *traversable* — they enter
the pool and steer the walk — but are never reported (``alive`` filter at
the end). This is exactly why MASK degrades QPS, which the benchmarks
reproduce.

Termination: the classic ef-search criterion — stop when no unexpanded pool
entry remains in any query's pool (every frontier candidate is already worse
than the current top-k) — plus a hard ``max_steps`` cap on loop trips.
``n_expanded`` reports the per-query count of actually expanded entries
(≤ W·max_steps), which is the paper's hop-count QPS denominator.

Compressed two-stage mode (DESIGN.md §10): with ``SearchParams.quantized``
the walk above scores candidate blocks on the int8 codes (asymmetric
distance, Pallas ``gather_scores_q8`` or the jnp fallback) — ~4x fewer
hot-loop bytes — and, when ``rerank_depth > 0``, a single exact fp32 pass
re-ranks the top-r alive pool entries before reporting (FreshDiskANN's
compressed-first/exact-rerank split). ``quantized=False`` (default) is the
exact fp32 engine, bit-identical to the pre-§10 behavior, and remains the
parity oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import distances, quantize
from repro.core.graph import NULL, GraphState
from repro.core.params import SearchParams
from repro.kernels import ops as kernel_ops

NEG_INF = distances.NEG_INF


class SearchResult(NamedTuple):
    ids: jax.Array         # i32[..., k]  NULL padded, score-descending
    scores: jax.Array      # f32[..., k]  -inf padded
    n_expanded: jax.Array  # i32[...]  hop count (profiling / paper's QPS story)


def entry_points(state: GraphState, key: jax.Array, num_starts: int) -> jax.Array:
    """Sample ``num_starts`` distinct present slots (Gumbel top-k trick)."""
    g = jax.random.gumbel(key, (state.capacity,))
    score = jnp.where(state.present, g, -jnp.inf)
    _, ids = jax.lax.top_k(score, num_starts)
    ok = state.present[ids]  # fewer present than num_starts → NULL out
    return jnp.where(ok, ids, NULL).astype(jnp.int32)


def batch_entry_points(
    state: GraphState,
    key: jax.Array,
    batch: int,
    num_starts: int,
    offset: jax.Array | int = 0,
) -> jax.Array:
    """Independent entry points for each of ``batch`` queries: i32[B, S].

    Lane ``i`` derives its key as ``fold_in(key, offset + i)`` — a function
    of the lane's *global* stream index only, never of the micro-batch
    shape. This is what makes query results invariant to how a stream is
    chunked and padded (DESIGN.md §7): ``jax.random.split(key, B)[i]``
    depends on ``B``, so the pre-session code produced different entry
    points for the same query depending on the chunk it landed in.
    """
    idx = jnp.arange(batch, dtype=jnp.int32) + offset
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
    return jax.vmap(lambda kk: entry_points(state, kk, num_starts))(keys)


# ---------------------------------------------------------------------------
# Batched beam engine — the hot path
# ---------------------------------------------------------------------------

class _BeamState(NamedTuple):
    pool_ids: jax.Array       # i32[B, K]  (the pool doubles as visited set)
    pool_scores: jax.Array    # f32[B, K]  score-descending
    pool_expanded: jax.Array  # bool[B, K]
    n_expanded: jax.Array     # i32[B]
    steps: jax.Array          # i32  (shared loop-trip counter)


def _resolve_use_pallas(params: SearchParams) -> bool:
    if params.use_pallas is not None:
        return params.use_pallas
    return kernel_ops.on_tpu()


def _score_block(
    state: GraphState,
    queries: jax.Array,    # f32[B, dim]
    ids: jax.Array,        # i32[B, C]
    valid: jax.Array,      # bool[B, C]
    use_pallas: bool,
    quantized: bool = False,
) -> jax.Array:
    """f32[B, C] scores of each query against its candidate block (invalid
    lanes → -inf). The Pallas path drives the table-row DMA straight from the
    candidate ids (no [B, C, d] HBM intermediate). ``quantized`` scores the
    block on int8 codes (asymmetric distance, DESIGN.md §10) — ~4x fewer
    hot-loop bytes per candidate, with the exact fp32 table untouched."""
    if quantized:
        if use_pallas:
            masked = jnp.where(valid, ids, NULL).astype(jnp.int32)
            return kernel_ops.gather_scores_q8(
                state.codes, state.scales, masked, queries, metric=state.metric
            )
        safe = jnp.where(valid, ids, 0)
        s = jax.vmap(
            lambda c, sc, q: quantize.scores_vs_codes(c, sc, q, state.metric)
        )(state.codes[safe], state.scales[safe], queries)
        return jnp.where(valid, s, NEG_INF)
    if use_pallas:
        masked = jnp.where(valid, ids, NULL).astype(jnp.int32)
        return kernel_ops.gather_scores(
            state.vectors, state.sqnorms, masked, queries, metric=state.metric
        )
    safe = jnp.where(valid, ids, 0)
    s = jax.vmap(
        lambda rows, sq, q: distances.scores_vs_rows(rows, sq, q, state.metric)
    )(state.vectors[safe], state.sqnorms[safe], queries)
    return jnp.where(valid, s, NEG_INF)


def _merge_pools(
    bs: _BeamState, new_ids: jax.Array, new_scores: jax.Array, k: int
) -> _BeamState:
    all_ids = jnp.concatenate([bs.pool_ids, new_ids], axis=1)
    all_scores = jnp.concatenate([bs.pool_scores, new_scores], axis=1)
    all_expanded = jnp.concatenate(
        [bs.pool_expanded, jnp.zeros(new_ids.shape, bool)], axis=1
    )
    top_scores, idx = jax.lax.top_k(all_scores, k)
    return bs._replace(
        pool_ids=jnp.take_along_axis(all_ids, idx, axis=1),
        pool_scores=top_scores,
        pool_expanded=jnp.take_along_axis(all_expanded, idx, axis=1),
    )


def beam_search(
    state: GraphState,
    queries: jax.Array,     # f32[B, dim]
    start_ids: jax.Array,   # i32[B, S]
    params: SearchParams,
    *,
    raw: bool = False,      # True → unfiltered traversal pools (incl. masked)
) -> SearchResult:
    """The batched beam engine (traceable; callers jit it or already are).

    Duplicate start ids within a query are deduped (the old engine could
    double-report them); ``entry_points`` always produces distinct ids, so
    this only matters for hand-built starts.
    """
    B = queries.shape[0]
    K, W, d_out = params.pool_size, params.beam_width, state.d_out
    C = W * d_out
    S = start_ids.shape[1]
    use_pallas = _resolve_use_pallas(params)

    # ---- seed the pools with the entry points ----
    sv = start_ids != NULL
    sv = sv & state.present[jnp.where(sv, start_ids, 0)]
    eq = (start_ids[:, :, None] == start_ids[:, None, :])
    eq = eq & sv[:, :, None] & sv[:, None, :]
    sv = sv & (jnp.argmax(eq, axis=2) == jnp.arange(S)[None, :])
    seed_scores = _score_block(
        state, queries, start_ids, sv, use_pallas, params.quantized
    )
    bs = _BeamState(
        pool_ids=jnp.full((B, K), NULL, jnp.int32),
        pool_scores=jnp.full((B, K), NEG_INF, jnp.float32),
        pool_expanded=jnp.zeros((B, K), bool),
        n_expanded=jnp.zeros((B,), jnp.int32),
        steps=jnp.asarray(0, jnp.int32),
    )
    bs = _merge_pools(bs, jnp.where(sv, start_ids, NULL), seed_scores, K)

    def cond(b: _BeamState) -> jax.Array:
        has_frontier = jnp.any((b.pool_ids != NULL) & ~b.pool_expanded)
        return has_frontier & (b.steps < params.max_steps)

    def body(b: _BeamState) -> _BeamState:
        frontier = jnp.where(
            (b.pool_ids != NULL) & ~b.pool_expanded, b.pool_scores, NEG_INF
        )
        top_w, wi = jax.lax.top_k(frontier, W)          # [B, W], k=W is small
        valid_w = top_w > NEG_INF                       # drained queries idle
        hit = jnp.any(
            (jnp.arange(K)[None, None, :] == wi[:, :, None])
            & valid_w[:, :, None],
            axis=1,
        )
        expanded = b.pool_expanded | hit

        cur = jnp.take_along_axis(b.pool_ids, wi, axis=1)
        nbrs3 = state.adj[jnp.where(valid_w, cur, 0)]   # i32[B, W, d_out]
        nv = ((nbrs3 != NULL) & valid_w[:, :, None]).reshape(B, C)
        nbrs = nbrs3.reshape(B, C)
        nv = nv & state.present[jnp.where(nv, nbrs, 0)]
        # visited dedup = pool membership (see module docstring): evicted
        # vertices can't re-enter the pool, so testing against the current
        # pool is exact
        nv = nv & ~jnp.any(nbrs[:, :, None] == b.pool_ids[:, None, :], axis=2)
        if W > 1:
            # intra-block dedup: two expanded vertices of the same query may
            # share a neighbor; keep the first occurrence only
            tri = jnp.arange(C)[:, None] > jnp.arange(C)[None, :]
            dup = jnp.any(
                (nbrs[:, :, None] == nbrs[:, None, :])
                & nv[:, None, :] & tri[None],
                axis=2,
            )
            nv = nv & ~dup

        nscores = _score_block(
            state, queries, nbrs, nv, use_pallas, params.quantized
        )
        b = b._replace(
            pool_expanded=expanded,
            n_expanded=b.n_expanded + jnp.sum(valid_w, axis=1, dtype=jnp.int32),
            steps=b.steps + 1,
        )
        return _merge_pools(b, jnp.where(nv, nbrs, NULL), nscores, K)

    bs = jax.lax.while_loop(cond, body, bs)

    if raw:
        # raw pools feed insert/repair internals, which re-score exact
        # vectors inside SELECT-NEIGHBORS — no re-rank here (on the
        # quantized walk the raw pool scores are the compressed scores)
        return SearchResult(bs.pool_ids, bs.pool_scores, bs.n_expanded)
    ids = bs.pool_ids
    ok = (ids != NULL) & state.alive[jnp.maximum(ids, 0)]
    rep_scores = jnp.where(ok, bs.pool_scores, NEG_INF)

    if params.quantized and params.rerank_depth > 0:
        # ---- stage 2 (DESIGN.md §10): one exact fp32 pass over the top-r
        # alive pool entries by compressed score; the reported top-k comes
        # from those r candidates only, with exact scores
        r = min(params.rerank_depth, K)
        top_comp, idx = jax.lax.top_k(rep_scores, r)
        cand = jnp.take_along_axis(ids, idx, axis=1)
        cv = top_comp > NEG_INF
        exact = _score_block(state, queries, cand, cv, use_pallas)
        exact = jnp.where(cv, exact, NEG_INF)
        if r < K:
            exact = jnp.pad(exact, ((0, 0), (0, K - r)),
                            constant_values=NEG_INF)
            cand = jnp.pad(cand, ((0, 0), (0, K - r)), constant_values=NULL)
        top_scores, idx2 = jax.lax.top_k(exact, K)
        rep_ids = jnp.where(
            top_scores > NEG_INF,
            jnp.take_along_axis(cand, idx2, axis=1), NULL,
        )
        return SearchResult(rep_ids, top_scores, bs.n_expanded)

    top_scores, idx = jax.lax.top_k(rep_scores, K)
    rep_ids = jnp.where(
        top_scores > NEG_INF, jnp.take_along_axis(ids, idx, axis=1), NULL
    )
    return SearchResult(rep_ids, top_scores, bs.n_expanded)


@functools.partial(jax.jit, static_argnames=("params", "raw"))
def _search_batch_jit(
    state: GraphState, queries: jax.Array, key: jax.Array,
    params: SearchParams, raw: bool,
) -> SearchResult:
    starts = batch_entry_points(state, key, queries.shape[0], params.num_starts)
    return beam_search(state, queries, starts, params, raw=raw)


def search_batch(
    state: GraphState, queries: jax.Array, key: jax.Array, params: SearchParams
) -> SearchResult:
    """Batched greedy search reporting alive vertices only."""
    return _search_batch_jit(state, queries, key, params, False)


def search_batch_raw(
    state: GraphState, queries: jax.Array, key: jax.Array, params: SearchParams
) -> SearchResult:
    """Unfiltered traversal pools (incl. masked) — insertion/repair internals."""
    return _search_batch_jit(state, queries, key, params, True)


def search_one(
    state: GraphState,
    q: jax.Array,
    start_ids: jax.Array,
    params: SearchParams,
) -> SearchResult:
    """Single-query view of the batched engine (B=1)."""
    res = beam_search(state, q[None], start_ids[None], params)
    return SearchResult(res.ids[0], res.scores[0], res.n_expanded[0])


def search_one_raw(
    state: GraphState,
    q: jax.Array,
    start_ids: jax.Array,
    params: SearchParams,
) -> SearchResult:
    res = beam_search(state, q[None], start_ids[None], params, raw=True)
    return SearchResult(res.ids[0], res.scores[0], res.n_expanded[0])


# ---------------------------------------------------------------------------
# Reference per-query engine — the pre-refactor implementation, kept as the
# slow-path oracle for the parity suite (tests/test_beam_parity.py) and for
# the seed-vs-engine rows in benchmarks/kernel_bench.py. Do not optimize.
# ---------------------------------------------------------------------------

class _LoopState(NamedTuple):
    pool_ids: jax.Array       # i32[k]
    pool_scores: jax.Array    # f32[k]
    pool_expanded: jax.Array  # bool[k]
    bitmap: jax.Array         # bool[capacity] — pushed-at-least-once
    steps: jax.Array          # i32


def _merge_pool_ref(
    pool: _LoopState, new_ids: jax.Array, new_scores: jax.Array, k: int
) -> _LoopState:
    all_ids = jnp.concatenate([pool.pool_ids, new_ids])
    all_scores = jnp.concatenate([pool.pool_scores, new_scores])
    all_expanded = jnp.concatenate(
        [pool.pool_expanded, jnp.zeros(new_ids.shape, bool)]
    )
    top_scores, idx = jax.lax.top_k(all_scores, k)
    return pool._replace(
        pool_ids=all_ids[idx],
        pool_scores=top_scores,
        pool_expanded=all_expanded[idx],
    )


def _score_new(
    state: GraphState, q: jax.Array, ids: jax.Array, valid: jax.Array
) -> jax.Array:
    safe = jnp.where(valid, ids, 0)
    rows = state.vectors[safe]
    s = distances.scores_vs_rows(rows, state.sqnorms[safe], q, state.metric)
    return jnp.where(valid, s, NEG_INF)


def _run_loop(
    state: GraphState, q: jax.Array, start_ids: jax.Array, params: SearchParams
) -> _LoopState:
    k = params.pool_size

    # ---- seed the pool with the entry points ----
    sv = start_ids != NULL
    sv = sv & state.present[jnp.where(sv, start_ids, 0)]
    seed_scores = _score_new(state, q, start_ids, sv)
    bitmap = jnp.zeros((state.capacity,), bool)
    bitmap = bitmap.at[jnp.where(sv, start_ids, 0)].max(sv)
    pool = _LoopState(
        pool_ids=jnp.full((k,), NULL, jnp.int32),
        pool_scores=jnp.full((k,), NEG_INF, jnp.float32),
        pool_expanded=jnp.zeros((k,), bool),
        bitmap=bitmap,
        steps=jnp.asarray(0, jnp.int32),
    )
    pool = _merge_pool_ref(pool, jnp.where(sv, start_ids, NULL), seed_scores, k)

    def cond(p: _LoopState) -> jax.Array:
        has_frontier = jnp.any((p.pool_ids != NULL) & ~p.pool_expanded)
        return has_frontier & (p.steps < params.max_steps)

    def body(p: _LoopState) -> _LoopState:
        frontier = jnp.where(
            (p.pool_ids != NULL) & ~p.pool_expanded, p.pool_scores, NEG_INF
        )
        best = jnp.argmax(frontier)
        cur = p.pool_ids[best]
        expanded = p.pool_expanded.at[best].set(True)

        nbrs = state.adj[jnp.maximum(cur, 0)]  # i32[d_out]
        nv = nbrs != NULL
        safe = jnp.where(nv, nbrs, 0)
        nv = nv & state.present[safe] & ~p.bitmap[safe]
        nscores = _score_new(state, q, nbrs, nv)
        bitmap = p.bitmap.at[safe].max(nv)

        p = p._replace(pool_expanded=expanded, bitmap=bitmap, steps=p.steps + 1)
        return _merge_pool_ref(p, jnp.where(nv, nbrs, NULL), nscores, k)

    return jax.lax.while_loop(cond, body, pool)


def search_one_reference(
    state: GraphState,
    q: jax.Array,
    start_ids: jax.Array,
    params: SearchParams,
) -> SearchResult:
    """Single-query greedy search reporting alive vertices only."""
    pool = _run_loop(state, q, start_ids, params)
    ids = pool.pool_ids
    ok = (ids != NULL) & state.alive[jnp.maximum(ids, 0)]
    rep_scores = jnp.where(ok, pool.pool_scores, NEG_INF)
    top_scores, idx = jax.lax.top_k(rep_scores, params.pool_size)
    rep_ids = jnp.where(top_scores > NEG_INF, ids[idx], NULL)
    return SearchResult(rep_ids, top_scores, pool.steps)


def search_one_reference_raw(
    state: GraphState,
    q: jax.Array,
    start_ids: jax.Array,
    params: SearchParams,
) -> SearchResult:
    """Unfiltered traversal pool (incl. masked) — reference raw path."""
    pool = _run_loop(state, q, start_ids, params)
    return SearchResult(pool.pool_ids, pool.pool_scores, pool.steps)


def _batched(search_fn):
    @functools.partial(jax.jit, static_argnames=("params",))
    def run(
        state: GraphState, queries: jax.Array, key: jax.Array, params: SearchParams
    ) -> SearchResult:
        starts = batch_entry_points(
            state, key, queries.shape[0], params.num_starts
        )
        return jax.vmap(lambda q, s: search_fn(state, q, s, params))(
            queries, starts
        )

    return run


search_batch_reference = _batched(search_one_reference)
search_batch_reference_raw = _batched(search_one_reference_raw)
