"""GREEDY-SEARCH (Alg 1) — TPU-native batched best-first beam search.

The paper's ``std::priority_queue`` becomes a fixed-size score-sorted pool;
each loop step expands the best not-yet-expanded pool entry, gathers its
``d_out`` neighbors, scores them in one fused gather+dot, and merges with
``lax.top_k``. A dense per-query visited bitmap replaces the hash set
(exact dedup; memory = capacity bytes/query, so callers chunk query batches).

MASK semantics (§5.2): tombstoned vertices are *traversable* — they enter the
pool and steer the walk — but are never reported (``alive`` filter at the
end). This is exactly why MASK degrades QPS, which the benchmarks reproduce.

Termination: the classic ef-search criterion — stop when no unexpanded pool
entry remains (every frontier candidate is already worse than the current
top-k) — plus a hard ``max_steps`` cap to bound the TPU while_loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import distances
from repro.core.graph import NULL, GraphState
from repro.core.params import SearchParams

NEG_INF = distances.NEG_INF


class SearchResult(NamedTuple):
    ids: jax.Array         # i32[..., k]  NULL padded, score-descending
    scores: jax.Array      # f32[..., k]  -inf padded
    n_expanded: jax.Array  # i32[...]  hop count (profiling / paper's QPS story)


class _LoopState(NamedTuple):
    pool_ids: jax.Array       # i32[k]
    pool_scores: jax.Array    # f32[k]
    pool_expanded: jax.Array  # bool[k]
    bitmap: jax.Array         # bool[capacity] — pushed-at-least-once
    steps: jax.Array          # i32


def entry_points(state: GraphState, key: jax.Array, num_starts: int) -> jax.Array:
    """Sample ``num_starts`` distinct present slots (Gumbel top-k trick)."""
    g = jax.random.gumbel(key, (state.capacity,))
    score = jnp.where(state.present, g, -jnp.inf)
    _, ids = jax.lax.top_k(score, num_starts)
    ok = state.present[ids]  # fewer present than num_starts → NULL out
    return jnp.where(ok, ids, NULL).astype(jnp.int32)


def _merge_pool(
    pool: _LoopState, new_ids: jax.Array, new_scores: jax.Array, k: int
) -> _LoopState:
    all_ids = jnp.concatenate([pool.pool_ids, new_ids])
    all_scores = jnp.concatenate([pool.pool_scores, new_scores])
    all_expanded = jnp.concatenate(
        [pool.pool_expanded, jnp.zeros(new_ids.shape, bool)]
    )
    top_scores, idx = jax.lax.top_k(all_scores, k)
    return pool._replace(
        pool_ids=all_ids[idx],
        pool_scores=top_scores,
        pool_expanded=all_expanded[idx],
    )


def _score_new(
    state: GraphState, q: jax.Array, ids: jax.Array, valid: jax.Array
) -> jax.Array:
    safe = jnp.where(valid, ids, 0)
    rows = state.vectors[safe]
    s = distances.scores_vs_rows(rows, state.sqnorms[safe], q, state.metric)
    return jnp.where(valid, s, NEG_INF)


def _run_loop(
    state: GraphState, q: jax.Array, start_ids: jax.Array, params: SearchParams
) -> _LoopState:
    k = params.pool_size

    # ---- seed the pool with the entry points ----
    sv = start_ids != NULL
    sv = sv & state.present[jnp.where(sv, start_ids, 0)]
    seed_scores = _score_new(state, q, start_ids, sv)
    bitmap = jnp.zeros((state.capacity,), bool)
    bitmap = bitmap.at[jnp.where(sv, start_ids, 0)].max(sv)
    pool = _LoopState(
        pool_ids=jnp.full((k,), NULL, jnp.int32),
        pool_scores=jnp.full((k,), NEG_INF, jnp.float32),
        pool_expanded=jnp.zeros((k,), bool),
        bitmap=bitmap,
        steps=jnp.asarray(0, jnp.int32),
    )
    pool = _merge_pool(pool, jnp.where(sv, start_ids, NULL), seed_scores, k)

    def cond(p: _LoopState) -> jax.Array:
        has_frontier = jnp.any((p.pool_ids != NULL) & ~p.pool_expanded)
        return has_frontier & (p.steps < params.max_steps)

    def body(p: _LoopState) -> _LoopState:
        frontier = jnp.where(
            (p.pool_ids != NULL) & ~p.pool_expanded, p.pool_scores, NEG_INF
        )
        best = jnp.argmax(frontier)
        cur = p.pool_ids[best]
        expanded = p.pool_expanded.at[best].set(True)

        nbrs = state.adj[jnp.maximum(cur, 0)]  # i32[d_out]
        nv = nbrs != NULL
        safe = jnp.where(nv, nbrs, 0)
        nv = nv & state.present[safe] & ~p.bitmap[safe]
        nscores = _score_new(state, q, nbrs, nv)
        bitmap = p.bitmap.at[safe].max(nv)

        p = p._replace(pool_expanded=expanded, bitmap=bitmap, steps=p.steps + 1)
        return _merge_pool(p, jnp.where(nv, nbrs, NULL), nscores, k)

    return jax.lax.while_loop(cond, body, pool)


def search_one(
    state: GraphState,
    q: jax.Array,
    start_ids: jax.Array,
    params: SearchParams,
) -> SearchResult:
    """Single-query greedy search reporting alive vertices only."""
    pool = _run_loop(state, q, start_ids, params)
    ids = pool.pool_ids
    ok = (ids != NULL) & state.alive[jnp.maximum(ids, 0)]
    rep_scores = jnp.where(ok, pool.pool_scores, NEG_INF)
    top_scores, idx = jax.lax.top_k(rep_scores, params.pool_size)
    rep_ids = jnp.where(top_scores > NEG_INF, ids[idx], NULL)
    return SearchResult(rep_ids, top_scores, pool.steps)


def search_one_raw(
    state: GraphState,
    q: jax.Array,
    start_ids: jax.Array,
    params: SearchParams,
) -> SearchResult:
    """Unfiltered traversal pool (incl. masked) — insertion/repair internals."""
    pool = _run_loop(state, q, start_ids, params)
    return SearchResult(pool.pool_ids, pool.pool_scores, pool.steps)


def _batched(search_fn):
    @functools.partial(jax.jit, static_argnames=("params",))
    def run(
        state: GraphState, queries: jax.Array, key: jax.Array, params: SearchParams
    ) -> SearchResult:
        keys = jax.random.split(key, queries.shape[0])
        starts = jax.vmap(
            lambda kk: entry_points(state, kk, params.num_starts)
        )(keys)
        return jax.vmap(lambda q, s: search_fn(state, q, s, params))(
            queries, starts
        )

    return run


search_batch = _batched(search_one)
search_batch_raw = _batched(search_one_raw)
