"""ReBuild baseline (§6 Methods) + bulk construction.

``build_graph`` is the paper's incremental constructor (sequential inserts);
``bulk_knn_build`` is the MXU-friendly alternative: exact kNN via the tiled
distance-matrix kernel, then SELECT-NEIGHBORS per node — used by the rebuild
benchmark at scale and by `ReBuild` each update batch.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distances, insert, quantize, select
from repro.core.graph import NULL, GraphState, init_graph
from repro.core.params import IndexParams


def build_graph(
    vectors: jax.Array,    # f32[n, dim]
    key: jax.Array,
    params: IndexParams,
    chunk: int = 64,
) -> GraphState:
    """Incremental construction (paper's way), chunked through the
    vectorized insert pipeline: each ``chunk``-sized micro-batch searches
    the graph-built-so-far snapshot (DESIGN.md §4)."""
    state = init_graph(
        params.capacity, params.dim, d_out=params.d_out,
        d_in=params.eff_d_in, metric=params.metric, dtype=vectors.dtype,
    )
    n = vectors.shape[0]
    for i, lo in enumerate(range(0, n, chunk)):
        part = vectors[lo:lo + chunk]
        if part.shape[0] < chunk:
            pad = jnp.zeros((chunk - part.shape[0],) + part.shape[1:],
                            part.dtype)
            part = jnp.concatenate([part, pad])
        valid = jnp.arange(chunk) < (n - lo)
        state, _ = insert.insert_batch(
            state, part, valid, jax.random.fold_in(key, i), params
        )
    return state


@functools.partial(jax.jit, static_argnames=("params", "k_nn"))
def bulk_knn_build(
    vectors: jax.Array,    # f32[n, dim]
    valid: jax.Array,      # bool[n]
    params: IndexParams,
    k_nn: int = 64,
) -> GraphState:
    """Exact-kNN bulk build: one [n, n] tiled score matrix → per-node select.

    O(n²·d) FLOPs but pure matmul (MXU). The per-node candidate pool is the
    exact k_nn nearest alive neighbors; SELECT-NEIGHBORS prunes to d_out and
    reverse edges are reconstructed exactly (I1 holds by construction).
    """
    n, dim = vectors.shape
    state = init_graph(
        params.capacity, dim, d_out=params.d_out,
        d_in=params.eff_d_in, metric=params.metric, dtype=vectors.dtype,
    )
    vec_cast = vectors.astype(state.vectors.dtype)
    if params.metric == "cos":
        vec_cast = distances.normalize(vec_cast)
    sq = distances.sqnorm(vec_cast)
    code_rows, code_scales = quantize.quantize_rows(vec_cast)
    state = dataclasses.replace(
        state,
        vectors=state.vectors.at[:n].set(jnp.where(valid[:, None], vec_cast, 0)),
        sqnorms=state.sqnorms.at[:n].set(jnp.where(valid, sq, 0.0)),
        codes=state.codes.at[:n].set(jnp.where(valid[:, None], code_rows, 0)),
        scales=state.scales.at[:n].set(jnp.where(valid, code_scales, 0.0)),
        alive=state.alive.at[:n].set(valid),
        present=state.present.at[:n].set(valid),
        size=jnp.sum(valid).astype(jnp.int32),
        # stamps follow row order — the same age order a sequential build
        # of these rows would assign (invariant I6)
        stamps=state.stamps.at[:n].set(
            jnp.where(valid,
                      jnp.cumsum(valid.astype(jnp.int32)) - 1, -1)
        ),
        clock=jnp.sum(valid).astype(jnp.int32),
        # all bulk-built rows are equally fresh (invariant I7)
        touch=state.touch.at[:n].set(jnp.where(valid, 0, -1)),
        tclock=jnp.asarray(1, jnp.int32),
    )

    # exact kNN (self + dead excluded)
    scores = distances.score_matrix(vec_cast, sq, vec_cast, params.metric)
    scores = jnp.where(valid[None, :] & valid[:, None], scores, -jnp.inf)
    scores = jnp.where(jnp.eye(n, dtype=bool), -jnp.inf, scores)
    top_s, top_i = jax.lax.top_k(scores, min(k_nn, n))
    cand_ids = jnp.where(top_s > -jnp.inf, top_i, NULL).astype(jnp.int32)

    nbrs = jax.vmap(
        lambda i, v, c: select.select_from_pool(state, v, c, params.d_out,
                                                exclude=i[None])
    )(jnp.arange(n, dtype=jnp.int32), vec_cast, cand_ids)   # i32[n, d_out]
    nbrs = jnp.where(valid[:, None], nbrs, NULL)

    # adjacency + exact reverse from the forward edges (bounded d_in, refuse
    # overflow deterministically: keep the first d_in in-edges per target)
    adj = state.adj.at[:n].set(nbrs)

    src = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[:, None], nbrs.shape
    ).reshape(-1)
    dst = nbrs.reshape(-1)
    ok = dst != NULL
    # rank of each in-edge within its destination (flat order); invalid edges
    # sink to a sentinel key past every real id
    key_dst = jnp.where(ok, dst, n)
    order = jnp.argsort(key_dst, stable=True)
    sorted_key = key_dst[order]
    pos = jnp.arange(sorted_key.shape[0])
    first_pos = jnp.searchsorted(sorted_key, sorted_key, side="left")
    rank = jnp.zeros_like(pos).at[order].set(pos - first_pos)
    keep = ok & (rank < state.d_in)
    rd, rr = jnp.where(keep, dst, 0), jnp.where(keep, rank, 0)
    # masked lanes park at [0,0] writing NULL; `max` makes them no-ops since
    # radj starts at NULL=-1 and real ids are >= 0 (collision-safe scatter)
    radj = state.radj.at[rd, rr].max(jnp.where(keep, src, NULL))
    # drop forward edges whose reverse overflowed (keeps invariant I1)
    drop = ok & (rank >= state.d_in)
    adj_flat = adj[:n].reshape(-1)
    adj_flat = jnp.where(drop, NULL, adj_flat)
    adj = adj.at[:n].set(adj_flat.reshape(n, -1))

    return dataclasses.replace(state, adj=adj, radj=radj)
