"""Similarity measures f(x, q) — paper §3 (higher is better, Eq. 1).

All scoring is expressed as a *similarity* (argmax form):
  l2  : f(x,q) = -||x-q||^2      (squared L2 — monotone in L2)
  ip  : f(x,q) = <x, q>          (MIPS)
  cos : f(x,q) = <x, q>/(|x||q|) (vectors are pre-normalized at insert, so
                                  this reduces to ip at query time)

The L2 form is computed as 2<x,q> - ||x||^2 (dropping the query-constant
||q||^2) so the batched path is a pure matmul against the cached sqnorms —
this is what makes the TPU port MXU-bound instead of VPU-bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


def sqnorm(x: jax.Array) -> jax.Array:
    return jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1)


def normalize(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    n = jnp.sqrt(jnp.maximum(sqnorm(x), eps))
    return x / n[..., None].astype(x.dtype)


def pair_score(x: jax.Array, q: jax.Array, metric: str) -> jax.Array:
    """Score between broadcastable batches of vectors. fp32 accumulate."""
    x32, q32 = x.astype(jnp.float32), q.astype(jnp.float32)
    dot = jnp.sum(x32 * q32, axis=-1)
    if metric == "l2":
        return 2.0 * dot - sqnorm(x32)  # + const(||q||^2), dropped
    if metric in ("ip", "cos"):
        return dot
    raise ValueError(metric)


def scores_vs_rows(
    rows: jax.Array,       # f32[n, dim] gathered candidate vectors
    row_sqnorms: jax.Array,  # f32[n]
    q: jax.Array,          # f32[dim]
    metric: str,
) -> jax.Array:
    """Scores of one query against n gathered rows (beam-expansion path)."""
    dot = rows.astype(jnp.float32) @ q.astype(jnp.float32)
    if metric == "l2":
        return 2.0 * dot - row_sqnorms
    return dot


def score_matrix(
    x: jax.Array,          # f32[m, dim] database block
    x_sqnorms: jax.Array,  # f32[m]
    q: jax.Array,          # f32[b, dim] query block
    metric: str,
) -> jax.Array:
    """[b, m] score matrix — the MXU-form bulk path (ground truth, rebuild,
    DLRM retrieval_cand)."""
    dots = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    if metric == "l2":
        return 2.0 * dots - x_sqnorms[None, :]
    return dots


def true_l2(score: jax.Array, q_sqnorm: jax.Array) -> jax.Array:
    """Recover ||x-q||^2 >= 0 from the l2 score (for reporting only)."""
    return jnp.maximum(q_sqnorm - score, 0.0)
