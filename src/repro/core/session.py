"""Streaming session API — device-resident state, async dispatch, op IR.

The session is the online index's new public surface (DESIGN.md §7): it owns
a device-resident ``GraphState`` plus the PRNG chain, compiles every
operation of a mixed query/insert/delete stream into fixed-shape
:class:`~repro.core.ops.OpBatch` micro-batches, and dispatches them through
the single jitted, state-donating ``apply_ops`` step. Dispatch is
**asynchronous**: ``query``/``insert``/``delete`` return an
:class:`OpHandle` immediately and the host only synchronizes on
``flush()`` or when a handle's ``result()`` is consumed — so host Python
(padding, encoding, bookkeeping) overlaps device execution instead of
stalling on a per-op ``block_until_ready``.

Key derivation (chunking-invariant, DESIGN.md §7): op number ``t`` uses
``key_t = fold_in(base_key, t)``; lane ``i`` of the op folds its *global*
stream index on top. A query stream therefore returns bit-identical results
no matter how it is chunked or padded — which is what lets the per-op
back-compat facade (``IPGMIndex``) and the streaming session be
parity-tested against each other.

``PhaseTimers`` moves to flush-based accounting: the per-phase fields count
host dispatch time (tiny under async dispatch), ``flush_s`` the synchronous
waits, and ``wall_s`` the busy wall-clock between the first dispatch of a
window and the flush that closes it. ``timers.to_dict()`` is the summary the
bench script consumes.

Consolidation (DESIGN.md §8): when ``MaintenanceParams.consolidate_threshold``
is set, the session auto-fires the jitted compaction pass
(``consolidate()``, OP_CONSOLIDATE micro-batches) at delete-dispatch and
flush boundaries once the tombstone share crosses it — which is what lets a
MASK-strategy session survive an unbounded stream.

Maintenance framework (DESIGN.md §14): every maintenance op — consolidate,
grow, refine, (tiered) merge — is declared once in the registry of
``core/maint.py``; the session's journal cseq snapshots, checkpoint
counters, replay dispatch, ``stats()`` counters, and the fault harness's
crash-point registry all iterate that registry instead of naming ops.

Background refinement (DESIGN.md §15): when
``MaintenanceParams.refine_threshold`` is set, the session opportunistically
fires the jitted refinement pass (``refine()``, OP_REFINE micro-batches) at
flush boundaries — the stream's natural idle points, where the op queue has
just drained — once enough update rows ("wear") have been dispatched since
the last pass. Each pass re-wires one chunk of the stalest alive slots at
construction quality, pinning incremental graphs to fresh-build quality
under churn.

Capacity growth (DESIGN.md §9): when ``MaintenanceParams.max_capacity`` is
set, the session auto-grows the state to a larger capacity tier
(``graph.grow_state``, geometric ``growth_factor`` steps) at
insert-dispatch boundaries, gated exactly like the consolidation trigger —
a free conservative host hint (``_free_hint`` underestimates the free-slot
count), a device-exact check only on crossing, and grow-vs-consolidate
arbitration that compacts tombstones before paying a recompile. Inserts a
full index must refuse (growth disarmed or capped) are *counted* in
``PhaseTimers.n_refused`` instead of silently returning NULL ids.

Durability (DESIGN.md §11): a session with a ``checkpoint_dir`` arms a
write-ahead op journal by default — every acknowledged op appends a
checksummed record *before* device dispatch, checkpoint ``save`` truncates
the log, and :meth:`Session.recover` rebuilds a crashed session as
(newest complete checkpoint) + (deterministic replay of the journaled
suffix). Replay is bit-exact by construction: op keys are a pure function
of logical stream position, auto-maintenance decisions are a pure function
of device-exact state (the conservative hints only gate *when the exact
check runs*, never its outcome), and the two host-initiated trigger sites
replay needs — flush boundaries and explicit ``consolidate``/``grow``
calls — are themselves journaled as marker records. Auto-triggered
maintenance is deliberately NOT journaled: the replayed op stream
re-derives it, so it can never double-apply.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import maint, metrics, quantize, rebuild
from repro.core import delete as delete_mod
from repro.core import ops as ops_mod
from repro.core.graph import (
    NULL,
    GraphState,
    graph_stats,
    grow_state,
    init_graph,
    next_capacity_tier,
)
from repro.core.ops import OP_DELETE, OP_INSERT, OP_QUERY
from repro.core.params import IndexParams
from repro.testing import faults


@dataclasses.dataclass
class PhaseTimers:
    """Flush-based phase accounting (the paper's QPS / total-time books).

    Per-phase ``*_s`` fields record *host dispatch* time — under async
    dispatch the device wait lands in ``flush_s`` instead, and ``wall_s``
    tracks end-to-end busy wall-clock (first dispatch of a window → the
    flush closing it). The legacy per-op facade flushes after every op, so
    for it ``wall_s`` ≈ the old synchronous per-op totals.
    """

    query_s: float = 0.0
    insert_s: float = 0.0
    delete_s: float = 0.0
    rebuild_s: float = 0.0
    consolidate_s: float = 0.0   # host dispatch + trigger sync of §8 passes
    grow_s: float = 0.0          # §9 capacity-tier moves (pad dispatch)
    merge_s: float = 0.0         # §12 tiered streaming-merge steps
    refine_s: float = 0.0        # §15 background refinement passes
    flush_s: float = 0.0
    wall_s: float = 0.0
    n_queries: int = 0
    n_inserts: int = 0
    n_deletes: int = 0
    n_consolidated: int = 0      # tombstones physically removed
    n_consolidations: int = 0    # compaction passes run
    n_refused: int = 0           # insert rows refused by a full index (§9)
    n_grows: int = 0             # capacity-tier moves (≙ op-step recompiles)
    n_rejected: int = 0          # insert rows rejected at dispatch (NaN/Inf)
    n_retries: int = 0           # transient dispatch failures absorbed (§11)
    n_merges: int = 0            # streaming merges completed (§12)
    n_merged: int = 0            # fresh-tier items drained into main (§12)
    n_refines: int = 0           # background refinement passes run (§15)
    n_refined: int = 0           # slots re-wired by refinement (§15)
    n_ops: int = 0

    def total(self) -> float:
        return (self.query_s + self.insert_s + self.delete_s
                + self.rebuild_s + self.consolidate_s + self.grow_s
                + self.merge_s + self.refine_s + self.flush_s)

    def maintenance_counters(self) -> dict:
        """Per-op (count, seconds) pairs, driven by the maint registry —
        a new registered op surfaces here (and in ``Session.stats()`` /
        ``run_workload`` summaries) without naming it anywhere."""
        out: dict = {}
        for op in maint.REGISTRY:
            if op.count_field:
                out[op.count_field] = getattr(self, op.count_field)
            if op.time_field:
                out[op.time_field] = getattr(self, op.time_field)
        return out

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total_s"] = self.total()
        n_items = self.n_queries + self.n_inserts + self.n_deletes
        d["n_items"] = n_items
        wall = self.wall_s + self.rebuild_s
        d["ops_per_s"] = n_items / wall if wall > 0 else 0.0
        return d


# registry contract: every registered maintenance op's timer fields must
# exist on PhaseTimers — fail at import, not deep inside a stats() call
_TIMER_FIELDS = {f.name for f in dataclasses.fields(PhaseTimers)}
for _op in maint.REGISTRY:
    for _f in (_op.time_field, _op.count_field):
        assert _f is None or _f in _TIMER_FIELDS, (
            f"maint op {_op.name!r} declares timer field {_f!r} "
            "missing from PhaseTimers")
del _TIMER_FIELDS, _op, _f


class OpHandle:
    """Future for one dispatched op — resolves to host results on demand.

    Holds only device *result* arrays (never a GraphState reference, so a
    handle can outlive any number of donations of the session state).
    Consuming the handle (``result()``/``block()``) retires it from the
    session's pending set, so serving loops that resolve every handle never
    accumulate pending state between flushes.
    """

    def __init__(self, op: str, n: int, k: int,
                 chunks: list[tuple[jax.Array, jax.Array, int]],
                 on_done=None):
        self.op = op          # "query" | "insert" | "delete"
        self.n = n            # real (unpadded) item count
        self.k = k            # reported columns for queries
        self._chunks = chunks  # [(ids_dev[B,K], scores_dev[B,K], n_valid)]
        self._on_done = on_done
        self._done = False
        # set by Session.insert when dispatch-time validation dropped rows:
        # positions of the dispatched rows within the caller's batch, so
        # result() reports NULL at the rejected positions instead of
        # silently shrinking the id array (DESIGN.md §11)
        self.row_map: np.ndarray | None = None
        self.total_rows: int | None = None

    def _finish(self) -> None:
        if not self._done:
            self._done = True
            if self._on_done is not None:
                self._on_done(self)

    def result(self):
        """Block until this op's results are on host.

        query       → (ids i32[n, k], scores f32[n, k]) numpy arrays
        insert      → ids i32[n] (NULL where the index was full)
        delete      → None
        consolidate → ids i32[n] of the compacted tombstone slots
        refine      → ids i32[n] of the re-wired slots
        """
        try:
            if self.op == "insert" and self.total_rows is not None:
                out = (np.concatenate(
                    [np.asarray(i)[:nv, 0] for i, _, nv in self._chunks]
                ) if self.n else np.zeros((0,), np.int32))
                full = np.full((self.total_rows,), NULL, np.int32)
                full[self.row_map] = out
                return full
            if self.op == "delete" or self.n == 0:
                if self.op == "query":
                    return (np.full((0, self.k), NULL, np.int32),
                            np.full((0, self.k), -np.inf, np.float32))
                if self.op in ("insert", "consolidate", "refine"):
                    return np.zeros((0,), np.int32)
                for ids, _, _ in self._chunks:
                    jax.block_until_ready(ids)
                return None
            if self.op == "query":
                ids = np.concatenate(
                    [np.asarray(i)[:nv, : self.k] for i, _, nv in self._chunks]
                )
                scores = np.concatenate(
                    [np.asarray(s)[:nv, : self.k] for _, s, nv in self._chunks]
                )
                return ids, scores
            # insert/consolidate: slot ids ride in column 0 of the result block
            return np.concatenate(
                [np.asarray(i)[:nv, 0] for i, _, nv in self._chunks]
            )
        finally:
            self._finish()

    def block(self) -> None:
        for ids, scores, _ in self._chunks:
            jax.block_until_ready((ids, scores))
        self._finish()


def consolidate_gate_crossed(thr: float | None, masked_hint: int,
                             present_floor: int) -> bool:
    """The free host-side consolidation gate (DESIGN.md §8), shared by
    :class:`Session` and ``ShardedSession``: with an overestimated tombstone
    count and an underestimated present count it only ever errs toward
    *checking* — the device-exact measurement runs only when this crosses."""
    return (thr is not None and masked_hint > 0
            and masked_hint >= thr * max(present_floor, 1))


def params_fingerprint(params: IndexParams, strategy: str) -> str:
    """Stable identity of (index geometry + policy, strategy) for checkpoint
    guarding.

    ``capacity`` is deliberately *excluded*: it is the one axis two
    compatible configurations may legitimately differ on — the growth engine
    (DESIGN.md §9) moves a session past its initial capacity tier, so a
    checkpoint records its live capacity separately (``extra["capacity"]``)
    and ``Session.restore`` range-checks it instead of fingerprinting it.
    Everything else — geometry (dim/degrees/metric), search knobs, and the
    maintenance policy including ``growth_factor``/``max_capacity`` — must
    match exactly. The vector-code scheme (DESIGN.md §10) is part of the
    geometry: a checkpoint's int8 codes are only meaningful to an engine
    that scores them under the same quantization scheme, so
    ``quantize.VECTOR_CODE_SCHEME`` is folded in and a scheme change
    invalidates old checkpoints instead of silently mis-scoring them.
    """
    def enc(obj):
        if dataclasses.is_dataclass(obj):
            return {f.name: enc(getattr(obj, f.name))
                    for f in dataclasses.fields(obj)}
        return obj
    d = enc(params)
    d.pop("capacity", None)
    return json.dumps({"params": d, "strategy": strategy,
                       "vector_codes": quantize.VECTOR_CODE_SCHEME},
                      sort_keys=True)


class Session:
    """Device-resident streaming session over one proximity-graph index.

    The session owns its ``GraphState`` exclusively: every dispatched op
    donates the state buffers to the jitted step and replaces the held
    reference with the returned (aliased or rewritten) state — no call-site
    ever sees a pre-donation array. Reads (``stats``, ``ground_truth``,
    ``rebuild_from_alive``, ``save``) implicitly ``flush()`` first.
    """

    def __init__(
        self,
        params: IndexParams,
        *,
        strategy: str | None = None,
        seed: int = 0,
        state: GraphState | None = None,
        checkpoint_dir: str | Path | None = None,
        checkpoint_keep: int = 3,
        unified_dispatch: bool = True,
        journal: bool | None = None,
        journal_fsync: str = "flush",
        flush_retries: int = 3,
        flush_backoff_s: float = 0.005,
    ):
        known = delete_mod.STRATEGIES + delete_mod.REFERENCE_STRATEGIES
        strategy = strategy if strategy is not None else params.maintenance.strategy
        if strategy not in known:
            raise ValueError(f"strategy must be one of {known}")
        self.params = params
        self.strategy = strategy
        self.seed = seed
        self._base_key = jax.random.PRNGKey(seed)
        self._op_counter = 0
        self._state = state if state is not None else init_graph(
            params.capacity, params.dim, d_out=params.d_out,
            d_in=params.eff_d_in, metric=params.metric,
        )
        self.timers = PhaseTimers()
        self._pending: list[OpHandle] = []
        self._window_t0: float | None = None
        # unified_dispatch=True routes every op through the traced-op_code
        # switch program (ONE compiled step per shape family for the whole
        # mixed stream); False selects the branch at trace time instead
        # (per-branch programs — the facade's compile-lean mode).
        self.unified_dispatch = unified_dispatch
        # consolidation engine bookkeeping (DESIGN.md §8): a *separate* PRNG
        # chain (so auto-triggered passes never shift op keys), plus cheap
        # host-side hints that gate the trigger without syncing the stream —
        # `_masked_hint` overestimates the tombstone count (every dispatched
        # mask-delete lane bumps it), `_present_floor` underestimates the
        # present count (inserts are ignored, hard deletes over-subtract);
        # the ratio therefore only ever errs toward *checking*, and the
        # device-exact measurement happens only when the gate crosses.
        self._consolidate_counter = 0
        self._in_consolidate = False
        self._masked_hint = 0
        self._present_floor = 0
        self.last_consolidate_handle: OpHandle | None = None
        # background-refinement bookkeeping (DESIGN.md §15): its own PRNG
        # chain counter (same isolation contract as consolidation) plus the
        # "wear" odometer — update rows dispatched since the last pass. Wear
        # is a pure function of the op stream (never of pending-queue depth
        # or wall-clock), which is what makes auto-refine decisions replay
        # deterministically; it is checkpointed alongside the counter.
        self._refine_counter = 0
        self._refine_wear = 0
        self._in_refine = False
        self.last_refine_handle: OpHandle | None = None
        # growth engine bookkeeping (DESIGN.md §9): `_free_hint`
        # *underestimates* the free-slot count (every dispatched insert row
        # subtracts, hard-delete frees are ignored), so an insert the hint
        # covers can never refuse — the device-exact room check runs only
        # when the hint crosses below the incoming batch size.
        self._free_hint = self._state.capacity
        if (state is not None
                or params.maintenance.consolidate_threshold is not None):
            self._refresh_hints()
        self._ckpt = None
        if checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager
            self._ckpt = CheckpointManager(checkpoint_dir, keep=checkpoint_keep)
        # durability layer (DESIGN.md §11): journal=None arms the write-ahead
        # op log whenever a checkpoint_dir is set. A *constructed* session is
        # a fresh timeline, so attach resets the log (stamping a META record
        # with the params fingerprint); Session.recover is the only path
        # that extends an existing journal. Single writer per directory.
        self.recovering = False
        self.recovery_info: dict | None = None
        self._journal = None
        self._journal_fsync = journal_fsync
        self._flush_retries = int(flush_retries)
        self._flush_backoff_s = float(flush_backoff_s)
        if journal is None:
            journal = checkpoint_dir is not None
        if journal:
            self._require_ckpt()
            self._attach_journal(fresh=True)

    # -- state ownership ---------------------------------------------------
    @property
    def state(self) -> GraphState:
        """The current (post-all-dispatched-ops) device state."""
        return self._state

    def set_state(self, state: GraphState) -> None:
        """Replace the session state (flushes pending work first)."""
        self.flush()
        self._state = state
        self._refresh_hints()

    @property
    def chunk(self) -> int:
        """The op-IR unified micro-batch width (streaming query default)."""
        return self.params.maintenance.insert_chunk

    # -- key plumbing ------------------------------------------------------
    def _op_key(self) -> jax.Array:
        key = jax.random.fold_in(self._base_key, self._op_counter)
        self._op_counter += 1
        return key

    # -- write-ahead journal (DESIGN.md §11) -------------------------------
    def _attach_journal(self, *, fresh: bool) -> None:
        from repro.checkpoint.journal import OpJournal

        path = Path(self._ckpt.dir) / "journal.bin"
        self._journal = OpJournal(path, fsync=self._journal_fsync)
        if fresh:
            self._journal.reset(meta={
                "fingerprint": params_fingerprint(self.params, self.strategy),
            })
        else:
            # recovery path: physically drop the torn/corrupt tail so new
            # appends extend a clean record prefix
            self._journal.repair()

    def _journal_append(self, code: int, *, payload=None, ids=None,
                        aux: dict | None = None) -> None:
        """Append one record *before* the action it describes (write-ahead).

        ``seq``/``cseq`` snapshot the op counter and the record's dedup
        counter at append time, which is what lets recovery skip records a
        later checkpoint already subsumes (the crash window between
        checkpoint publish and journal truncation would otherwise
        double-replay). The dedup counter is registry-driven: a maintenance
        record snapshots its *own* op's counter (consolidate →
        ``_consolidate_counter``, refine → ``_refine_counter``, ...); every
        other record keeps the legacy consolidate-counter snapshot
        byte-compatibly (stream-op replay only ever gates on ``seq``).
        """
        if self._journal is None:
            return
        mop = maint.by_journal_code(code)
        cseq = (getattr(self, mop.counter_attr)
                if mop is not None and mop.counter_attr is not None
                else self._consolidate_counter)
        self._journal.append(code, seq=self._op_counter, cseq=cseq,
                             payload=payload, ids=ids, aux=aux)
        faults.crash_point("post-journal-append")

    # -- dispatch core -----------------------------------------------------
    def _dispatch(self, op_code: int, arr, chunk: int, *,
                  fold_chunk_key: bool = False) -> OpHandle:
        """Chop one op into padded OpBatches and enqueue them (no sync)."""
        for mop in maint.SESSION_OPS:
            if mop.op_code is not None and op_code == mop.op_code:
                # static-only maintenance op: the traced switch would
                # silently clip it to NOOP — route through the op's own
                # session method instead
                raise ValueError(
                    f"OP_{mop.name.upper()} is not a stream op; "
                    f"use Session.{mop.name}()")
        key = self._op_key()  # consumed even for empty ops: stable chain
        n = arr.shape[0]
        if n == 0:  # no device work: don't arm the busy-wall window
            h = OpHandle(ops_mod.OP_NAMES[op_code], 0,
                         self.params.search.pool_size, [])
            self.timers.n_ops += 1
            return h
        if self._window_t0 is None:
            self._window_t0 = time.perf_counter()
        static_op = None if self.unified_dispatch else op_code
        is_delete = op_code == OP_DELETE
        chunks = []
        for ci, lo in enumerate(range(0, n, chunk)):
            part = arr[lo:lo + chunk]
            batch = ops_mod.make_op(
                op_code, chunk, self.params.dim,
                payload=None if is_delete else part,
                ids=part if is_delete else None,
                offset=lo,
            )
            # deletes decorrelate multi-chunk repair searches by chunk index
            # (their lane folds are chunk-local); query/insert fold global
            # stream indices via `offset` instead, for chunking invariance
            ckey = jax.random.fold_in(key, ci) if fold_chunk_key else key
            self._state, ids, scores = ops_mod.apply_ops_step(
                self._state, batch, ckey, self.params, self.strategy,
                static_op=static_op,
            )
            chunks.append((ids, scores, part.shape[0]))
        handle = OpHandle(
            ops_mod.OP_NAMES[op_code], n, self.params.search.pool_size,
            chunks, on_done=self._handle_done,
        )
        self._pending.append(handle)
        self.timers.n_ops += 1
        return handle

    def _handle_done(self, handle: OpHandle) -> None:
        """A consumed handle retires from the pending set; when the set
        drains without an explicit flush (serving loops that resolve every
        result), the timer window closes here instead."""
        try:
            self._pending.remove(handle)
        except ValueError:
            return  # already retired by flush()
        if not self._pending and self._window_t0 is not None:
            self.timers.wall_s += time.perf_counter() - self._window_t0
            self._window_t0 = None

    # -- the op surface ----------------------------------------------------
    def query(self, queries, k: int | None = None, *,
              chunk: int | None = None) -> OpHandle:
        """Dispatch a batched ANN query; returns a handle (async).

        ``handle.result()`` → (ids i32[B,k], scores f32[B,k]). Results are
        invariant to ``chunk`` (per-item keys fold global stream indices).
        """
        q = np.asarray(queries, np.float32)
        k = k if k is not None else self.params.search.pool_size
        # queries don't mutate state but DO consume an op key, so replay must
        # know they happened — a count-only record keeps the journal cheap
        self._journal_append(OP_QUERY, aux={"n": int(q.shape[0])})
        t0 = time.perf_counter()
        h = self._dispatch(OP_QUERY, q, chunk or self.chunk)
        h.k = min(k, self.params.search.pool_size)
        self.timers.query_s += time.perf_counter() - t0
        self.timers.n_queries += q.shape[0]
        return h

    def insert(self, vectors, *, chunk: int | None = None) -> OpHandle:
        """Dispatch a batch insert; ``handle.result()`` → assigned ids.

        The insert-dispatch boundary is the growth trigger point
        (DESIGN.md §9): ``_ensure_room`` grows the capacity tier and/or
        compacts tombstones before the batch runs, so an armed session
        (``maintenance.max_capacity``) never returns NULL ids until the
        ceiling is reached — and every refusal that does happen is counted
        in ``timers.n_refused``.
        """
        v = np.asarray(vectors, np.float32)
        self._journal_append(OP_INSERT, payload=v, aux={"chunk": chunk})
        # dispatch-time validation: a NaN/Inf row would poison every distance
        # it ever participates in, so it is rejected here (counted in
        # timers.n_rejected, NULL id at its position in result()). Exact-zero
        # rows are legitimate and insert normally — the quantizer gives them
        # a positive sentinel scale so their codes can never collide with
        # the freed-slot (0, 0.0) scrub pattern of invariant I5 (§10/§11).
        total, keep = v.shape[0], None
        if total:
            finite = np.isfinite(v).all(axis=1)
            if not finite.all():
                self.timers.n_rejected += int(total - finite.sum())
                keep = np.flatnonzero(finite)
                v = v[keep]
        # the gate runs OUTSIDE the insert stopwatch: its consolidation /
        # growth work bills to consolidate_s / grow_s (as the delete-path
        # trigger does), so PhaseTimers.total() never double-counts
        if v.shape[0]:
            self._ensure_room(v.shape[0])
        t0 = time.perf_counter()
        h = self._dispatch(OP_INSERT, v, chunk or
                           self.params.maintenance.insert_chunk)
        if keep is not None:
            h.row_map, h.total_rows = keep, total
        self._free_hint = max(self._free_hint - v.shape[0], 0)
        self._refine_wear += v.shape[0]
        self.timers.insert_s += time.perf_counter() - t0
        self.timers.n_inserts += v.shape[0]
        return h

    def delete(self, ids, *, chunk: int | None = None) -> OpHandle:
        """Dispatch a batch delete with the session's strategy.

        A MASK delete is the only op that grows the tombstone set, so this
        is one of the two consolidation trigger points (the other is
        ``flush`` — DESIGN.md §8).
        """
        arr = np.asarray(ids, np.int32)
        eff_chunk = chunk or self.params.maintenance.delete_chunk
        # delete repair keys fold the chunk index (chunk-local lanes), so the
        # effective width is part of the op's identity — journal it
        self._journal_append(OP_DELETE, ids=arr,
                             aux={"chunk": int(eff_chunk)})
        t0 = time.perf_counter()
        h = self._dispatch(OP_DELETE, arr, eff_chunk,
                           fold_chunk_key=True)
        self.timers.delete_s += time.perf_counter() - t0
        self.timers.n_deletes += arr.shape[0]
        self._refine_wear += arr.shape[0]
        if self.strategy == "mask":
            self._masked_hint += arr.shape[0]
            self._maybe_consolidate()
        else:
            self._present_floor = max(self._present_floor - arr.shape[0], 0)
        return h

    # -- maintenance-op plumbing (DESIGN.md §14) ---------------------------
    def _maint_key(self, mop: maint.MaintOp) -> jax.Array:
        """Next key of ``mop``'s chain — derived from the base key but on
        the op's own registered stream, so firing (or not firing) a pass
        never perturbs the op-key chain of the surrounding stream."""
        counter = getattr(self, mop.counter_attr)
        setattr(self, mop.counter_attr, counter + 1)
        return maint.maint_key(self._base_key, mop, counter)

    def _refresh_hints(self) -> None:
        """Replace the host hints with device-exact counts (synchronizes)."""
        self._masked_hint = int(jnp.sum(self._state.masked))
        self._present_floor = int(jnp.sum(self._state.present))
        self._free_hint = self._state.capacity - self._present_floor

    def consolidate(self, *, strategy: str | None = None,
                    chunk: int | None = None,
                    _n_masked: int | None = None,
                    _auto: bool = False) -> int:
        """Physically remove every tombstone: the jitted compaction pass.

        Reads the exact tombstone count (synchronizing on the dispatched
        stream; the auto-trigger passes the count it just measured via
        ``_n_masked`` instead of reducing twice), then dispatches
        ``ceil(n/chunk)`` OP_CONSOLIDATE micro-batches — each compacts the
        lowest-id tombstones at its stream position, repairs the survivors'
        rows with ``consolidate_strategy`` and returns the freed slots to
        the allocator. Returns the number of consolidated vertices; the
        dispatched work itself is async (settled by ``flush``/reads).

        Only *explicit* calls journal (JR_CONSOLIDATE): auto-triggered
        passes (``_auto=True``) are a pure function of the replayed op
        stream and would double-apply if recorded (DESIGN.md §11).
        """
        if not _auto:
            self._journal_append(ops_mod.JR_CONSOLIDATE,
                                 aux={"strategy": strategy, "chunk": chunk})
        faults.crash_point("pre-consolidate")
        t0 = time.perf_counter()
        n_masked = (int(jnp.sum(self._state.masked))
                    if _n_masked is None else int(_n_masked))
        if n_masked == 0:
            self._masked_hint = 0
            self.timers.consolidate_s += time.perf_counter() - t0
            return 0
        if self._window_t0 is None:
            self._window_t0 = time.perf_counter()
        mp = self.params.maintenance
        chunk = int(chunk) if chunk else (mp.consolidate_chunk
                                          or mp.delete_chunk)
        params = self.params
        if strategy is not None and strategy != mp.consolidate_strategy:
            params = dataclasses.replace(
                self.params,
                maintenance=dataclasses.replace(
                    mp, consolidate_strategy=strategy),
            )
        # always static-dispatched (ops.py): maintenance passes are
        # host-initiated, so the mixed-stream switch never carries this
        # branch and only consolidating sessions compile it
        static_op = ops_mod.OP_CONSOLIDATE
        chunks = []
        # the op is operand-free: one encoded batch serves every drain step
        batch = ops_mod.make_op(ops_mod.OP_CONSOLIDATE, chunk, self.params.dim)
        for lo in range(0, n_masked, chunk):
            self._state, ids, scores = ops_mod.apply_ops_step(
                self._state, batch, self._maint_key(maint.CONSOLIDATE),
                params, self.strategy, static_op=static_op,
            )
            chunks.append((ids, scores, min(chunk, n_masked - lo)))
        handle = OpHandle(
            "consolidate", n_masked, self.params.search.pool_size, chunks,
            on_done=self._handle_done,
        )
        # the int return keeps the legacy contract; the compacted slot ids
        # stay reachable through this handle until consumed/flushed
        self.last_consolidate_handle = handle
        self._pending.append(handle)
        self.timers.n_ops += 1
        self.timers.n_consolidations += 1
        self.timers.n_consolidated += n_masked
        self.timers.consolidate_s += time.perf_counter() - t0
        self._masked_hint = 0
        self._present_floor = max(self._present_floor - n_masked, 0)
        self._free_hint += n_masked  # compacted slots return to the allocator
        faults.crash_point("post-consolidate")
        return n_masked

    def _maybe_consolidate(self) -> int:
        """Auto-trigger: fire the compaction pass when the tombstone share
        crosses ``consolidate_threshold``. The host-side hint gate is free
        and conservative (only ever errs toward checking); the device-exact
        measurement — which synchronizes — runs only when it crosses."""
        thr = self.params.maintenance.consolidate_threshold
        if self._in_consolidate or not consolidate_gate_crossed(
                thr, self._masked_hint, self._present_floor):
            return 0
        self._refresh_hints()  # device-exact (synchronizes)
        if not consolidate_gate_crossed(
                thr, self._masked_hint, self._present_floor):
            return 0
        self._in_consolidate = True
        try:
            return self.consolidate(_n_masked=self._masked_hint, _auto=True)
        finally:
            self._in_consolidate = False

    # -- background refinement engine (DESIGN.md §15) ----------------------
    def refine(self, *, n: int | None = None, chunk: int | None = None,
               _auto: bool = False) -> int:
        """Re-wire the stalest alive slots at construction quality.

        Dispatches ``ceil(n/chunk)`` OP_REFINE micro-batches — each picks
        the chunk's worth of lowest-``touch`` alive slots at its stream
        position (refined rows bump their stamp on-device, so successive
        chunks sweep oldest-rows-first), re-searches their own vectors
        through the batched beam engine at ``eff_insert_search`` quality,
        re-selects over (pool ∪ current row) and scatter-applies. ``n``
        defaults to one chunk — a bounded slice of background work.
        Returns the number of slots submitted for refinement; the
        dispatched work itself is async (settled by ``flush``/reads).

        Refinement never changes the alive set, so it needs no hint
        bookkeeping; its keys come from the registered REFINE chain, so
        firing a pass never shifts the op-key chain (timing invariance).
        Only *explicit* calls journal (JR_REFINE): auto-triggered passes
        are a pure function of the replayed op stream (DESIGN.md §11).
        """
        if not _auto:
            self._journal_append(
                maint.REFINE.journal_code,
                aux={"n": None if n is None else int(n),
                     "chunk": None if chunk is None else int(chunk)})
        faults.crash_point("refine-begin")
        t0 = time.perf_counter()
        mp = self.params.maintenance
        chunk = int(chunk) if chunk else (mp.refine_chunk or mp.insert_chunk)
        n_alive = int(jnp.sum(self._state.alive))
        n_target = min(chunk if n is None else int(n), n_alive)
        self._refine_wear = 0  # any pass resets the odometer (incl. replay)
        if n_target <= 0:
            self.timers.refine_s += time.perf_counter() - t0
            return 0
        if self._window_t0 is None:
            self._window_t0 = time.perf_counter()
        # static-dispatched like every maintenance op: host-initiated, so
        # the mixed-stream switch never carries this branch and only
        # refining sessions compile it. Operand-free: one encoded batch
        # serves every step of the pass.
        batch = ops_mod.make_op(ops_mod.OP_REFINE, chunk, self.params.dim)
        chunks = []
        for lo in range(0, n_target, chunk):
            self._state, ids, scores = ops_mod.apply_ops_step(
                self._state, batch, self._maint_key(maint.REFINE),
                self.params, self.strategy, static_op=ops_mod.OP_REFINE,
            )
            chunks.append((ids, scores, min(chunk, n_target - lo)))
            faults.crash_point("refine-step")
        handle = OpHandle(
            "refine", n_target, self.params.search.pool_size, chunks,
            on_done=self._handle_done,
        )
        self.last_refine_handle = handle
        self._pending.append(handle)
        self.timers.n_ops += 1
        self.timers.n_refines += 1
        self.timers.n_refined += n_target
        self.timers.refine_s += time.perf_counter() - t0
        return n_target

    def _maybe_refine(self) -> int:
        """Opportunistic trigger: fire one bounded refinement pass at a
        flush boundary (the op queue has just drained — the stream's idle
        point) once ``refine_threshold`` update rows of wear accumulated.
        The wear odometer is free host arithmetic; the only device read is
        the alive count of the pass itself, paid when the gate crosses."""
        thr = self.params.maintenance.refine_threshold
        if thr is None or self._in_refine or self._refine_wear < thr:
            return 0
        self._in_refine = True
        try:
            return self.refine(_auto=True)
        finally:
            self._in_refine = False

    # -- capacity growth engine (DESIGN.md §9) -----------------------------
    def _ensure_room(self, n: int) -> None:
        """Grow/consolidate gate at the insert-dispatch boundary.

        ``_free_hint`` is a guaranteed underestimate of the free-slot count,
        so when it covers the batch no refusal is possible and the gate is
        free; the device-exact room check (which synchronizes the stream)
        runs only on crossing. Arbitration then compacts tombstones before
        paying a growth recompile — reclaiming masked slots is one
        consolidation pass inside the already-compiled shape family, growing
        is a whole new tier. Whatever shortfall survives (growth disarmed or
        capped at ``max_capacity``) is counted into ``timers.n_refused`` —
        exactly, because the allocator fills the lowest free slots first and
        refuses the remaining rows deterministically.
        """
        if self._free_hint >= n:
            return
        mp = self.params.maintenance
        self._refresh_hints()  # device-exact (synchronizes)
        free = self._free_hint
        if free < n and self._masked_hint > 0 and (
                mp.consolidate_threshold is not None
                or mp.max_capacity is not None):
            free += self.consolidate(_n_masked=self._masked_hint, _auto=True)
        if free < n and mp.max_capacity is not None:
            cap = self._state.capacity
            target = next_capacity_tier(
                cap, cap - free + n, mp.growth_factor, mp.max_capacity)
            if target > cap:
                self.grow(target, _auto=True)
                free += target - cap
        if free < n:
            self.timers.n_refused += n - free
        self._free_hint = free

    def grow(self, new_capacity: int, *, _auto: bool = False) -> None:
        """Move the state to a larger capacity tier (``graph.grow_state``).

        Dispatches asynchronously like every other op — existing slots keep
        their ids, new slots arrive free — and puts the session in a new
        shape family: the next ``apply_ops_step`` dispatch compiles once for
        the new tier (op-key chain and per-lane PRNG folds are untouched, so
        logical streams are growth-timing-invariant, DESIGN.md §9). An
        *armed* session enforces ``maintenance.max_capacity`` here too, so
        every tier it can ever save is one its own config restores.
        """
        t0 = time.perf_counter()
        if new_capacity == self._state.capacity:
            return
        ceiling = self.params.maintenance.max_capacity
        if ceiling is not None and new_capacity > ceiling:
            raise ValueError(
                f"new_capacity {new_capacity} exceeds maintenance."
                f"max_capacity {ceiling}")
        if not _auto:
            # explicit tier moves are journaled; auto-growth re-derives from
            # the replayed op stream (same rationale as consolidate)
            self._journal_append(ops_mod.JR_GROW,
                                 aux={"new_capacity": int(new_capacity)})
        faults.crash_point("pre-grow")
        if self._window_t0 is None:
            self._window_t0 = t0
        grown = grow_state(self._state, new_capacity)
        self._free_hint += grown.capacity - self._state.capacity
        self._state = grown
        self.timers.n_grows += 1
        self.timers.grow_s += time.perf_counter() - t0
        faults.crash_point("post-grow")

    def flush(self) -> PhaseTimers:
        """Synchronize: block until every dispatched op (and the state) is
        materialized; settle the timer window. Returns the timers. Also a
        consolidation trigger point (DESIGN.md §8): the threshold check runs
        first, so the flushed state is the compacted one.

        Because the trigger can compact, *when* a flush happened is part of
        the stream's logical identity — so a journaled session records a
        JR_FLUSH marker before the trigger and replay re-flushes at the same
        positions (DESIGN.md §11). The marker precedes the trigger for the
        same write-ahead reason as every other record.
        """
        faults.crash_point("pre-flush")
        self._journal_append(ops_mod.JR_FLUSH)
        self._maybe_consolidate()
        self._maybe_refine()
        self._sync()
        faults.crash_point("post-flush")
        return self.timers

    def _sync(self) -> None:
        """The synchronization body of :meth:`flush`, without the trigger or
        the journal marker — recovery settles replayed work through this so
        it cannot fire a compaction the original timeline never saw.

        Transient dispatch/sync failures (a device runtime hiccup — injected
        in tests via ``faults.transient``) are absorbed with bounded
        exponential backoff; exhaustion re-raises, counted retries land in
        ``timers.n_retries``.
        """
        t0 = time.perf_counter()
        attempt = 0
        while True:
            try:
                faults.transient_point("flush")
                for h in list(self._pending):  # block() retires in place
                    h.block()
                jax.block_until_ready(self._state.adj)
                break
            except faults.TransientDispatchError:
                if attempt >= self._flush_retries:
                    raise
                self.timers.n_retries += 1
                time.sleep(self._flush_backoff_s * (2.0 ** attempt))
                attempt += 1
        self._pending.clear()
        if self._journal is not None and self._journal.fsync_policy == "flush":
            self._journal.sync()  # flush is the acknowledgement barrier
        dt = time.perf_counter() - t0
        self.timers.flush_s += dt
        if self._window_t0 is not None:
            self.timers.wall_s += time.perf_counter() - self._window_t0
            self._window_t0 = None

    def _live_params(self) -> IndexParams:
        """``self.params`` with ``capacity`` pinned to the live state's tier
        (they diverge once the growth engine moves past the initial tier)."""
        if self.params.capacity == self._state.capacity:
            return self.params
        return dataclasses.replace(
            self.params, capacity=self._state.capacity)

    # -- host-path maintenance --------------------------------------------
    def rebuild_from_alive(self) -> None:
        """ReBuild baseline: reconstruct the whole graph from alive vectors.

        Rebuilds at the *live* capacity tier (``state.capacity``), not the
        initial ``params.capacity`` — after a growth the two diverge, and
        rebuilding at the stale tier would silently shrink the index.
        """
        self.flush()
        t0 = time.perf_counter()
        live_cap = self._state.capacity
        alive = np.asarray(self._state.alive)
        vecs = np.asarray(self._state.vectors)[alive]
        n = vecs.shape[0]
        padded = np.zeros((live_cap, self.params.dim), vecs.dtype)
        padded[:n] = vecs
        valid = jnp.arange(live_cap) < n
        self._state = rebuild.bulk_knn_build(
            jnp.asarray(padded), valid, self._live_params()
        )
        jax.block_until_ready(self._state.adj)
        self._masked_hint = 0
        self._present_floor = n
        self._free_hint = live_cap - n
        self.timers.rebuild_s += time.perf_counter() - t0

    # -- reporting ---------------------------------------------------------
    def ground_truth(self, queries, k: int):
        self.flush()
        return metrics.brute_force_topk(self._state, jnp.asarray(queries), k)

    def recall(self, queries, k: int) -> float:
        ids, _ = self.query(queries, k=k).result()
        _, true_ids = self.ground_truth(queries, k)
        return float(metrics.recall_at_k(jnp.asarray(ids), true_ids, k))

    def stats(self) -> dict:
        self.flush()
        out = {k: np.asarray(v).item()
               for k, v in graph_stats(self._state).items()}
        out["capacity"] = self._state.capacity  # live tier, not params'
        out["n_refused"] = self.timers.n_refused
        # every registered maintenance op reports its count/time uniformly
        # (n_consolidations/consolidate_s, n_grows/grow_s, n_refines/
        # refine_s, n_merges/merge_s) — a new op's counters arrive for free
        out.update(self.timers.maintenance_counters())
        return out

    # -- checkpointing (DESIGN.md §7) --------------------------------------
    def _require_ckpt(self):
        if self._ckpt is None:
            raise ValueError(
                "session has no checkpoint_dir; pass checkpoint_dir= to "
                "Session(...) to enable save/restore"
            )
        return self._ckpt

    def _ckpt_tree(self):
        return {"graph": self._state, "base_key": self._base_key}

    def save(self, step: int) -> Path:
        """Checkpoint GraphState + PRNG chain + timers + params fingerprint.

        The fingerprint covers geometry + policy only; the *live* capacity
        tier (which growth may have moved past ``params.capacity``) is
        recorded separately so ``restore`` can range-check it.
        """
        mgr = self._require_ckpt()
        self.flush()
        extra = {
            "fingerprint": params_fingerprint(self.params, self.strategy),
            "capacity": int(self._state.capacity),
            "op_counter": self._op_counter,
            "timers": self.timers.to_dict(),
        }
        # checkpoint-counter contract (DESIGN.md §14): each registered
        # maintenance op persists its dedup counter + declared state attrs
        for mop in maint.SESSION_OPS:
            if mop.extra_key is not None:
                extra[mop.extra_key] = int(getattr(self, mop.counter_attr))
            for attr, ekey in mop.state_attrs:
                extra[ekey] = int(getattr(self, attr))
        path = mgr.save(step, self._ckpt_tree(), extra=extra)
        # the published checkpoint subsumes the whole journal prefix; a crash
        # in this window (before truncation) is safe — recovery skips records
        # whose seq/cseq the restored counters already cover
        faults.crash_point("post-checkpoint-save")
        if self._journal is not None:
            self._journal.reset(meta={
                "fingerprint": params_fingerprint(self.params, self.strategy),
            })
        return path

    def restore(self, step: int | None = None) -> int:
        """Restore the session to a saved step (latest when ``step=None``).

        Rejects checkpoints written under a different (params, strategy)
        fingerprint — restoring a graph into mismatched geometry would
        corrupt it silently. Capacity is exempt from the fingerprint
        (DESIGN.md §9): any saved tier ≥ ``params.capacity`` restores (the
        allocator cannot shrink) and the session resumes at that tier;
        ``max_capacity`` bounds *growth*, not restorability — the matching
        policy fingerprint already guarantees the writer enforced the same
        ceiling. Returns the restored step number.

        ``step=None`` walks back past corrupt steps (a torn manifest or
        garbled shard raises :class:`~repro.checkpoint.manager.
        CheckpointCorruptError` per step and the next-older complete step is
        tried); an explicit ``step`` propagates the typed error instead.
        Restoring rewinds the timeline, so an attached journal is reset —
        its suffix described a future this session no longer has.
        """
        from repro.checkpoint.manager import CheckpointCorruptError

        mgr = self._require_ckpt()
        self.flush()
        if step is None:
            steps = mgr.all_steps()
            if not steps:
                raise FileNotFoundError(f"no checkpoint in {mgr.dir}")
            tree = extra = None
            errors: list[str] = []
            for s in reversed(steps):
                try:
                    tree, extra = mgr.restore(s, self._ckpt_tree())
                    step = s
                    break
                except CheckpointCorruptError as e:
                    errors.append(str(e))
            if tree is None:
                raise CheckpointCorruptError(
                    "every checkpoint step is corrupt:\n  "
                    + "\n  ".join(errors))
        else:
            tree, extra = mgr.restore(step, self._ckpt_tree())
        want = params_fingerprint(self.params, self.strategy)
        if extra.get("fingerprint") != want:
            raise ValueError(
                "checkpoint params/strategy fingerprint mismatch — refusing "
                "to restore an index saved under a different configuration"
            )
        tree = jax.tree.map(jnp.asarray, tree)
        state = tree["graph"]
        saved_cap = int(extra.get("capacity", state.alive.shape[0]))
        if saved_cap < self.params.capacity:
            raise ValueError(
                f"checkpoint capacity {saved_cap} is below this "
                f"configuration's initial capacity {self.params.capacity} "
                "— shrinking an allocator is not supported, refusing to "
                "restore"
            )
        # the unflatten used the *current* session's treedef, whose static
        # capacity may be a different tier — re-pin it to the saved arrays
        self._state = dataclasses.replace(state, capacity=saved_cap)
        self._base_key = tree["base_key"]
        self._op_counter = int(extra["op_counter"])
        # registry-driven counter restore; .get(..., 0) keeps checkpoints
        # written before an op existed restorable (missing key = never fired)
        for mop in maint.SESSION_OPS:
            if mop.extra_key is not None:
                setattr(self, mop.counter_attr,
                        int(extra.get(mop.extra_key, 0)))
            for attr, ekey in mop.state_attrs:
                setattr(self, attr, int(extra.get(ekey, 0)))
        self._refresh_hints()
        if self._journal is not None:
            self._journal.reset(meta={
                "fingerprint": params_fingerprint(self.params, self.strategy),
            })
        return step

    @classmethod
    def recover(
        cls,
        checkpoint_dir: str | Path,
        params: IndexParams,
        *,
        strategy: str | None = None,
        seed: int = 0,
        checkpoint_keep: int = 3,
        unified_dispatch: bool = True,
        journal_fsync: str = "flush",
        flush_retries: int = 3,
        flush_backoff_s: float = 0.005,
    ) -> "Session":
        """Rebuild a crashed session from ``checkpoint_dir`` (DESIGN.md §11).

        Restores the newest checkpoint that validates (walking past corrupt
        steps), scans the write-ahead journal — dropping any torn/corrupt
        tail — and replays the suffix through the normal op pipeline:
        records whose ``seq``/``cseq`` the restored counters already cover
        are skipped (the checkpoint subsumes them), queries advance the key
        chain without re-executing, and JR_FLUSH markers re-run the flush
        trigger so auto-compactions land at their original stream positions.
        The result is bit-identical to the uninterrupted run over the same
        acknowledged prefix. ``params``/``strategy``/``seed`` must match the
        crashed session's (the checkpoint and journal fingerprints enforce
        the first two).

        The replayed records stay in the journal (truncation happens only at
        the next checkpoint ``save``), so a crash *during or after* recovery
        recovers again from the same disk state.
        """
        from repro.checkpoint import journal as journal_mod

        sess = cls(
            params, strategy=strategy, seed=seed,
            checkpoint_dir=checkpoint_dir, checkpoint_keep=checkpoint_keep,
            unified_dispatch=unified_dispatch, journal=False,
            journal_fsync=journal_fsync, flush_retries=flush_retries,
            flush_backoff_s=flush_backoff_s,
        )
        sess.recovering = True
        t0 = time.perf_counter()
        records, _, dropped = journal_mod.scan_file(
            Path(sess._ckpt.dir) / "journal.bin")
        step = None
        try:
            step = sess.restore(None)  # journal not attached: no reset
        except FileNotFoundError:
            pass  # crashed before the first checkpoint: replay from empty
        want = params_fingerprint(sess.params, sess.strategy)
        n_replayed = n_skipped = n_unreplayable = 0
        for idx, rec in enumerate(records):
            code = rec.code
            if code == ops_mod.JR_META:
                fp = rec.aux.get("fingerprint")
                if fp is not None and fp != want:
                    raise ValueError(
                        "journal params/strategy fingerprint mismatch — "
                        "refusing to replay ops recorded under a different "
                        "configuration")
                continue
            if code in (OP_QUERY, OP_INSERT, OP_DELETE, ops_mod.JR_FLUSH):
                if rec.seq < sess._op_counter:
                    n_skipped += 1
                    continue
                if code != ops_mod.JR_FLUSH and rec.seq > sess._op_counter:
                    # sequence gap: the newest checkpoint was corrupt AND the
                    # journal had already been truncated past the fallback
                    # step — the ops in between are genuinely gone, so this
                    # suffix belongs to a timeline the session can no longer
                    # reach. Stop replaying, surface the loss, come up on
                    # the longest recoverable prefix (a stale index beats
                    # refusing to serve).
                    n_unreplayable = len(records) - idx
                    break
            if code == OP_QUERY:
                sess._op_key()  # results are gone; only the chain advances
            elif code == OP_INSERT:
                sess.insert(rec.payload, chunk=rec.aux.get("chunk"))
            elif code == OP_DELETE:
                sess.delete(rec.ids, chunk=rec.aux.get("chunk"))
            elif code == ops_mod.JR_FLUSH:
                sess.flush()
            else:
                # maintenance records dispatch through the registry: the
                # op's replay hook re-executes the pass (or dedups it
                # against the restored counters) — adding an op needs no
                # new branch here (DESIGN.md §14)
                mop = maint.by_journal_code(code)
                if mop is None or mop.tier != "session":
                    raise ValueError(f"unknown journal record code {code}")
                if not mop.replay(sess, rec):
                    n_skipped += 1
                    continue
            n_replayed += 1
        sess._sync()  # settle WITHOUT the flush trigger (no extra compaction)
        # a gapped suffix is a dead timeline — it can never replay against
        # this state, so start a fresh journal rather than extend it
        sess._attach_journal(fresh=n_unreplayable > 0)
        sess.recovering = False
        sess.recovery_info = {
            "step": step,
            "n_replayed": n_replayed,
            "n_skipped": n_skipped,
            "n_unreplayable": n_unreplayable,
            "dropped_bytes": int(dropped),
            "replay_s": time.perf_counter() - t0,
        }
        return sess
