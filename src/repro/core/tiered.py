"""Two-tier streaming index — a small fresh tier in front of a big main tier.

``TieredSession`` (DESIGN.md §12) scales the session API past what one
mutable graph serves comfortably: every insert lands in a small *fresh*
:class:`~repro.core.session.Session` (cheap to mutate, hard-delete
strategy), deletes of main-resident points become tombstones in the *main*
tier's MASK bitmap, and queries fan out to both tiers and union their
results — deduplicated by **external id**, tombstone-filtered by each
tier's own alive bitmap. A background :class:`~repro.core.merge.
StreamingMerge` drains the fresh tier into main in bounded chunks (one
"pump" step per insert/delete), so neither tier ever stops serving and no
op pauses longer than one merge chunk.

External ids: callers address points by a stable external id (assigned
monotonically by ``insert``, or caller-chosen via ``insert(ids=...)``).
Query results report external ids; the slot ids of the two tiers never
escape. Re-inserting a live external id is an **upsert**: the old copy is
deleted (in whichever tier(s) hold it) before the new vector lands in
fresh — so a query can never surface a stale vector or the same id twice.

Determinism contract (the §7/§8 guarantee class, extended):

  · every public op consumes a *fixed* number of per-tier op keys —
    queries one main key (the fresh tier is served by an exact host scan,
    no key), deletes one key per tier, inserts one delete key per tier
    plus one fresh insert key — regardless of where the targets happen to
    live, so merge timing can never shift either tier's op-key chain;
  · merge work runs on its own PRNG stream
    (``fold_in(base, MERGE_KEY_STREAM)`` + merge counter), like the §8
    consolidation chain;
  · merge progress is a pure function of the acknowledged *mutation*
    stream (the auto-start gate reads exact host mirrors; one pump per
    insert/delete — queries never pump, keeping fan-out latency flat, and
    flushes never pump, keeping flush idempotent for recovery), which is
    what makes crash recovery land bit-exactly mid-merge.

Durability (DESIGN.md §11): with a ``checkpoint_dir`` the tiered session
arms its own write-ahead journal — ops journal under their OP_* codes with
*external* ids, explicit merges under JR_MERGE — and ``save`` checkpoints
both tiers plus the slot→external-id maps atomically (completing any
in-flight merge first: the checkpoint merge barrier). ``recover`` replays
the journal suffix through the normal op pipeline.

Host mirrors: the tiered layer keeps exact numpy mirrors of each tier's
``present``/``masked`` bitmaps plus the slot→ext maps. Every device-side
allocation and compaction pick is deterministic (lowest-free-first /
lowest-id-tombstones-first), so the mirrors track the device bit-exactly
without ever synchronizing — they are what lets routing, the merge gate
and refusal accounting run host-side at op rate.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import maint
from repro.core import merge as merge_mod
from repro.core import metrics
from repro.core import ops as ops_mod
from repro.core.graph import NULL
from repro.core.ops import OP_DELETE, OP_INSERT, OP_QUERY
from repro.core.params import IndexParams
from repro.core.session import (
    PhaseTimers,
    Session,
    params_fingerprint,
)
from repro.testing import faults

_HARD_STRATEGIES = ("pure", "local", "global", "rwalk")


class _TierMirror:
    """Exact host mirror of one tier's occupancy + slot→ext map.

    ``present``/``masked`` replicate the device bitmaps (allocation and
    compaction picks are deterministic, so no sync is ever needed);
    ``ext[slot]`` is the external id resident in ``slot`` (NULL = none).
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.present = np.zeros((capacity,), bool)
        self.masked = np.zeros((capacity,), bool)
        self.ext = np.full((capacity,), NULL, np.int32)

    def grow(self, new_capacity: int) -> None:
        extra = new_capacity - self.capacity
        if extra <= 0:
            return
        self.present = np.pad(self.present, (0, extra))
        self.masked = np.pad(self.masked, (0, extra))
        self.ext = np.pad(self.ext, (0, extra), constant_values=NULL)
        self.capacity = new_capacity

    @property
    def n_free(self) -> int:
        return int(self.capacity - np.sum(self.present))


def _union_topk(ext_ids: np.ndarray, scores: np.ndarray, k: int,
                device: bool = False, dedup: bool = True):
    """Dedup-by-ext union of fan-out results → top-k (scores descending).

    Duplicate external ids (an item resident in both tiers mid-drain) keep
    their best score only; NULL lanes never rank. Runs host-side — the
    fan-in must not cost a device dispatch on the query hot path (the
    ≥0.95x single-session throughput floor, ``benchmarks/kernel_bench.py
    run_tiered``). ``dedup=False`` skips the duplicate sweep — valid
    whenever no external id can be resident in both tiers, i.e. whenever
    no merge was in flight at dispatch (upserts delete the old copy in
    the same op, so mid-drain "both" items are the only duplicate
    source). ``device=True`` routes the final top-k through the sharded
    fan-in kernel (``distributed.ann.topk_union``) instead —
    semantically identical modulo tie order; used off the hot path
    (``ground_truth``) to keep the two unions covered by the same tests.
    """
    ids = np.ascontiguousarray(ext_ids, np.int32)
    sc = np.ascontiguousarray(scores, np.float32).copy()
    B, W = ids.shape
    if B == 0:
        return (np.full((0, k), NULL, np.int32),
                np.full((0, k), -np.inf, np.float32))
    sc[ids == NULL] = -np.inf
    if dedup:
        # one lexsort across all rows: group (row, ext), keep best score
        rowid = np.repeat(np.arange(B), W)
        flat_i, flat_s = ids.ravel(), sc.ravel()
        order = np.lexsort((-flat_s, flat_i, rowid))
        e, r = flat_i[order], rowid[order]
        dup = np.zeros(B * W, bool)
        dup[1:] = (e[1:] == e[:-1]) & (r[1:] == r[:-1]) & (e[1:] != NULL)
        flat_s = flat_s.copy()
        flat_s[order[dup]] = -np.inf
        sc = flat_s.reshape(B, W)
    if device:
        from repro.distributed.ann import topk_union  # lazy: import cycle
        top_s, top_i = topk_union(jnp.asarray(sc), jnp.asarray(ids), k)
        top_s, top_i = np.asarray(top_s), np.asarray(top_i)
    else:
        top = np.argsort(-sc, axis=1, kind="stable")[:, :k]
        rows = np.arange(B)[:, None]
        top_s = sc[rows, top]
        top_i = ids[rows, top]
    top_i = np.where(top_s > -np.inf, top_i, NULL).astype(np.int32, copy=False)
    return top_i, top_s


def _translate(slot_ids: np.ndarray, ext_map: np.ndarray) -> np.ndarray:
    """slot ids [B,K] → external ids under a dispatch-time ext snapshot."""
    safe = np.clip(slot_ids, 0, len(ext_map) - 1)
    return np.where(slot_ids >= 0, ext_map[safe], NULL).astype(np.int32)


class TieredOpHandle:
    """Future for one tiered op — fans in the per-tier handles on demand."""

    def __init__(self, op: str, n: int, k: int = 0, subs=(),
                 ext_result: np.ndarray | None = None,
                 fresh_res: np.ndarray | None = None,
                 fresh_ext: np.ndarray | None = None,
                 main_ext: np.ndarray | None = None,
                 halved: bool = False,
                 both: np.ndarray | None = None):
        self.op = op
        self.n = n
        self.k = k
        self._subs = list(subs)
        self._ext_result = ext_result   # insert: acked external ids
        self._fresh_res = fresh_res     # query: fresh key matrix [B, C]
        self._fresh_ext = fresh_ext     # query: fresh slot→ext snapshot
        self._main_ext = main_ext       # query: NULL-padded main slot→ext
        self._halved = halved           # query: keys are score/2 (l2)
        self._both = both               # query: mid-drain "both" ext ids

    def result(self):
        """Block until applied on both tiers; return the fan-in result.

        query  → (ext_ids i32[n, k], scores f32[n, k])
        insert → ext_ids i32[n] (NULL where rejected/refused/superseded)
        delete → None
        """
        if self.op == "query":
            mi, ms = self._subs[0].result()
            if self.n == 0:
                return (np.full((0, self.k), NULL, np.int32),
                        np.full((0, self.k), -np.inf, np.float32))
            # fused fan-in: one ranking pass over [fresh keys | main keys].
            # Main scores are halved to the fresh keys' scale (exact), the
            # winners' scores doubled back (exact) — see _fresh_key. The
            # engine pads empty pool lanes with NULL ids AND −inf scores
            # (search.NEG_INF), and the padded main map gathers slot NULL
            # to ext NULL, so no fix-up pass is needed anywhere on the
            # common (no-merge) path. Call count matters more than row
            # width here: each numpy call costs ~10-25µs of cache-refill
            # tax when interleaved with device dispatch, so this path
            # stays at ~8 calls on [B, C+k] rather than pre-cutting the
            # fresh side to top-k with extra partitions.
            mext = self._main_ext[mi]
            mkey = 0.5 * ms if self._halved else ms
            if self._both is not None:
                # an ext resident in both tiers mid-drain would surface
                # twice. The exact fresh scan ALWAYS carries the fresh
                # copy of every both-resident item, so the main copy can
                # be dropped unconditionally — one isin over the [B, k]
                # main lanes instead of a lexsort sweep of the union.
                mkey = np.where(np.isin(mext, self._both), -np.inf, mkey)
            allk = np.concatenate([self._fresh_res, mkey], axis=1)
            B, C = self._fresh_res.shape
            allid = np.concatenate(
                [np.broadcast_to(self._fresh_ext, (B, C)), mext], axis=1)
            # negation (not a reversed ascending slice) keeps NaN scores
            # ranked last, matching the device engine's convention
            top = np.argsort(-allk, axis=1)[:, :self.k]
            tops = np.take_along_axis(allk, top, axis=1)
            topi = np.take_along_axis(allid, top, axis=1)
            if self._halved:
                tops *= 2.0
            if self._both is not None:
                # a dropped-to-−inf main lane keeps a real (duplicate) ext
                # id; NULL it out if it still made the top-k of a row with
                # fewer than k live candidates
                topi = np.where(tops > -np.inf, topi, NULL).astype(
                    np.int32, copy=False)
            return topi, tops
        for h in self._subs:
            h.block()
        if self.op == "insert":
            return self._ext_result
        return None

    def block(self) -> None:
        for h in self._subs:
            h.block()


class TieredSession:
    """Two-tier streaming session: fresh-tier writes, fan-out reads.

    ``params`` configures the **main** tier (its maintenance strategy is
    forced to ``"mask"`` — the tombstone bitmap is what makes cross-tier
    deletes O(1)); the fresh tier reuses the same geometry at
    ``fresh_capacity`` slots with a hard-delete ``fresh_strategy``. The
    ``maintenance.merge_*`` knobs arm the streaming-merge auto-trigger.
    """

    def __init__(
        self,
        params: IndexParams,
        *,
        fresh_capacity: int | None = None,
        fresh_strategy: str = "global",
        seed: int = 0,
        checkpoint_dir: str | Path | None = None,
        checkpoint_keep: int = 3,
        unified_dispatch: bool = True,
        journal: bool | None = None,
        journal_fsync: str = "flush",
    ):
        if fresh_strategy not in _HARD_STRATEGIES:
            raise ValueError(
                f"fresh_strategy must be a hard-delete strategy "
                f"{_HARD_STRATEGIES} (the fresh tier never tombstones)")
        mp = params.maintenance
        if fresh_capacity is None:
            fresh_capacity = max(2 * mp.insert_chunk, params.capacity // 8)
        if fresh_capacity < 1:
            raise ValueError("fresh_capacity must be >= 1")
        self.params = params
        self.fresh_capacity = int(fresh_capacity)
        self.fresh_strategy = fresh_strategy
        self.seed = seed
        self._base_key = jax.random.PRNGKey(seed)
        # tier configs: neither tier self-consolidates (merge compaction is
        # the ONLY main-tier compactor — keeps the host mirrors exact) and
        # the fresh tier never grows (merge catch-up is its backpressure)
        fresh_params = dataclasses.replace(
            params, capacity=self.fresh_capacity,
            maintenance=dataclasses.replace(
                mp, strategy=fresh_strategy, consolidate_threshold=None,
                max_capacity=None, merge_fresh_threshold=None,
                merge_tombstone_threshold=None))
        main_params = dataclasses.replace(
            params,
            maintenance=dataclasses.replace(
                mp, strategy="mask", consolidate_threshold=None,
                merge_fresh_threshold=None, merge_tombstone_threshold=None))
        self._fresh = Session(fresh_params, strategy=fresh_strategy,
                              seed=2 * seed + 1, journal=False,
                              unified_dispatch=unified_dispatch)
        self._main = Session(main_params, strategy="mask", seed=2 * seed,
                             journal=False,
                             unified_dispatch=unified_dispatch)
        self._fm = _TierMirror(self.fresh_capacity)
        self._mm = _TierMirror(params.capacity)
        # host mirror of the fresh tier's stored vectors — serves the exact
        # fresh scan on the query hot path (bitwise the device rows for
        # l2/ip; cos rows may differ from the device copy in the last ulp
        # of the normalization)
        self._fvec = np.zeros((self.fresh_capacity, params.dim), np.float32)
        self._fsqh = np.zeros((self.fresh_capacity,), np.float32)  # ‖row‖²/2
        # fused additive bias for the fresh scan — occupancy penalty and
        # (for l2) the −‖x‖²/2 term in ONE vector, so the hot path is a
        # single matmul + add: −inf at absent slots, else −‖x‖²/2 (l2) or
        # 0 (ip/cos). Kept in lockstep with _fm.present at every flip site.
        self._fbias = np.full((self.fresh_capacity,), -np.inf, np.float32)
        # copy-on-write snapshots of the slot→ext maps handed to query
        # handles; None = stale, rebuilt on the next query (mutations only
        # pay a flag write, queries only pay the copy when something moved)
        self._fext_snap: np.ndarray | None = None
        self._mext_pad: np.ndarray | None = None
        self._loc: dict[int, tuple] = {}   # ext → ("fresh",f)|("main",m)|("both",f,m)
        self._both_set: set[int] = set()   # live "both" ext ids in _loc
        self._next_ext = 0
        self._op_counter = 0
        self._merge_counter = 0
        self._merges_done = 0
        self._active_merge: merge_mod.StreamingMerge | None = None
        self.timers = PhaseTimers()
        self.recovering = False
        self.recovery_info: dict | None = None
        self._ckpt = None
        if checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager
            self._ckpt = CheckpointManager(checkpoint_dir,
                                           keep=checkpoint_keep)
        self._journal = None
        self._journal_fsync = journal_fsync
        if journal is None:
            journal = checkpoint_dir is not None
        if journal:
            self._require_ckpt()
            self._attach_journal(fresh=True)

    # -- tier access (read-only views for tests/benchmarks) ----------------
    @property
    def fresh(self) -> Session:
        return self._fresh

    @property
    def main(self) -> Session:
        return self._main

    @property
    def active_merge(self) -> merge_mod.StreamingMerge | None:
        return self._active_merge

    @property
    def n_alive(self) -> int:
        """Number of live external ids (an item in both tiers counts once)."""
        return len(self._loc)

    @property
    def _merge_chunk(self) -> int:
        mp = self.params.maintenance
        return mp.merge_chunk or mp.insert_chunk

    # -- identity / durability plumbing ------------------------------------
    def _fingerprint(self) -> str:
        return json.dumps({
            "tiered": params_fingerprint(self.params, "mask"),
            "fresh_capacity": self.fresh_capacity,
            "fresh_strategy": self.fresh_strategy,
        }, sort_keys=True)

    def _require_ckpt(self):
        if self._ckpt is None:
            raise ValueError(
                "session has no checkpoint_dir; pass checkpoint_dir= to "
                "TieredSession(...) to enable save/restore")
        return self._ckpt

    def _attach_journal(self, *, fresh: bool) -> None:
        from repro.checkpoint.journal import OpJournal

        path = Path(self._ckpt.dir) / "journal.bin"
        self._journal = OpJournal(path, fsync=self._journal_fsync)
        if fresh:
            self._journal.reset(meta={"fingerprint": self._fingerprint()})
        else:
            self._journal.repair()

    def _journal_append(self, code: int, *, payload=None, ids=None,
                        aux: dict | None = None) -> None:
        if self._journal is None:
            return
        # cseq carries the merge counter here: JR_MERGE records are deduped
        # against merges a later checkpoint already covers, exactly like
        # Session's JR_CONSOLIDATE/cseq pairing (DESIGN.md §11). The
        # counter is the MERGE registry entry's ``counter_attr``
        # (core/maint.py) — the tiered tier registers exactly one
        # maintenance op, so every record snapshots it.
        self._journal.append(code, seq=self._op_counter,
                             cseq=getattr(self, maint.MERGE.counter_attr),
                             payload=payload, ids=ids, aux=aux)
        faults.crash_point("post-journal-append")

    # -- merge engine plumbing (DESIGN.md §12) -----------------------------
    def _merge_key(self) -> jax.Array:
        # drawn from the MERGE op's registered key stream (DESIGN.md §14);
        # _merge_counter advances per *draw* (several per merge), while
        # _merges_done — the cseq dedup counter — advances per merge
        key = maint.maint_key(self._base_key, maint.MERGE,
                              self._merge_counter)
        self._merge_counter += 1
        return key

    def _pump(self) -> None:
        """One bounded merge step per insert/delete while a merge is in flight."""
        if self._active_merge is not None and self._active_merge.step():
            self._active_merge = None

    def _maybe_merge_start(self) -> None:
        """Auto-trigger: start a merge when either gate arm crosses.

        Exact host counters (the mirrors), so unlike the §8 hint gate there
        is no device sync to avoid — the check is free and precise. Never
        journaled: replay re-derives the decision from the same mirrors.
        """
        if self._active_merge is not None:
            return
        mp = self.params.maintenance
        ft, tt = mp.merge_fresh_threshold, mp.merge_tombstone_threshold
        fire = False
        if ft is not None:
            fire |= int(np.sum(self._fm.present)) >= ft * self.fresh_capacity
        if tt is not None:
            n_masked = int(np.sum(self._mm.masked))
            n_present = int(np.sum(self._mm.present))
            fire |= n_masked > 0 and n_masked >= tt * max(n_present, 1)
        if fire:
            self._active_merge = merge_mod.StreamingMerge(self)

    def _merge_to_completion(self) -> int:
        if self._active_merge is None:
            self._active_merge = merge_mod.StreamingMerge(self)
        m = self._active_merge
        m.run()
        self._active_merge = None
        return m.n_drained

    def merge(self) -> int:
        """Run a streaming merge to completion (explicit, journaled).

        Completes the in-flight merge if one is active, else starts one.
        Returns the number of items drained fresh→main. The auto-triggered
        path (``maintenance.merge_*`` thresholds) instead advances one
        chunk per mutation op and is not journaled.
        """
        self._journal_append(ops_mod.JR_MERGE)
        return self._merge_to_completion()

    # -- the op surface ----------------------------------------------------
    def _ext_snap_dirty(self) -> None:
        """Invalidate the COW slot→ext snapshots after any ext-map write."""
        self._fext_snap = None
        self._mext_pad = None

    def _fresh_key(self, q: np.ndarray) -> np.ndarray:
        """Ranking keys [B, fresh_capacity] for the exact fresh scan.

        The fresh tier never exceeds ``fresh_capacity`` rows, so an exact
        host-side scan of the vector mirror beats paying a second device
        dispatch per query (that dispatch overhead is what the ≥0.95x
        single-session throughput floor forbids) — and the small tier gets
        *exact* results, FreshDiskANN-style. Consumes no fresh-tier op key.

        For l2 the key is ⟨x,q⟩ − ‖x‖²/2 — exactly HALF the engine's
        2⟨x,q⟩ − ‖x‖² score (``distances.pair_score``): halving and
        doubling are exact in binary floating point, so the fan-in ranks
        these against half-scaled main scores and recovers bit-exact
        scores by doubling the winners, touching only [B, k] lanes
        instead of the full key matrix. ip/cos: the dot itself. Absent
        slots are −inf (their ext mirror entries are already NULL).
        The −‖x‖²/2 term and the occupancy penalty live fused in
        ``_fbias``, so this is one matmul plus one add.
        """
        return q @ self._fvec.T + self._fbias

    def query(self, queries, k: int | None = None) -> TieredOpHandle:
        """Fan-out ANN query over both tiers; returns a handle (async).

        The main tier runs the device beam engine (one op key); the fresh
        tier is served by the exact host scan (no key, no device work).
        Queries do NOT pump the merge — merge progress is a function of
        the *mutation* stream only, which keeps fan-out latency flat.
        ``handle.result()`` → (ext_ids i32[B,k], scores f32[B,k]) — the
        dedup-by-external-id union of the two tiers' top-k.
        """
        q = np.asarray(queries, np.float32)
        k = k if k is not None else self.params.search.pool_size
        k = min(k, self.params.search.pool_size)
        self._journal_append(OP_QUERY, aux={"n": int(q.shape[0])})
        self._op_counter += 1
        t0 = time.perf_counter()
        fkey = self._fresh_key(q)
        hm = self._main.query(q, k=k)
        # duplicates across tiers exist only while some item is "both"-
        # resident mid-drain — snapshot the flag now, like the ext maps
        # (the padded main map turns slot NULL (−1) into ext NULL by
        # indexing). The snapshots are COW: handles share one frozen array
        # until the next mutation invalidates it (``_ext_snap_dirty``).
        fe = self._fext_snap
        if fe is None:
            fe = self._fext_snap = self._fm.ext.copy()
        mp = self._mext_pad
        if mp is None:
            mp = self._mext_pad = np.append(self._mm.ext, np.int32(NULL))
        both = (np.fromiter(self._both_set, np.int32,
                            len(self._both_set))
                if self._both_set else None)
        h = TieredOpHandle("query", q.shape[0], k, (hm,),
                           fresh_res=fkey, fresh_ext=fe, main_ext=mp,
                           halved=self.params.metric == "l2",
                           both=both)
        self.timers.query_s += time.perf_counter() - t0
        self.timers.n_queries += q.shape[0]
        self.timers.n_ops += 1
        return h

    def insert(self, vectors, ids=None) -> TieredOpHandle:
        """Insert (or upsert) a batch into the fresh tier.

        ``ids`` picks the external ids (else assigned monotonically). A row
        whose external id is currently live anywhere replaces the old copy
        — the old vector is deleted from its tier(s) in the same op, so it
        can never be returned again (stale-ghost regression,
        tests/test_tiered.py). ``handle.result()`` → the acked external
        ids, NULL at rejected (non-finite), refused (both tiers full) and
        superseded (duplicate-id-within-batch, last wins) positions.
        """
        v = np.asarray(vectors, np.float32)
        n = v.shape[0]
        if ids is None:
            ext = np.arange(self._next_ext, self._next_ext + n,
                            dtype=np.int64)
        else:
            ext = np.asarray(ids, np.int64).reshape(-1)
            if ext.shape[0] != n:
                raise ValueError("ids must match vectors' row count")
            if n and (ext.min() < 0 or ext.max() >= 2**31):
                raise ValueError("external ids must be int32 and >= 0")
        ext = ext.astype(np.int32)
        if n:
            self._next_ext = max(self._next_ext, int(ext.max()) + 1)
        self._journal_append(OP_INSERT, payload=v, ids=ext)
        self._op_counter += 1
        self._pump()
        # dispatch-time validation (same rules as Session.insert) + in-batch
        # upsert order: a duplicated external id keeps its LAST finite row
        live = (np.isfinite(v).all(axis=1) if n
                else np.zeros((0,), bool))
        self.timers.n_rejected += int(n - np.sum(live))
        seen: set[int] = set()
        for i in range(n - 1, -1, -1):
            if not live[i]:
                continue
            e = int(ext[i])
            if e in seen:
                live[i] = False
            else:
                seen.add(e)
        # cross-tier upsert: evict live duplicates first (uniform key use —
        # one delete key per tier, dispatched even when there are none)
        dups = np.asarray(
            [int(e) for e, ok in zip(ext, live) if ok and int(e) in self._loc],
            np.int32)
        sub = list(self._delete_exts(dups))
        vk = v[live]
        ek = ext[live]
        nk = vk.shape[0]
        # fresh-tier backpressure: when the batch outruns the merge, finish
        # the drain synchronously (deterministic — re-derived on replay)
        if nk and self._fm.n_free < nk and (
                np.sum(self._fm.present) > 0
                or self._active_merge is not None):
            self._merge_to_completion()
        t0 = time.perf_counter()
        free_ids = np.flatnonzero(~self._fm.present)
        n_ok = min(nk, len(free_ids))
        self.timers.n_refused += nk - n_ok
        if nk:
            sub.append(self._fresh.insert(vk))
        else:
            sub.append(self._fresh.insert(np.zeros((0, self.params.dim),
                                                   np.float32)))
        slots = free_ids[:n_ok].astype(np.int32)
        self._fm.present[slots] = True
        self._fm.ext[slots] = ek[:n_ok]
        self._ext_snap_dirty()
        # vector mirror for the exact fresh scan — what the device stores:
        # verbatim f32 rows (cos: pre-normalized, the numpy twin of
        # distances.normalize)
        vstore = vk[:n_ok]
        if self.params.metric == "cos":
            vstore = vstore / np.sqrt(np.maximum(
                np.sum(np.square(vstore), -1, keepdims=True), 1e-12))
        self._fvec[slots] = vstore
        self._fsqh[slots] = 0.5 * np.sum(np.square(vstore), axis=-1)
        self._fbias[slots] = (-self._fsqh[slots]
                              if self.params.metric == "l2" else 0.0)
        for e, s in zip(ek[:n_ok], slots):
            self._loc[int(e)] = ("fresh", int(s))
        res = np.full((n,), NULL, np.int32)
        live_idx = np.flatnonzero(live)
        res[live_idx[:n_ok]] = ek[:n_ok]
        self.timers.insert_s += time.perf_counter() - t0
        self.timers.n_inserts += nk
        self.timers.n_ops += 1
        self._maybe_merge_start()
        return TieredOpHandle("insert", n, subs=sub, ext_result=res)

    def delete(self, ids) -> TieredOpHandle:
        """Delete a batch of external ids (wherever each is resident).

        Fresh-resident ids hard-delete; main-resident ids tombstone (the
        §12 cross-tier bitmap); ids mid-drain leave both tiers. Unknown
        ids are ignored. One delete key per tier is always consumed.
        """
        arr = np.asarray(ids, np.int64).reshape(-1).astype(np.int32)
        self._journal_append(OP_DELETE, ids=arr)
        self._op_counter += 1
        self._pump()
        t0 = time.perf_counter()
        sub = self._delete_exts(arr)
        self.timers.delete_s += time.perf_counter() - t0
        self.timers.n_deletes += arr.shape[0]
        self.timers.n_ops += 1
        self._maybe_merge_start()
        return TieredOpHandle("delete", arr.shape[0], subs=sub)

    def _delete_exts(self, exts: np.ndarray):
        """Route external-id deletes to their tiers (mirrors + device).

        Always dispatches exactly one delete op per tier — empty where a
        tier holds no targets — so the per-tier key chains advance
        identically no matter where the ids live (merge-timing invariance).
        """
        fslots, mslots = [], []
        m = self._active_merge
        for e in np.unique(exts):
            e = int(e)
            loc = self._loc.pop(e, None)
            if loc is None:
                continue
            if loc[0] in ("fresh", "both"):
                f = loc[1]
                fslots.append(f)
                self._fm.present[f] = False
                self._fbias[f] = -np.inf
                self._fm.ext[f] = NULL
                if loc[0] == "fresh" and m is not None and not m.done:
                    m.cancelled.add(e)
                if loc[0] == "both":
                    self._both_set.discard(e)
            if loc[0] == "main":
                mslots.append(loc[1])
                self._mm.masked[loc[1]] = True
                self._mm.ext[loc[1]] = NULL
            elif loc[0] == "both":
                mslots.append(loc[2])
                self._mm.masked[loc[2]] = True
                self._mm.ext[loc[2]] = NULL
        self._ext_snap_dirty()
        hf = self._fresh.delete(np.asarray(sorted(fslots), np.int32))
        hm = self._main.delete(np.asarray(sorted(mslots), np.int32))
        return hf, hm

    def flush(self) -> PhaseTimers:
        """Synchronize both tiers; also a merge *trigger* point.

        Flush never pumps — a journaled JR_FLUSH replays as another flush,
        and a crash inside one is resumed by re-running it, so everything
        here must be idempotent (``_maybe_merge_start`` is: a second call
        sees the active merge and does nothing; a pump would not be).
        Merge chunks advance on insert/delete only.
        """
        faults.crash_point("pre-flush")
        self._journal_append(ops_mod.JR_FLUSH)
        self._maybe_merge_start()
        self._fresh._sync()
        self._main._sync()
        if self._journal is not None and self._journal.fsync_policy == "flush":
            self._journal.sync()
        faults.crash_point("post-flush")
        return self.timers

    # -- reporting ---------------------------------------------------------
    def ground_truth(self, queries, k: int):
        """Exact top-k over the union of both tiers' alive sets (ext ids)."""
        self.flush()
        q = jnp.asarray(queries, jnp.float32)
        fs, fi = metrics.brute_force_topk(self._fresh.state, q, k)
        ms, mi = metrics.brute_force_topk(self._main.state, q, k)
        ids = np.concatenate(
            [_translate(np.asarray(fi), self._fm.ext),
             _translate(np.asarray(mi), self._mm.ext)], axis=1)
        sc = np.concatenate([np.asarray(fs), np.asarray(ms)], axis=1)
        # off the hot path: route the fan-in through the sharded union
        # kernel so both union implementations stay exercised
        return _union_topk(ids, sc, k, device=True)

    def recall(self, queries, k: int) -> float:
        ids, _ = self.query(queries, k=k).result()
        true_ids, _ = self.ground_truth(queries, k)
        return float(metrics.recall_at_k(jnp.asarray(ids),
                                         jnp.asarray(true_ids), k))

    def stats(self) -> dict:
        self.flush()
        out = {
            "n_alive": self.n_alive,
            "n_fresh": int(np.sum(self._fm.present)),
            "n_main": int(np.sum(self._mm.present & ~self._mm.masked)),
            "n_main_masked": int(np.sum(self._mm.masked)),
            "fresh_capacity": self._fresh.state.capacity,
            "main_capacity": self._main.state.capacity,
            "n_merged": self.timers.n_merged,
            "n_refused": self.timers.n_refused,
            "merge_active": self._active_merge is not None,
        }
        # registry-driven maintenance counters (n_merges/merge_s, plus the
        # session-tier counters of this facade's own timers), like
        # Session.stats (DESIGN.md §14)
        out.update(self.timers.maintenance_counters())
        return out

    def check_mirrors(self) -> None:
        """Assert the host mirrors match the device bitmaps bit-exactly."""
        self.flush()
        for name, sess, mir in (("fresh", self._fresh, self._fm),
                                ("main", self._main, self._mm)):
            present = np.asarray(sess.state.present)
            masked = np.asarray(sess.state.masked)
            if not np.array_equal(present, mir.present):
                raise AssertionError(f"{name} present mirror diverged")
            if not np.array_equal(masked, mir.masked):
                raise AssertionError(f"{name} masked mirror diverged")
        if self.params.metric != "cos":   # cos: last-ulp normalize skew
            dev = np.asarray(self._fresh.state.vectors)
            pres = np.flatnonzero(self._fm.present)
            if not np.array_equal(self._fvec[pres], dev[pres]):
                raise AssertionError("fresh vector mirror diverged")
            want = 0.5 * np.sum(np.square(self._fvec[pres]), axis=-1)
            if not np.array_equal(self._fsqh[pres], want):
                raise AssertionError("fresh sqnorm mirror diverged")
        for e, loc in self._loc.items():
            if loc[0] in ("fresh", "both"):
                assert self._fm.ext[loc[1]] == e
            if loc[0] == "main":
                assert self._mm.ext[loc[1]] == e
            elif loc[0] == "both":
                assert self._mm.ext[loc[2]] == e
        both = {e for e, loc in self._loc.items() if loc[0] == "both"}
        if both != self._both_set:
            raise AssertionError(
                f"_both_set diverged: {self._both_set} != {both}")
        alive_bias = (-self._fsqh if self.params.metric == "l2"
                      else np.float32(0.0))
        want_bias = np.where(self._fm.present, alive_bias,
                             np.float32(-np.inf))
        if not np.array_equal(self._fbias, want_bias):
            raise AssertionError("fresh scan bias diverged")
        # the COW ext snapshots, when materialized, must match the live maps
        if self._fext_snap is not None and not np.array_equal(
                self._fext_snap, self._fm.ext):
            raise AssertionError("fresh ext snapshot went stale")
        if self._mext_pad is not None and not np.array_equal(
                self._mext_pad, np.append(self._mm.ext, np.int32(NULL))):
            raise AssertionError("main ext snapshot went stale")

    # -- checkpointing (DESIGN.md §11/§12) ---------------------------------
    def _ckpt_tree(self):
        return {
            "fresh_graph": self._fresh._state,
            "main_graph": self._main._state,
            "base_key": self._base_key,
            "fresh_ext": jnp.asarray(self._fm.ext),
            "main_ext": jnp.asarray(self._mm.ext),
        }

    def save(self, step: int) -> Path:
        """Checkpoint both tiers + ext maps + counters atomically.

        An in-flight merge is completed first (the **merge barrier**): a
        checkpoint never holds a mid-drain item in both tiers, so restore
        needs no merge state beyond the counters. The barrier is journaled
        (JR_MERGE via :meth:`merge`), so a crash between the barrier and
        the checkpoint publish replays to the identical post-merge state.
        """
        mgr = self._require_ckpt()
        if self._active_merge is not None:
            self.merge()
        self.flush()
        path = mgr.save(
            step, self._ckpt_tree(),
            extra={
                "fingerprint": self._fingerprint(),
                "fresh_capacity": int(self._fresh.state.capacity),
                "main_capacity": int(self._main.state.capacity),
                "op_counter": self._op_counter,
                "fresh_op_counter": self._fresh._op_counter,
                "main_op_counter": self._main._op_counter,
                "merge_counter": self._merge_counter,
                # the MERGE registry entry's checkpoint-counter contract
                maint.MERGE.extra_key: getattr(self, maint.MERGE.counter_attr),
                "next_ext": self._next_ext,
                "timers": self.timers.to_dict(),
            },
        )
        faults.crash_point("post-checkpoint-save")
        if self._journal is not None:
            self._journal.reset(meta={"fingerprint": self._fingerprint()})
        return path

    def restore(self, step: int | None = None) -> int:
        """Restore both tiers from a saved step (latest when ``None``).

        Same guard rails as ``Session.restore``: fingerprint must match,
        the main tier's saved capacity must cover this configuration's
        initial capacity, corrupt steps are walked past when ``step`` is
        ``None``. Mirrors and the ext→location table are rebuilt from the
        checkpointed ext maps (a checkpoint never holds mid-merge state,
        so no ``"both"`` entries exist).
        """
        from repro.checkpoint.manager import CheckpointCorruptError

        mgr = self._require_ckpt()
        self.flush()
        if step is None:
            steps = mgr.all_steps()
            if not steps:
                raise FileNotFoundError(f"no checkpoint in {mgr.dir}")
            tree = extra = None
            errors: list[str] = []
            for s in reversed(steps):
                try:
                    tree, extra = mgr.restore(s, self._ckpt_tree())
                    step = s
                    break
                except CheckpointCorruptError as e:
                    errors.append(str(e))
            if tree is None:
                raise CheckpointCorruptError(
                    "every checkpoint step is corrupt:\n  "
                    + "\n  ".join(errors))
        else:
            tree, extra = mgr.restore(step, self._ckpt_tree())
        if extra.get("fingerprint") != self._fingerprint():
            raise ValueError(
                "checkpoint params/strategy fingerprint mismatch — refusing "
                "to restore an index saved under a different configuration")
        tree = jax.tree.map(jnp.asarray, tree)
        fc = int(extra["fresh_capacity"])
        mc = int(extra["main_capacity"])
        if fc != self.fresh_capacity:
            raise ValueError(
                f"checkpoint fresh capacity {fc} != configured "
                f"{self.fresh_capacity}")
        if mc < self.params.capacity:
            raise ValueError(
                f"checkpoint main capacity {mc} is below this "
                f"configuration's initial capacity {self.params.capacity}")
        self._fresh._state = dataclasses.replace(tree["fresh_graph"],
                                                 capacity=fc)
        self._main._state = dataclasses.replace(tree["main_graph"],
                                                capacity=mc)
        self._base_key = tree["base_key"]
        self._op_counter = int(extra["op_counter"])
        self._fresh._op_counter = int(extra["fresh_op_counter"])
        self._main._op_counter = int(extra["main_op_counter"])
        self._merge_counter = int(extra["merge_counter"])
        setattr(self, maint.MERGE.counter_attr,
                int(extra[maint.MERGE.extra_key]))
        self._next_ext = int(extra["next_ext"])
        self._active_merge = None
        # rebuild mirrors + location table from the checkpointed state
        self._fm = _TierMirror(fc)
        self._fm.present = np.asarray(self._fresh.state.present).copy()
        self._fm.ext = np.asarray(tree["fresh_ext"]).astype(np.int32).copy()
        self._fvec = np.asarray(self._fresh.state.vectors).astype(
            np.float32).copy()
        self._fsqh = (0.5 * np.sum(np.square(self._fvec), axis=-1)
                      ).astype(np.float32)
        alive_bias = (-self._fsqh if self.params.metric == "l2"
                      else np.float32(0.0))
        self._fbias = np.where(self._fm.present, alive_bias,
                               np.float32(-np.inf)).astype(np.float32)
        self._ext_snap_dirty()
        self._mm = _TierMirror(mc)
        self._mm.present = np.asarray(self._main.state.present).copy()
        self._mm.masked = np.asarray(self._main.state.masked).copy()
        self._mm.ext = np.asarray(tree["main_ext"]).astype(np.int32).copy()
        self._loc = {}
        self._both_set = set()   # a checkpoint never holds mid-merge state
        for s in np.flatnonzero(self._fm.ext != NULL):
            self._loc[int(self._fm.ext[s])] = ("fresh", int(s))
        for s in np.flatnonzero(self._mm.ext != NULL):
            self._loc[int(self._mm.ext[s])] = ("main", int(s))
        self._fresh._refresh_hints()
        self._main._refresh_hints()
        if self._journal is not None:
            self._journal.reset(meta={"fingerprint": self._fingerprint()})
        return step

    @classmethod
    def recover(
        cls,
        checkpoint_dir: str | Path,
        params: IndexParams,
        *,
        fresh_capacity: int | None = None,
        fresh_strategy: str = "global",
        seed: int = 0,
        checkpoint_keep: int = 3,
        unified_dispatch: bool = True,
        journal_fsync: str = "flush",
    ) -> "TieredSession":
        """Rebuild a crashed tiered session: checkpoint + journal replay.

        Same contract as ``Session.recover`` (DESIGN.md §11): the newest
        valid checkpoint restores, the journal suffix replays through the
        normal op pipeline (queries reproduce only their key/counter
        effects without re-executing), and the result — *including any
        mid-merge progress*, which is a pure function of the op stream — is
        bit-identical to the uninterrupted run over the acknowledged
        prefix.
        """
        from repro.checkpoint import journal as journal_mod

        sess = cls(
            params, fresh_capacity=fresh_capacity,
            fresh_strategy=fresh_strategy, seed=seed,
            checkpoint_dir=checkpoint_dir, checkpoint_keep=checkpoint_keep,
            unified_dispatch=unified_dispatch, journal=False,
            journal_fsync=journal_fsync,
        )
        sess.recovering = True
        t0 = time.perf_counter()
        records, _, dropped = journal_mod.scan_file(
            Path(sess._ckpt.dir) / "journal.bin")
        step = None
        try:
            step = sess.restore(None)
        except FileNotFoundError:
            pass  # crashed before the first checkpoint: replay from empty
        want = sess._fingerprint()
        n_replayed = n_skipped = n_unreplayable = 0
        for idx, rec in enumerate(records):
            code = rec.code
            if code == ops_mod.JR_META:
                fp = rec.aux.get("fingerprint")
                if fp is not None and fp != want:
                    raise ValueError(
                        "journal params/strategy fingerprint mismatch — "
                        "refusing to replay ops recorded under a different "
                        "configuration")
                continue
            if code in (OP_QUERY, OP_INSERT, OP_DELETE, ops_mod.JR_FLUSH):
                if rec.seq < sess._op_counter:
                    n_skipped += 1
                    continue
                if code != ops_mod.JR_FLUSH and rec.seq > sess._op_counter:
                    # gapped suffix: dead timeline (see Session.recover)
                    n_unreplayable = len(records) - idx
                    break
            if code == OP_QUERY:
                # results are gone; reproduce the state effects only: the
                # op-counter bump and the main tier's op key (the fresh
                # scan is host-only — no key, no pump on queries)
                sess._op_counter += 1
                sess._main._op_key()
            elif code == OP_INSERT:
                sess.insert(rec.payload, ids=rec.ids)
            elif code == OP_DELETE:
                sess.delete(rec.ids)
            elif code == ops_mod.JR_FLUSH:
                sess.flush()
            else:
                # tiered maintenance records dispatch through the registry
                # (core/maint.py), mirroring Session.recover
                mop = maint.by_journal_code(code)
                if mop is None or mop.tier != "tiered":
                    raise ValueError(f"unknown journal record code {code}")
                if not mop.replay(sess, rec):
                    n_skipped += 1
                    continue
            n_replayed += 1
        sess._fresh._sync()
        sess._main._sync()
        sess._attach_journal(fresh=n_unreplayable > 0)
        sess.recovering = False
        sess.recovery_info = {
            "step": step,
            "n_replayed": n_replayed,
            "n_skipped": n_skipped,
            "n_unreplayable": n_unreplayable,
            "dropped_bytes": int(dropped),
            "replay_s": time.perf_counter() - t0,
        }
        return sess
