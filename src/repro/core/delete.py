"""DELETE-UPDATE-EDGES — the paper's four strategies (Alg 4–6, §5), batched.

All strategies are implemented over a *batch* of deletions (the paper's
workloads delete 10k vectors per step), with each strategy expressed as
vectorized gathers/scatters + (for GLOBAL) a batched repair search that
reuses the exact query path — so on TPU the repair cost is literally
denominated in "equivalent queries", which is the amortization argument of
§6.2.

  PURE   (Alg 4): drop vertex + incident edges (vectorized edge scrub).
  MASK   (§5.2) : tombstone — traversable, not reportable, edges untouched.
  LOCAL  (Alg 5): each in-neighbor u of deleted x splices ONE diverse edge
                  chosen from x's out-neighbors (candidates local to x).
  GLOBAL (Alg 6): each in-neighbor u is re-inserted: full greedy search from
                  u's vector, SELECT-NEIGHBORS over the global candidates,
                  out-edges replaced wholesale.
  RWALK  (Mishra et al. 2025, PAPERS.md): random-walk replacement wiring —
                  each in-neighbor u splices ONE edge found by short walks
                  seeded at a *random subset of x's out-neighborhood* and
                  run through the batched beam engine with u's vector as
                  the guide. Candidate quality sits between LOCAL (x's
                  1-hop neighborhood only) and GLOBAL (full re-search) at a
                  small fixed walk budget (``MaintenanceParams.rwalk_*``).

Each repair strategy is split into a *plan* (which edges to splice/replace —
shared verbatim between the vectorized and reference appliers, so parity
tests compare pure edge-application semantics) and an *applier*. The
vectorized appliers (DESIGN.md §4) group the planned edits per source row
and apply them through the bulk primitive ``set_out_edges_batch`` — one
forward scatter + one incremental reverse patch instead of O(B·d_in)
sequential ``lax.cond`` chains. The sequential appliers are kept
as ``delete_local_reference`` / ``delete_global_reference`` /
``delete_rwalk_reference`` (strategy names
accepted by ``delete_batch`` and ``IPGMIndex``) and pinned against the
vectorized paths by ``tests/test_update_parity.py``. Under in-degree
pressure the two differ only in *which* bounded subset of edges survives
(scalar refusal vs deterministic truncation-by-rank — DESIGN.md §4).

Ordering subtlety shared by LOCAL/GLOBAL: the deleted batch is first marked
dead (``alive=False``) but kept *present* so repair searches can still route
through it (Alg 6 searches on the not-yet-updated graph); edges are scrubbed
and slots freed only after all repairs are computed.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import search, select
from repro.core.graph import (
    NULL,
    GraphState,
    add_edge,
    group_by_destination,
    pack_rows,
    remove_edge,
    scrub_edges_to,
    set_out_edges,
    set_out_edges_batch,
)
from repro.core.params import IndexParams

STRATEGIES = ("pure", "mask", "local", "global", "rwalk")
REFERENCE_STRATEGIES = ("local_reference", "global_reference",
                        "rwalk_reference")


def _dead_mask(state: GraphState, ids: jax.Array, valid: jax.Array) -> jax.Array:
    m = jnp.zeros((state.capacity,), bool)
    return m.at[jnp.where(valid, ids, 0)].max(valid)


def _precheck(state: GraphState, ids: jax.Array, valid: jax.Array) -> jax.Array:
    """Only alive vertices can be deleted."""
    safe = jnp.where(valid, ids, 0)
    return valid & (ids != NULL) & state.alive[safe]


def _mark_dead(state: GraphState, ids: jax.Array, valid: jax.Array) -> GraphState:
    """alive=False (not reportable) while still present (traversable).

    Invalid lanes park at index 0 — the ``.min`` combine makes their write a
    no-op (min(x, True) == x), so duplicate-index scatters stay exact. The
    ``size`` decrement must count *distinct* slots: the same id twice in one
    batch passes ``_precheck`` on both lanes (it checks the pre-batch
    ``alive``), and while the alive scatter is idempotent, subtracting per
    lane would drive ``size`` below the true alive count. First lane wins,
    found by a sort-free scatter-min over lane indices: O(B) work instead of
    the O(B²) all-pairs first-occurrence mask.
    """
    B = ids.shape[0]
    safe = jnp.where(valid, ids, 0)
    lane = jnp.where(valid, jnp.arange(B, dtype=jnp.int32), B)
    winner = jnp.full((state.capacity,), B, jnp.int32).at[safe].min(lane)
    first = valid & (winner[safe] == lane)
    n_dead = jnp.sum(first).astype(jnp.int32)
    alive = state.alive.at[safe].min(~valid)
    return dataclasses.replace(state, alive=alive, size=state.size - n_dead)


def _finalize_removal(
    state: GraphState, ids: jax.Array, valid: jax.Array
) -> GraphState:
    dead = _dead_mask(state, ids, valid)
    state = scrub_edges_to(state, dead)
    # slots already counted out of `size` by _mark_dead; free presence only
    safe = jnp.where(valid, ids, 0)
    present = state.present.at[safe].min(~valid)  # collision-safe scatter
    # freed slots scrub their compressed codes (invariant I5): `vectors`
    # keeps stale bytes but codes/scales return to the empty-slot encoding.
    # The dead boolean mask + where is immune to duplicate/parked lanes.
    return dataclasses.replace(
        state,
        present=present,
        codes=jnp.where(dead[:, None], 0, state.codes),
        scales=jnp.where(dead, 0.0, state.scales),
        stamps=jnp.where(dead, -1, state.stamps),  # invariant I6
        touch=jnp.where(dead, -1, state.touch),    # invariant I7
    )


# ---------------------------------------------------------------------------
# PURE (Alg 4)
# ---------------------------------------------------------------------------

def delete_pure(
    state: GraphState, ids: jax.Array, valid: jax.Array, key, params: IndexParams
) -> GraphState:
    del key
    valid = _precheck(state, ids, valid)
    state = _mark_dead(state, ids, valid)
    return _finalize_removal(state, ids, valid)


# ---------------------------------------------------------------------------
# MASK (§5.2)
# ---------------------------------------------------------------------------

def delete_mask(
    state: GraphState, ids: jax.Array, valid: jax.Array, key, params: IndexParams
) -> GraphState:
    del key
    valid = _precheck(state, ids, valid)
    return _mark_dead(state, ids, valid)  # present stays True: tombstone


# ---------------------------------------------------------------------------
# LOCAL (Alg 5)
# ---------------------------------------------------------------------------

def _local_repair_plan(
    state: GraphState, ids: jax.Array, valid: jax.Array, dead: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Alg 5 lines 3–6 for the whole batch: which edge each surviving
    in-neighbor u of deleted x splices in. Returns (u, x, z, valid) flats of
    length B·d_in. Shared by the vectorized and reference appliers."""
    B, d_in, d_out = ids.shape[0], state.d_in, state.d_out

    safe_ids = jnp.where(valid, ids, 0)
    in_nbrs = state.radj[safe_ids]                     # i32[B, d_in]  the u's
    out_nbrs = state.adj[safe_ids]                     # i32[B, d_out] candidates

    u_flat = in_nbrs.reshape(-1)                       # [B*d_in]
    x_flat = jnp.repeat(safe_ids, d_in)                # deleted vertex per unit
    # each deletion's candidate row, repeated once per its d_in in-neighbor slot
    c_flat = jnp.broadcast_to(
        out_nbrs[:, None, :], (B, d_in, d_out)
    ).reshape(B * d_in, d_out)
    u_valid = (u_flat != NULL) & jnp.repeat(valid, d_in)
    su = jnp.where(u_valid, u_flat, 0)
    # u must itself survive (not in the delete batch)
    u_valid = u_valid & ~dead[su] & state.present[su]

    def pick_one(u, cands, uv):
        """SELECT-NEIGHBORS(u, N(x), 1, N(u) ∪ {u}) — Alg 5 line 6."""
        exclude = jnp.concatenate([state.adj[u], u[None]])
        cv = (cands != NULL) & ~dead[jnp.maximum(cands, 0)]
        cv = cv & state.alive[jnp.maximum(cands, 0)]
        cv = cv & ~jnp.any(cands[:, None] == exclude[None, :], axis=1)
        picked = select.select_neighbors(
            state.vectors[u], cands, state.vectors[jnp.maximum(cands, 0)],
            cv & uv, 1, state.metric,
        )
        return picked[0]

    z_flat = jax.vmap(pick_one)(su, c_flat, u_valid)   # i32[B*d_in]
    return u_flat, x_flat, z_flat, u_valid


def _splice_apply(
    state: GraphState, dead: jax.Array,
    u_flat: jax.Array, z_flat: jax.Array, u_valid: jax.Array,
) -> GraphState:
    """Vectorized one-edge-splice applier shared by LOCAL and RWALK: group
    the planned additions per surviving row u, drop each row's dying
    entries, and apply through one ``set_out_edges_batch`` scatter."""
    cap, d_out = state.capacity, state.d_out

    # group the planned additions per surviving row u (each u holds ≤ d_out
    # lanes — one per deleted out-neighbor)
    adds, touched_u = group_by_destination(
        z_flat, u_flat, u_valid & (z_flat != NULL), cap, d_out
    )
    # compact frame over the ≤ B·d_in rows that actually gain an edge
    R_u = min(u_flat.shape[0], cap)
    _, uid = jax.lax.top_k(touched_u.astype(jnp.int32), R_u)
    u_ok = touched_u[uid]
    uv = jnp.where(u_ok, uid, 0).astype(jnp.int32)
    adds_rows = adds[uv]                                  # [R_u, d_out]
    # dedup additions within a row (several x's may pick the same z for u)
    eqa = (adds_rows[:, :, None] == adds_rows[:, None, :]) \
        & (adds_rows != NULL)[:, :, None]
    first = jnp.argmax(eqa, axis=2) == jnp.arange(d_out)[None, :]
    adds_rows = jnp.where(first, adds_rows, NULL)
    old_rows = state.adj[uv]
    # drop additions already present in u's row ("already there" = success)
    dup = jnp.any(adds_rows[:, :, None] == old_rows[:, None, :], axis=2)
    adds_rows = jnp.where(dup, NULL, adds_rows)

    # new row = (old row minus the dying x entries) ++ additions, truncated
    # at d_out in that order — matching the sequential remove-then-add order
    old_rows = jnp.where(
        (old_rows != NULL) & dead[jnp.maximum(old_rows, 0)], NULL, old_rows
    )
    packed = pack_rows(jnp.concatenate([old_rows, adds_rows], axis=1))
    return set_out_edges_batch(state, uid, packed[:, :d_out], u_ok)


def _splice_apply_reference(
    state: GraphState,
    u_flat: jax.Array, x_flat: jax.Array, z_flat: jax.Array,
    u_valid: jax.Array,
) -> GraphState:
    """Sequential splice applier (parity oracle for ``_splice_apply``):
    remove (u → x) first (frees the row slot), then add (u → z)."""
    def body(i, st):
        def splice(s):
            s = remove_edge(s, u_flat[i], x_flat[i])
            return jax.lax.cond(
                z_flat[i] != NULL,
                lambda s2: add_edge(s2, u_flat[i], z_flat[i]),
                lambda s2: s2,
                s,
            )
        return jax.lax.cond(u_valid[i], splice, lambda s: s, st)

    return jax.lax.fori_loop(0, u_flat.shape[0], body, state)


def _local_repair_apply(
    state: GraphState, ids: jax.Array, valid: jax.Array, dead: jax.Array,
    key, params: IndexParams,
) -> GraphState:
    """LOCAL plan + vectorized applier: splices grouped per u, one scatter.

    Shared by ``delete_local`` and the consolidation pass (DESIGN.md §8) —
    the ``dead`` mask is the caller's batch, which for consolidation is a
    chunk of tombstones rather than freshly marked deletions.
    """
    del key, params
    u_flat, _, z_flat, u_valid = _local_repair_plan(state, ids, valid, dead)
    return _splice_apply(state, dead, u_flat, z_flat, u_valid)


def delete_local(
    state: GraphState, ids: jax.Array, valid: jax.Array, key, params: IndexParams
) -> GraphState:
    """LOCAL with the vectorized applier: splices grouped per u, one scatter."""
    valid = _precheck(state, ids, valid)
    state = _mark_dead(state, ids, valid)
    dead = _dead_mask(state, ids, valid)
    state = _local_repair_apply(state, ids, valid, dead, key, params)
    return _finalize_removal(state, ids, valid)


def delete_local_reference(
    state: GraphState, ids: jax.Array, valid: jax.Array, key, params: IndexParams
) -> GraphState:
    """LOCAL with the pre-refactor sequential applier (parity oracle)."""
    del key
    valid = _precheck(state, ids, valid)
    state = _mark_dead(state, ids, valid)
    dead = _dead_mask(state, ids, valid)
    u_flat, x_flat, z_flat, u_valid = _local_repair_plan(state, ids, valid, dead)
    state = _splice_apply_reference(state, u_flat, x_flat, z_flat, u_valid)
    return _finalize_removal(state, ids, valid)


# ---------------------------------------------------------------------------
# GLOBAL (Alg 6) — the paper's recommended strategy
# ---------------------------------------------------------------------------

def _global_repair_plan(
    state: GraphState,
    ids: jax.Array,
    valid: jax.Array,
    dead: jax.Array,
    key,
    params: IndexParams,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Alg 6 lines 3–6 for the whole batch: the unique surviving in-neighbors
    and their wholesale replacement rows. Returns (u_flat, u_valid,
    new_nbrs). Shared by the vectorized and reference appliers."""
    B, d_in = ids.shape[0], state.d_in

    # ---- collect the unique surviving in-neighbors of the whole batch ----
    safe_ids = jnp.where(valid, ids, 0)
    u_flat = state.radj[safe_ids].reshape(-1)          # [B*d_in]
    u_valid = (u_flat != NULL) & jnp.repeat(valid, d_in)
    su = jnp.where(u_valid, u_flat, 0)
    u_valid = u_valid & ~dead[su] & state.alive[su]
    # dedupe (first occurrence wins) — a u may point at several deleted x's
    eq = u_flat[:, None] == u_flat[None, :]
    eq = eq & u_valid[None, :] & u_valid[:, None]
    first = jnp.argmax(eq, axis=1) == jnp.arange(u_flat.shape[0])
    u_valid = u_valid & first
    su = jnp.where(u_valid, u_flat, 0)

    # ---- batched repair search: GREEDY-SEARCH(u, G, k) on the marked graph,
    # all B·d_in in-neighbors through ONE batched beam-engine call (the same
    # compiled program the query path runs — §6.2's "repair cost in units of
    # queries" is now literal) ----
    sp = params.eff_insert_search
    u_vecs = state.vectors[su]
    starts = search.batch_entry_points(
        state, key, u_flat.shape[0], sp.num_starts
    )
    res = search.beam_search(
        state, u_vecs, starts, sp
    )  # alive-only candidates — deleted batch is already non-alive

    # ---- SELECT-NEIGHBORS(u, C, d, {x_i}) ----
    new_nbrs = jax.vmap(
        lambda u, vec, cids: select.select_from_pool(
            state, vec, cids, params.d_out, exclude=u[None]
        )
    )(su, u_vecs, res.ids)                              # i32[B*d_in, d_out]
    return u_flat, u_valid, new_nbrs


def _global_repair_apply(
    state: GraphState, ids: jax.Array, valid: jax.Array, dead: jax.Array,
    key, params: IndexParams,
) -> GraphState:
    """GLOBAL plan + vectorized applier: wholesale row replacement of every
    repaired u in one ``set_out_edges_batch`` scatter. Shared by
    ``delete_global`` and the consolidation pass (DESIGN.md §8)."""
    u_flat, u_valid, new_nbrs = _global_repair_plan(
        state, ids, valid, dead, key, params
    )
    return set_out_edges_batch(state, u_flat, new_nbrs, u_valid)


def delete_global(
    state: GraphState, ids: jax.Array, valid: jax.Array, key, params: IndexParams
) -> GraphState:
    """GLOBAL with the vectorized applier: wholesale row replacement of every
    repaired u in one ``set_out_edges_batch`` scatter."""
    valid = _precheck(state, ids, valid)
    state = _mark_dead(state, ids, valid)
    dead = _dead_mask(state, ids, valid)
    state = _global_repair_apply(state, ids, valid, dead, key, params)
    return _finalize_removal(state, ids, valid)


def delete_global_reference(
    state: GraphState, ids: jax.Array, valid: jax.Array, key, params: IndexParams
) -> GraphState:
    """GLOBAL with the pre-refactor sequential applier (parity oracle)."""
    valid = _precheck(state, ids, valid)
    state = _mark_dead(state, ids, valid)
    dead = _dead_mask(state, ids, valid)
    u_flat, u_valid, new_nbrs = _global_repair_plan(
        state, ids, valid, dead, key, params
    )

    def body(i, st):
        def repair(s):
            return set_out_edges(s, u_flat[i], new_nbrs[i])
        return jax.lax.cond(u_valid[i], repair, lambda s: s, st)

    state = jax.lax.fori_loop(0, u_flat.shape[0], body, state)
    return _finalize_removal(state, ids, valid)


# ---------------------------------------------------------------------------
# RWALK — random-walk replacement wiring (Mishra et al. 2025, PAPERS.md)
# ---------------------------------------------------------------------------

def _rwalk_walk_params(params: IndexParams):
    """The short-walk search budget: a few steps of the beam engine at
    beam_width=1 (the classic walk) over a small pool. Static under jit —
    built from the frozen param dataclasses at trace time."""
    mp = params.maintenance
    return dataclasses.replace(
        params.eff_insert_search,
        pool_size=mp.rwalk_pool,
        max_steps=mp.rwalk_steps,
        num_starts=min(mp.rwalk_starts, mp.rwalk_pool),
        beam_width=1,
        rerank_depth=0,
    )


def _rwalk_repair_plan(
    state: GraphState,
    ids: jax.Array,
    valid: jax.Array,
    dead: jax.Array,
    key,
    params: IndexParams,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Random-walk replacement plan: for each surviving in-neighbor u of a
    deleted x, short walks seeded at a random subset of x's out-neighborhood
    (the walk origins) run through the batched beam engine guided by u's
    vector; ONE replacement edge u → z is then picked from the walk pool.
    Returns (u, x, z, valid) flats of length B·d_in — the same contract as
    ``_local_repair_plan``, so both strategies share the splice appliers."""
    B, d_in, d_out = ids.shape[0], state.d_in, state.d_out
    mp = params.maintenance

    safe_ids = jnp.where(valid, ids, 0)
    in_nbrs = state.radj[safe_ids]                     # i32[B, d_in]  the u's
    out_nbrs = state.adj[safe_ids]                     # i32[B, d_out] origins
    u_flat = in_nbrs.reshape(-1)                       # [B*d_in]
    x_flat = jnp.repeat(safe_ids, d_in)                # deleted vertex per lane
    c_flat = jnp.broadcast_to(
        out_nbrs[:, None, :], (B, d_in, d_out)
    ).reshape(B * d_in, d_out)
    u_valid = (u_flat != NULL) & jnp.repeat(valid, d_in)
    su = jnp.where(u_valid, u_flat, 0)
    # u must itself survive (not in the delete batch)
    u_valid = u_valid & ~dead[su] & state.present[su]

    # ---- walk origins: a Gumbel-top-k random subset of x's out-neighbors,
    # per lane (fold_in by lane index — same per-lane key discipline as
    # batch_entry_points). Dead-but-present origins are allowed: the delete
    # batch stays traversable until _finalize_removal, exactly like the
    # GLOBAL repair search.
    S = max(1, min(mp.rwalk_starts, d_out))
    n_lanes = u_flat.shape[0]
    lane_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(n_lanes, dtype=jnp.int32)
    )

    def origins(k_i, cands):
        cv = cands != NULL
        cv = cv & state.present[jnp.where(cv, cands, 0)]
        g = jax.random.gumbel(k_i, (d_out,))
        _, idx = jax.lax.top_k(jnp.where(cv, g, -jnp.inf), S)
        return jnp.where(cv[idx], cands[idx], NULL).astype(jnp.int32)

    starts = jax.vmap(origins)(lane_keys, c_flat)      # i32[B*d_in, S]

    # ---- short walks through the batched beam engine, ONE call for all
    # B·d_in lanes — raw pools (tombstones steer but never get selected)
    wp = _rwalk_walk_params(params)
    u_vecs = state.vectors[su]
    res = search.beam_search(state, u_vecs, starts, wp, raw=True)

    # ---- one replacement per u: diverse pick from the walk pool, never an
    # existing neighbor, never u itself, alive targets only (excludes the
    # delete batch and tombstones)
    def pick_one(u, vec, cids):
        exclude = jnp.concatenate([state.adj[u], u[None]])
        picked = select.select_from_pool(
            state, vec, cids, 1, exclude=exclude, keep_pruned=False
        )
        return picked[0]

    z_flat = jax.vmap(pick_one)(su, u_vecs, res.ids)   # i32[B*d_in]
    z_flat = jnp.where(u_valid, z_flat, NULL)
    return u_flat, x_flat, z_flat, u_valid


def _rwalk_repair_apply(
    state: GraphState, ids: jax.Array, valid: jax.Array, dead: jax.Array,
    key, params: IndexParams,
) -> GraphState:
    """RWALK plan + vectorized splice applier (shared with LOCAL). Shared by
    ``delete_rwalk`` and the consolidation pass (DESIGN.md §8)."""
    u_flat, _, z_flat, u_valid = _rwalk_repair_plan(
        state, ids, valid, dead, key, params
    )
    return _splice_apply(state, dead, u_flat, z_flat, u_valid)


def delete_rwalk(
    state: GraphState, ids: jax.Array, valid: jax.Array, key, params: IndexParams
) -> GraphState:
    """RWALK with the vectorized applier: splices grouped per u, one scatter."""
    valid = _precheck(state, ids, valid)
    state = _mark_dead(state, ids, valid)
    dead = _dead_mask(state, ids, valid)
    state = _rwalk_repair_apply(state, ids, valid, dead, key, params)
    return _finalize_removal(state, ids, valid)


def delete_rwalk_reference(
    state: GraphState, ids: jax.Array, valid: jax.Array, key, params: IndexParams
) -> GraphState:
    """RWALK with the sequential splice applier (parity oracle)."""
    valid = _precheck(state, ids, valid)
    state = _mark_dead(state, ids, valid)
    dead = _dead_mask(state, ids, valid)
    u_flat, x_flat, z_flat, u_valid = _rwalk_repair_plan(
        state, ids, valid, dead, key, params
    )
    state = _splice_apply_reference(state, u_flat, x_flat, z_flat, u_valid)
    return _finalize_removal(state, ids, valid)


# the vectorized repair appliers, keyed the way the consolidation pass
# (core/consolidate.py) selects them; signature (state, ids, valid, dead,
# key, params) → state — the ``dead`` mask is supplied by the caller so the
# same appliers serve freshly marked deletions and long-lived tombstones
REPAIR_APPLIERS = {
    "local": _local_repair_apply,
    "global": _global_repair_apply,
    "rwalk": _rwalk_repair_apply,
}

_STRATEGY_FNS = {
    "pure": delete_pure,
    "mask": delete_mask,
    "local": delete_local,
    "global": delete_global,
    "rwalk": delete_rwalk,
    "local_reference": delete_local_reference,
    "global_reference": delete_global_reference,
    "rwalk_reference": delete_rwalk_reference,
}


@functools.partial(
    jax.jit, static_argnames=("strategy", "params"), donate_argnums=(0,)
)
def delete_batch(
    state: GraphState,
    ids: jax.Array,       # i32[B]
    valid: jax.Array,     # bool[B]
    key: jax.Array,
    strategy: str,
    params: IndexParams,
) -> GraphState:
    return _STRATEGY_FNS[strategy](state, ids, valid, key, params)
