"""IPGM core — the paper's contribution as a composable JAX module."""
from repro.core.graph import NULL, GraphState, graph_stats, init_graph
from repro.core.maintenance import IPGMIndex, run_workload
from repro.core.params import IndexParams, SearchParams

__all__ = [
    "NULL",
    "GraphState",
    "graph_stats",
    "init_graph",
    "IPGMIndex",
    "run_workload",
    "IndexParams",
    "SearchParams",
]
