"""IPGM core — the paper's contribution as a composable JAX module.

NOTE: the consolidation engine's functions live in ``repro.core.consolidate``
and are intentionally NOT re-exported here — binding the ``consolidate``
function at package level would shadow the submodule of the same name and
break ``from repro.core import consolidate as consolidate_mod`` imports.
"""
from repro.core.graph import NULL, GraphState, graph_stats, init_graph
from repro.core.maintenance import IPGMIndex, run_workload
from repro.core.ops import OpBatch, apply_ops, apply_ops_step
from repro.core.merge import StreamingMerge
from repro.core.params import IndexParams, MaintenanceParams, SearchParams
from repro.core.session import OpHandle, PhaseTimers, Session
from repro.core.tiered import TieredOpHandle, TieredSession

__all__ = [
    "NULL",
    "GraphState",
    "graph_stats",
    "init_graph",
    "IPGMIndex",
    "run_workload",
    "IndexParams",
    "MaintenanceParams",
    "SearchParams",
    "Session",
    "StreamingMerge",
    "TieredOpHandle",
    "TieredSession",
    "OpHandle",
    "OpBatch",
    "PhaseTimers",
    "apply_ops",
    "apply_ops_step",
]
