"""IPGM core — the paper's contribution as a composable JAX module."""
from repro.core.graph import NULL, GraphState, graph_stats, init_graph
from repro.core.maintenance import IPGMIndex, run_workload
from repro.core.ops import OpBatch, apply_ops, apply_ops_step
from repro.core.params import IndexParams, MaintenanceParams, SearchParams
from repro.core.session import OpHandle, PhaseTimers, Session

__all__ = [
    "NULL",
    "GraphState",
    "graph_stats",
    "init_graph",
    "IPGMIndex",
    "run_workload",
    "IndexParams",
    "MaintenanceParams",
    "SearchParams",
    "Session",
    "OpHandle",
    "OpBatch",
    "PhaseTimers",
    "apply_ops",
    "apply_ops_step",
]
