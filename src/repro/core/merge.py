"""Streaming merge — the fresh→main drain engine of the two-tier index.

A :class:`~repro.core.tiered.TieredSession` routes every mutation to a small
fresh tier and accumulates deletes of main-resident points as tombstones in
the main tier's MASK bitmap (DESIGN.md §12). :class:`StreamingMerge` is the
third maintenance op (alongside consolidate §8 and grow §9) that keeps that
arrangement sustainable on an unbounded stream: it moves a *snapshot* of the
fresh tier into main in bounded chunks, reclaiming main tombstones on the
way, while both tiers keep serving — queries fan out and deduplicate against
the pre-merge snapshot until the per-item tier swap retires the drained
copies.

Phases (each ``step()`` call performs ONE bounded chunk of work, so query
service never pauses longer than one chunk — the tiered session "pumps" one
step per insert/delete while a merge is active; queries and flushes never
pump, so fan-out latency stays flat and flush stays idempotent):

  1. **compact** — exactly ``ceil(n0/chunk)`` OP_CONSOLIDATE micro-batches
     on the main tier, where ``n0`` is main's tombstone count at merge
     start. Reuses the §8 compaction path verbatim (lowest-id tombstones
     first); tombstones that arrive mid-merge may be swept opportunistically
     by later chunks, any remainder waits for the next merge.
  2. **drain** — snapshot items (host-copied vectors, age-ordered by their
     insertion stamps, invariant I6) are appended to main through the
     batched insert applier. Room is made by growing main's capacity tier
     when armed; when growth is capped out the drain stops early and the
     undrained suffix simply stays in the fresh tier ("capped" merge).
     Each drained item becomes resident in *both* tiers — queries dedupe by
     external id, so the visible result set never changes.
  3. **swap** — the drained items' fresh slots are released through the
     fresh tier's delete applier, chunk by chunk. An item's authoritative
     copy moves atomically (per item) from fresh to main: it is reachable
     in at least one tier at every instant.

Determinism (DESIGN.md §11/§12): every device call uses the merge PRNG
chain — ``fold_in(fold_in(base, MERGE_KEY_STREAM), merge_counter)`` — never
either tier's op-key chain, so *when* a merge runs can never shift the
results of the logical op stream. Merge progress itself is a pure function
of the acknowledged mutation stream (auto-start gate + one pump per
insert/delete), which is what lets crash recovery replay a journal suffix
and land bit-exactly in the middle of a merge.

The merge's cross-layer wiring — its key stream, JR_MERGE journal code and
cseq dedup counter, checkpoint-counter contract, and the crash points fired
below (``merge-begin``, ``merge-compact-step``, ``merge-drain-step``,
``pre-merge-swap``, ``post-merge-swap``) — is declared once on the MERGE
entry of the maintenance-op registry (``core/maint.py``, DESIGN.md §14);
``faults.TIERED_CRASH_POINTS`` is generated from it.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ops as ops_mod
from repro.core.graph import NULL, next_capacity_tier
from repro.core.session import OpHandle
from repro.testing import faults

# phase tags, in execution order
COMPACT, DRAIN, SWAP, DONE = "compact", "drain", "swap", "done"


class StreamingMerge:
    """One in-flight fresh→main merge over a fixed start-of-merge snapshot.

    Owned and driven by a ``TieredSession``; not meaningful standalone. The
    constructor takes the snapshot (synchronizing on the fresh tier's
    arrays); each ``step()`` performs one chunk of compact/drain/swap work
    and returns whether the merge is finished.
    """

    def __init__(self, owner) -> None:
        faults.crash_point("merge-begin")
        self.owner = owner
        fresh, main = owner._fresh, owner._main
        fm, mm = owner._fm, owner._mm
        self.chunk = owner._merge_chunk
        # --- snapshot: every fresh-resident item, oldest first (I6) ---
        slots = np.flatnonzero(fm.present).astype(np.int32)
        stamps = np.asarray(fresh.state.stamps)[slots]
        order = np.argsort(stamps, kind="stable")
        self.slots = slots[order]                       # fresh slot per item
        self.exts = fm.ext[self.slots].copy()           # external id per item
        self.vecs = np.asarray(fresh.state.vectors)[self.slots].copy()
        # --- compact plan: fixed at merge start (chunk count, not slot set —
        # each chunk sweeps whatever the lowest-id tombstones are *then*) ---
        n0 = int(np.sum(mm.masked))
        self._compact_left = -(-n0 // self.chunk) if n0 else 0
        self._consolidate_batch = ops_mod.make_op(
            ops_mod.OP_CONSOLIDATE, self.chunk, main.params.dim)
        self.phase = COMPACT if self._compact_left else DRAIN
        self._ptr = 0                  # next snapshot item to consider
        self._swap_ptr = 0             # next drained item to swap out
        self.cancelled: set[int] = set()   # exts deleted before their drain
        self.drained: list[tuple[int, int]] = []  # (ext, fresh_slot)
        self.capped = False            # main filled up; suffix stays fresh
        self.n_drained = 0

    @property
    def done(self) -> bool:
        return self.phase == DONE

    # -- the one-chunk work unit -------------------------------------------
    def step(self) -> bool:
        """Perform one bounded chunk of merge work. Returns ``done``."""
        if self.phase == DONE:
            return True
        t0 = time.perf_counter()
        if self.phase == COMPACT:
            self._compact_step()
        elif self.phase == DRAIN:
            self._drain_step()
        elif self.phase == SWAP:
            self._swap_step()
        self.owner.timers.merge_s += time.perf_counter() - t0
        return self.phase == DONE

    def run(self) -> None:
        """Drive the merge to completion (the save/catch-up barrier)."""
        while not self.step():
            pass

    # -- phase 1: main-tier tombstone compaction ---------------------------
    def _compact_step(self) -> None:
        owner, main, mm = self.owner, self.owner._main, self.owner._mm
        key = owner._merge_key()
        main._state, ids, scores = ops_mod.apply_ops_step(
            main._state, self._consolidate_batch, key, main.params,
            main.strategy, static_op=ops_mod.OP_CONSOLIDATE,
        )
        # mirror the device's pick exactly: the chunk's lowest-id tombstones
        freed = np.flatnonzero(mm.masked)[: self.chunk]
        mm.masked[freed] = False
        mm.present[freed] = False
        n = len(freed)
        h = OpHandle("consolidate", n, main.params.search.pool_size,
                     [(ids, scores, n)], on_done=main._handle_done)
        main._pending.append(h)
        self._compact_left -= 1
        if self._compact_left == 0:
            self.phase = DRAIN
        faults.crash_point("merge-compact-step")

    # -- phase 2: fresh→main drain -----------------------------------------
    def _next_drain_batch(self) -> np.ndarray:
        """Indices of the next ≤chunk snapshot items still worth draining."""
        sel = []
        while self._ptr < len(self.slots) and len(sel) < self.chunk:
            if int(self.exts[self._ptr]) not in self.cancelled:
                sel.append(self._ptr)
            self._ptr += 1
            if len(sel) == self.chunk:
                break
        return np.asarray(sel, np.int64)

    def _drain_step(self) -> None:
        owner, main, mm = self.owner, self.owner._main, self.owner._mm
        sel = self._next_drain_batch()
        n = len(sel)
        if n == 0:
            self._enter_swap()
            return
        # room in main: compact already ran, so grow the tier (when armed)
        free = int(mm.capacity - np.sum(mm.present))
        if free < n:
            mp = owner.params.maintenance
            cap = main.state.capacity
            target = next_capacity_tier(
                cap, cap - free + n, mp.growth_factor, mp.max_capacity)
            if target > cap:
                main.grow(target, _auto=True)
                mm.grow(target)
                free += target - cap
        if free < n:
            if free == 0:
                # main is capped out: the undrained suffix stays fresh
                self.capped = True
                self._ptr = len(self.slots)
                self._enter_swap()
                return
            self._ptr = int(sel[free])  # re-consider the overflow next step
            sel = sel[:free]
            n = free
        batch = ops_mod.make_op(
            ops_mod.OP_INSERT, self.chunk, main.params.dim,
            payload=self.vecs[sel])
        key = owner._merge_key()
        main._state, ids, scores = ops_mod.apply_ops_step(
            main._state, batch, key, main.params, main.strategy,
            static_op=None if main.unified_dispatch else ops_mod.OP_INSERT,
        )
        # host mirror of the batched allocator: i-th valid row → i-th lowest
        # free slot (insert.py phase 1); room was ensured above, no refusals
        mslots = np.flatnonzero(~mm.present)[:n]
        exts = self.exts[sel]
        mm.present[mslots] = True
        mm.ext[mslots] = exts
        owner._ext_snap_dirty()
        for i, (e, ms) in enumerate(zip(exts, mslots)):
            fs = int(self.slots[sel[i]])
            owner._loc[int(e)] = ("both", fs, int(ms))
            owner._both_set.add(int(e))
            self.drained.append((int(e), fs))
        h = OpHandle("insert", n, main.params.search.pool_size,
                     [(ids, scores, n)], on_done=main._handle_done)
        main._pending.append(h)
        self.n_drained += n
        owner.timers.n_merged += n
        faults.crash_point("merge-drain-step")

    # -- phase 3: per-item tier swap ---------------------------------------
    def _enter_swap(self) -> None:
        self.phase = SWAP
        faults.crash_point("pre-merge-swap")

    def _swap_step(self) -> None:
        owner, fresh, fm = self.owner, self.owner._fresh, self.owner._fm
        # items deleted while "both" already left both tiers — skip them
        sel = []
        while self._swap_ptr < len(self.drained) and len(sel) < self.chunk:
            ext, fslot = self.drained[self._swap_ptr]
            self._swap_ptr += 1
            loc = owner._loc.get(ext)
            if loc is not None and loc[0] == "both" and loc[1] == fslot:
                sel.append((ext, fslot, loc[2]))
        if sel:
            fslots = np.asarray([s[1] for s in sel], np.int32)
            batch = ops_mod.make_op(
                ops_mod.OP_DELETE, self.chunk, fresh.params.dim, ids=fslots)
            key = owner._merge_key()
            fresh._state, ids, scores = ops_mod.apply_ops_step(
                fresh._state, batch, key, fresh.params, fresh.strategy,
                static_op=None if fresh.unified_dispatch
                else ops_mod.OP_DELETE,
            )
            fm.present[fslots] = False
            owner._fbias[fslots] = -np.inf
            fm.ext[fslots] = NULL
            owner._ext_snap_dirty()
            for ext, _, mslot in sel:
                owner._loc[ext] = ("main", mslot)
                owner._both_set.discard(ext)
            h = OpHandle("delete", len(sel), fresh.params.search.pool_size,
                         [(ids, scores, len(sel))],
                         on_done=fresh._handle_done)
            fresh._pending.append(h)
        if self._swap_ptr >= len(self.drained):
            self.phase = DONE
            owner._merges_done += 1
            owner.timers.n_merges += 1
            faults.crash_point("post-merge-swap")
