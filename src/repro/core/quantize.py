"""Per-slot int8 vector codes — the compressed-scoring storage scheme.

The hot loop (beam expansion) reads fp32 rows from ``vectors[capacity, d]``;
serving the walk on int8 codes instead moves ~4x fewer bytes per candidate
(FreshDiskANN's compressed-first/exact-rerank split — DESIGN.md §10). The
scheme is the simplest one that keeps a checkable transactional invariant:

  · per-row symmetric max-abs scaling: ``scale = max|x| / 127``,
    ``code = round(x / scale)`` (round-half-even, the IEEE default) — a pure
    deterministic function of the row, unlike the *stochastic* gradient
    quantizer in ``distributed/compression.py`` (which trades determinism
    for unbiasedness; vector codes need the opposite trade so the invariant
    ``codes == quantize(vectors)`` is exactly re-checkable at any barrier);
  · a *present* all-zero row maps to (zero codes, ``ZERO_ROW_SCALE``) — a
    positive sentinel scale — while freed/never-used slots are scrubbed to
    (zero codes, ``0.0``) by the delete/consolidate/grow paths. The v1
    scheme mapped zero rows to scale 0.0 too, which made a legitimately
    inserted zero vector byte-identical to a freed slot: invariant I5
    became unable to distinguish live from dead, and any tooling keying on
    the scrub pattern would treat the row as deleted. The sentinel breaks
    the collision without perturbing a single score — the codes are all
    zero, so every metric's similarity below is exactly 0.0 no matter the
    scale (ip/cos: scale·0; l2: scale·(0 − scale·0));
  · asymmetric distance against an uncompressed fp32 query ``q``:
        ip/cos:  scale · <codes, q>
        l2:      scale · (2·<codes, q> − scale · Σ codes²)
    i.e. every metric's similarity evaluated on the dequantized row without
    materializing it (the ``Σ codes²`` term replaces the ``sqnorms`` cache).

``VECTOR_CODE_SCHEME`` names this scheme; it is folded into the checkpoint
fingerprint so a state whose codes were produced under a different scheme
can never be silently restored into an engine that scores them differently.
(The zero-row sentinel bumped it v1 → v2: v1 checkpoints hold codes whose
zero rows this engine would re-encode differently, failing I5's re-check.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

VECTOR_CODE_SCHEME = "int8-rowmax-rne-v2"

# Scale stamped on present all-zero rows: positive (distinguishes them from
# the freed-slot 0.0 scrub) and the smallest normal f32, so even an
# (impossible) nonzero code under it would contribute ~nothing to a score.
ZERO_ROW_SCALE = jnp.float32(2.0 ** -126)


def quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Deterministic per-row int8 quantization over the last axis.

    Returns ``(codes i8[..., d], scales f32[...])`` with
    ``codes · scale ≈ x`` (error ≤ scale/2 per element). Any leading batch
    shape is accepted — ``[capacity, d]`` states and the stacked
    ``[shards, capacity, d]`` layout of ``ShardedSession`` both work.
    """
    x32 = x.astype(jnp.float32)
    maxabs = jnp.max(jnp.abs(x32), axis=-1)
    # multiply by the f32-rounded reciprocal instead of dividing: XLA's
    # simplifier rewrites division-by-constant into exactly this multiply
    # inside jit, so spelling it out keeps jit and eager bit-identical —
    # which the re-checkable invariant I5 requires
    scales = maxabs * jnp.float32(1.0 / 127.0)
    # zero rows take the positive sentinel scale so a present zero vector
    # can never collide with the freed-slot (0 codes, 0.0 scale) scrub
    scales = jnp.where(maxabs > 0, scales, ZERO_ROW_SCALE)
    safe = jnp.where(maxabs > 0, scales, 1.0)
    codes = jnp.clip(jnp.round(x32 / safe[..., None]), -127, 127)
    return codes.astype(jnp.int8), scales


def dequantize_rows(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """f32[..., d] reconstruction ``codes · scale`` (test/debug helper)."""
    return codes.astype(jnp.float32) * scales[..., None]


def scores_vs_codes(
    codes: jax.Array,   # i8[..., d] gathered candidate codes
    scales: jax.Array,  # f32[...]
    q: jax.Array,       # f32[d] uncompressed query
    metric: str,
) -> jax.Array:
    """Asymmetric similarity of ``q`` vs each compressed row (higher=better).

    Matches ``distances.scores_vs_rows`` on the dequantized rows exactly in
    math (l2 as ``2<x,q> − ||x||²``), with ``||x̂||² = scale²·Σcodes²``
    computed from the codes — no fp32 row or sqnorm cache is touched.
    """
    c = codes.astype(jnp.float32)
    dots = jnp.einsum("...d,d->...", c, q.astype(jnp.float32))
    if metric == "l2":
        return scales * (2.0 * dots - scales * jnp.sum(c * c, axis=-1))
    return scales * dots
