"""Test-support utilities that ship with the library (not under tests/).

``repro.testing.faults`` is imported by production modules (session,
checkpoint manager, sharded session) to mark crash points — the hooks are
no-ops unless a fault plan is activated, so shipping them in-tree costs one
dict lookup per instrumented site and buys a deterministic kill-and-recover
harness (DESIGN.md §11).
"""
from repro.testing import faults

__all__ = ["faults"]
