"""Deterministic fault injection: named crash points + transient failures.

Production code marks the places where a real deployment can die — after a
journal append, mid-checkpoint-save, between consolidation passes — by
calling ``crash_point("name")``. With no plan activated the call is a dict
lookup and returns immediately, so the instrumentation is free in normal
runs. A test activates a :class:`FaultPlan` (via :func:`inject`) naming
which hit of which point should die; the site then raises
:class:`SimulatedCrash`, the test discards the session (a real crash would
discard the process), and recovery is exercised against whatever bytes were
durably on disk at that moment.

Two properties make the harness usable for bit-exactness matrices:

  · **determinism** — a plan is data (point name → 1-based hit ordinal, or
    a seeded schedule drawn by :func:`random_plan`), never wall-clock or
    real randomness, so a failing matrix cell replays exactly;
  · **closed registry** — ``crash_point`` rejects names not in
    :data:`CRASH_POINTS`, so a typo in production instrumentation fails
    loudly in any test that activates *any* plan, and the matrix test can
    enumerate every registered point knowing the list is exhaustive.

Transient (retryable) failures are separate: ``transient_point(site)``
raises :class:`TransientDispatchError` for the first ``k`` hits of a site,
which ``Session.flush`` absorbs with bounded retry/backoff.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
from typing import Iterator

from repro.core import maint as _maint

# Every name production code may pass to crash_point(). Grouped by tier;
# tests/benchmarks import SESSION_CRASH_POINTS for the single-process
# recovery matrix and SHARDED_CRASH_POINTS for the distributed tier.
#
# Maintenance-op kill sites are *generated* from the maintenance-op registry
# (core/maint.py): an op declares its crash points once and they join the
# closed registry — and thereby the recovery crash matrix — here, without
# hand-listing. The infrastructure sites (journal/flush/dispatch/checkpoint)
# are not maintenance ops and stay listed explicitly. For consolidate that
# yields ("pre-consolidate", "post-consolidate"), grow ("pre-grow",
# "post-grow"), refine ("refine-begin", "refine-step"), merge the five
# merge-phase points — see each op's entry for per-point semantics.
SESSION_CRASH_POINTS = (
    "post-journal-append",    # record durable, device never saw the op
    "pre-flush",              # flush requested, nothing synced yet
    "post-flush",             # host/device synced, timers not yet settled
    *_maint.crash_points("session"),
    "mid-checkpoint-save",    # shards written, manifest/publish pending
    "post-checkpoint-save",   # checkpoint published, journal not truncated
)
SHARDED_CRASH_POINTS = (
    "sharded-pre-dispatch",   # per-shard op batch built, not dispatched
    "sharded-post-dispatch",  # mesh program ran, handles not retired
    *_maint.sharded_crash_points(),
)
TIERED_CRASH_POINTS = _maint.crash_points("tiered")
CRASH_POINTS = (SESSION_CRASH_POINTS + SHARDED_CRASH_POINTS
                + TIERED_CRASH_POINTS)
_CRASH_POINT_SET = frozenset(CRASH_POINTS)


class SimulatedCrash(RuntimeError):
    """Raised at an armed crash point.

    Simulates a process kill: the test must treat the session object as
    dead (device buffers lost) and recover from disk only. The exception
    unwinds normally, so unlike a real ``kill -9`` any ``finally`` blocks
    run — instrumented sites therefore never put durability-critical work
    in cleanup handlers.
    """


class TransientDispatchError(RuntimeError):
    """A retryable dispatch failure (simulated device/runtime hiccup)."""


@dataclasses.dataclass
class FaultPlan:
    """What dies where. Pure data; activation is via :func:`inject`.

    ``crashes``  maps crash-point name → 1-based hit ordinal at which that
    point raises. ``transients`` maps a transient site name → number of
    consecutive initial hits that fail with TransientDispatchError.
    """

    crashes: dict[str, int] = dataclasses.field(default_factory=dict)
    transients: dict[str, int] = dataclasses.field(default_factory=dict)
    # runtime bookkeeping (reset on activation)
    hits: dict[str, int] = dataclasses.field(default_factory=dict)
    log: list[str] = dataclasses.field(default_factory=list)

    def _bump(self, name: str) -> int:
        n = self.hits.get(name, 0) + 1
        self.hits[name] = n
        return n


_lock = threading.Lock()
_active: FaultPlan | None = None


def active_plan() -> FaultPlan | None:
    return _active


def crash_point(name: str) -> None:
    """Mark a named kill site. No-op unless an armed plan targets it."""
    if name not in _CRASH_POINT_SET:
        raise ValueError(f"unregistered crash point {name!r}")
    plan = _active
    if plan is None:
        return
    with _lock:
        n = plan._bump(name)
        armed = plan.crashes.get(name)
    if armed is not None and n == armed:
        plan.log.append(f"crash:{name}#{n}")
        raise SimulatedCrash(f"simulated crash at {name} (hit {n})")


def transient_point(site: str) -> None:
    """Mark a retryable-failure site (e.g. ``"flush"``)."""
    plan = _active
    if plan is None:
        return
    with _lock:
        remaining = plan.transients.get(site, 0)
        if remaining <= 0:
            return
        plan.transients[site] = remaining - 1
    plan.log.append(f"transient:{site}")
    raise TransientDispatchError(f"simulated transient failure at {site}")


@contextlib.contextmanager
def inject(plan: FaultPlan) -> Iterator[FaultPlan]:
    """Activate ``plan`` for the dynamic extent of the block.

    Plans do not nest (a second activation raises) — the matrix semantics
    depend on hit counts being attributable to exactly one plan.
    """
    global _active
    with _lock:
        if _active is not None:
            raise RuntimeError("a fault plan is already active")
        plan.hits = {}
        plan.log = []
        _active = plan
    try:
        yield plan
    finally:
        with _lock:
            _active = None


def crash_once(point: str, hit: int = 1) -> FaultPlan:
    """Plan that kills the process at the ``hit``-th arrival at ``point``."""
    if point not in _CRASH_POINT_SET:
        raise ValueError(f"unregistered crash point {point!r}")
    return FaultPlan(crashes={point: hit})


def transient(site: str, count: int = 1) -> FaultPlan:
    """Plan whose first ``count`` hits of ``site`` fail transiently."""
    return FaultPlan(transients={site: count})


def random_plan(
    seed: int,
    points: tuple[str, ...] = SESSION_CRASH_POINTS,
    max_hit: int = 4,
) -> FaultPlan:
    """Seeded schedule: one crash at a uniformly drawn (point, hit) cell.

    The draw is a pure function of ``seed`` — rerunning a failing seed
    reproduces the identical kill.
    """
    rng = random.Random(seed)
    point = points[rng.randrange(len(points))]
    return FaultPlan(crashes={point: rng.randrange(1, max_hit + 1)})
