"""Shared GNN-family shape cells + input-spec builders.

Four shape regimes (assignment):
  full_graph_sm  — cora-scale full batch  (N=2,708  E=10,556  F=1,433)
  minibatch_lg   — reddit-scale sampled   (N=232,965 E=114,615,892;
                   batch_nodes=1,024 fanout 15-10 → sampled block sizes)
  ogb_products   — products full batch    (N=2,449,029 E=61,859,140 F=100)
  molecule       — 128 merged small graphs (30 nodes / 64 edges each)

All cells are STATIC shapes; the sampled cell sizes are the padded block
sizes produced by data/graph_sampler.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.registry import ShapeCell, sds
from repro.models.gnn.common import GraphData

D_EDGE = 8
FANOUT = (15, 10)
BATCH_NODES = 1024

GNN_SIZES = {
    "full_graph_sm": dict(
        n_nodes=2_708, n_edges=10_556, d_feat=1_433, n_classes=7, n_graphs=1,
    ),
    "minibatch_lg": dict(
        # sampled subgraph: 1024 targets + 1024·15 hop-1 + 1024·150 hop-2
        n_nodes=BATCH_NODES * (1 + FANOUT[0] + FANOUT[0] * FANOUT[1]),
        n_edges=BATCH_NODES * FANOUT[0] * (1 + FANOUT[1]),
        d_feat=602, n_classes=41, n_graphs=1,
        batch_nodes=BATCH_NODES, fanout=FANOUT,
        full_nodes=232_965, full_edges=114_615_892,
    ),
    "ogb_products": dict(
        n_nodes=2_449_029, n_edges=61_859_140, d_feat=100, n_classes=47,
        n_graphs=1,
    ),
    "molecule": dict(
        n_nodes=30 * 128, n_edges=64 * 128, d_feat=16, n_classes=1,
        n_graphs=128,
    ),
}


def gnn_shapes() -> dict[str, ShapeCell]:
    return {
        name: ShapeCell(name, "train", dict(sizes))
        for name, sizes in GNN_SIZES.items()
    }


def _pad512(n: int) -> int:
    """Graph dims are padded to 512 multiples (divisible by every mesh) —
    the logical sizes stay exact; masks carry validity."""
    return -(-n // 512) * 512


def graph_specs(sizes: dict) -> GraphData:
    N, E, F = _pad512(sizes["n_nodes"]), _pad512(sizes["n_edges"]), sizes["d_feat"]
    G = sizes["n_graphs"]
    return GraphData(
        x=sds((N, F), jnp.float32),
        senders=sds((E,), jnp.int32),
        receivers=sds((E,), jnp.int32),
        node_mask=sds((N,), jnp.bool_),
        edge_mask=sds((E,), jnp.bool_),
        labels=sds((N,), jnp.int32),
        label_mask=sds((N,), jnp.bool_),
        positions=sds((N, 3), jnp.float32),
        edge_attr=sds((E, D_EDGE), jnp.float32),
        graph_ids=sds((N,), jnp.int32),
        targets=sds((G,), jnp.float32),
    )


def gnn_input_specs(arch: str, cfg, shape: str) -> dict:
    sizes = GNN_SIZES[shape]
    if arch == "graphsage" and shape == "minibatch_lg":
        B, (f1, f2) = sizes["batch_nodes"], sizes["fanout"]
        F = sizes["d_feat"]
        return {
            "graph": graph_specs(dict(sizes, n_nodes=8, n_edges=8)),  # unused stub
            "blocks": {
                "feats": [
                    sds((B * f1 * f2, F), jnp.float32),
                    sds((B * f1, F), jnp.float32),
                    sds((B, F), jnp.float32),
                ],
                "masks": [
                    sds((B * f1 * f2,), jnp.bool_),
                    sds((B * f1,), jnp.bool_),
                    sds((B,), jnp.bool_),
                ],
            },
            "block_labels": sds((B,), jnp.int32),
            "block_label_mask": sds((B,), jnp.bool_),
        }
    batch = {"graph": graph_specs(sizes)}
    if arch == "dimenet":
        T = _pad512(max_triplets(shape))
        batch["triplets"] = {
            "edge_kj": sds((T,), jnp.int32),
            "edge_ji": sds((T,), jnp.int32),
            "mask": sds((T,), jnp.bool_),
        }
    return batch


def max_triplets(shape: str) -> int:
    """Capped triplet budget (Σ deg² is unbounded on power-law graphs)."""
    return {
        "full_graph_sm": 65_536,
        "minibatch_lg": 2 * GNN_SIZES["minibatch_lg"]["n_edges"],
        "ogb_products": 2 * GNN_SIZES["ogb_products"]["n_edges"],
        "molecule": 32_768,
    }[shape]
