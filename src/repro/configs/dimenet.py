"""dimenet — 6 blocks d_hidden=128 n_bilinear=8 n_spherical=7 n_radial=6.
[arXiv:2003.03123]

Triplet budgets are capped per shape (gnn_common.max_triplets) — Σ deg²
explodes on power-law graphs; non-molecular shapes get surrogate 3D
positions from the pipeline (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from repro.configs.gnn_common import GNN_SIZES, gnn_input_specs, gnn_shapes
from repro.configs.registry import ArchSpec, register
from repro.models.gnn.dimenet import DimeNetConfig

ARCH_ID = "dimenet"


def config_for_shape(shape: str) -> DimeNetConfig:
    s = GNN_SIZES[shape]
    return DimeNetConfig(
        name=ARCH_ID, n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
        n_radial=6, d_in=s["d_feat"], n_targets=1,
    )


def smoke_config() -> DimeNetConfig:
    return DimeNetConfig(name=ARCH_ID, n_blocks=2, d_hidden=16, n_bilinear=2,
                         n_spherical=3, n_radial=4, d_in=8, n_targets=1)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="gnn",
    config_for_shape=config_for_shape,
    smoke_config=smoke_config,
    shapes=gnn_shapes(),
    input_specs=lambda cfg, shape: gnn_input_specs("dimenet", cfg, shape),
    notes="directional (triplet) message passing; graph-level regression",
))
