"""gat-cora — 2 layers d_hidden=8 n_heads=8 attention aggregator.
[arXiv:1710.10903]"""
from __future__ import annotations

from repro.configs.gnn_common import GNN_SIZES, gnn_input_specs, gnn_shapes
from repro.configs.registry import ArchSpec, register
from repro.models.gnn.gat import GATConfig

ARCH_ID = "gat-cora"


def config_for_shape(shape: str) -> GATConfig:
    s = GNN_SIZES[shape]
    return GATConfig(
        name=ARCH_ID, n_layers=2, d_in=s["d_feat"], d_hidden=8, n_heads=8,
        n_classes=max(s["n_classes"], 2),
    )


def smoke_config() -> GATConfig:
    return GATConfig(name=ARCH_ID, n_layers=2, d_in=12, d_hidden=4,
                     n_heads=2, n_classes=3)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="gnn",
    config_for_shape=config_for_shape,
    smoke_config=smoke_config,
    shapes=gnn_shapes(),
    input_specs=lambda cfg, shape: gnn_input_specs("gat", cfg, shape),
    notes="SDDMM edge scores → segment softmax → SpMM",
))
