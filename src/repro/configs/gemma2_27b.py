"""gemma2-27b — 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000,
alternating local(4096)+global attention, attn/final logit softcaps,
sandwich norms, sqrt(d) embed scaling.  [arXiv:2408.00118]"""
from __future__ import annotations

from repro.configs.lm_common import lm_input_specs, lm_shapes, smoke_lm
from repro.configs.registry import ArchSpec, register
from repro.models.transformer import TransformerConfig

ARCH_ID = "gemma2-27b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        d_head=128,
        d_ff=36864,
        vocab=256_000,
        rope_theta=10_000.0,
        window=4096,
        layer_pattern=("local", "global"),
        attn_softcap=50.0,
        final_softcap=30.0,
        sandwich_norm=True,
        embed_scale=True,
        attn_scale=(4608 // 32) ** -0.5,   # query_pre_attn_scalar = d_model/H
    )


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="lm",
    config_for_shape=lambda shape: config(),
    smoke_config=lambda: smoke_lm(config()),
    shapes=lm_shapes(long_skip=None),  # local+global alternating → run 500k
    input_specs=lambda cfg, shape: lm_input_specs(cfg, lm_shapes()[shape]),
    notes="local+global alternating, logit softcaps, GQA kv=16",
))
