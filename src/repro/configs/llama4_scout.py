"""llama4-scout-17b-a16e — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert, chunked-local attention
with NoPE global layers every 4th (iRoPE).  [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from __future__ import annotations

from repro.configs.lm_common import lm_input_specs, lm_shapes, smoke_lm
from repro.configs.registry import ArchSpec, register
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "llama4-scout-17b-a16e"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab=202_048,
        rope_theta=500_000.0,
        window=8192,                                   # chunked local attn
        layer_pattern=("local", "local", "local", "global"),
        rope_on_global=False,                          # iRoPE: NoPE on global
        moe=MoEConfig(n_experts=16, top_k=1, d_model=5120, d_ff=8192,
                      capacity_factor=1.25, n_shared=1, gated=True),
    )


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="lm",
    config_for_shape=lambda shape: config(),
    smoke_config=lambda: smoke_lm(config()),
    shapes=lm_shapes(long_skip=None),  # local/chunked path → run long_500k
    input_specs=lambda cfg, shape: lm_input_specs(cfg, lm_shapes()[shape]),
    notes="MoE top-1 + shared expert, early-fusion backbone; 3:1 local:global"
          " chunked attention enables 500k decode",
))
