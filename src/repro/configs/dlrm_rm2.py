"""dlrm-rm2 — n_dense=13 n_sparse=26 embed_dim=64 bot 13-512-256-64
top 512-512-256-1 dot interaction.  [arXiv:1906.00091]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, ShapeCell, register, sds
from repro.models.dlrm import DLRMConfig

ARCH_ID = "dlrm-rm2"
NNZ = 4  # multi-hot ids per sparse field (padded; mask carries true counts)


def config() -> DLRMConfig:
    return DLRMConfig(
        name=ARCH_ID, n_dense=13, n_sparse=26, embed_dim=64,
        n_rows=1_048_576,  # 2^20 ≈ the paper's 1e6, divisible by 512 shards
        nnz=NNZ,
        bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1),
    )


def smoke_config() -> DLRMConfig:
    return DLRMConfig(
        name=ARCH_ID, n_dense=13, n_sparse=26, embed_dim=8, n_rows=512,
        nnz=NNZ, bot_mlp=(32, 16, 8), top_mlp=(32, 16, 1),
    )


SHAPES = {
    "train_batch": ShapeCell("train_batch", "train", {"batch": 65_536}),
    "serve_p99": ShapeCell("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeCell("serve_bulk", "serve", {"batch": 262_144}),
    "retrieval_cand": ShapeCell(
        "retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}
    ),
}


def input_specs(cfg: DLRMConfig, shape: str) -> dict:
    cell = SHAPES[shape]
    B = cell.sizes["batch"]
    if cell.kind == "retrieval":
        return {
            "dense": sds((B, cfg.n_dense), jnp.float32),
            "candidates": sds(
                (cell.sizes["n_candidates"], cfg.bot_mlp[-1]), jnp.float32
            ),
        }
    specs = {
        "dense": sds((B, cfg.n_dense), jnp.float32),
        "sparse_ids": sds((B, cfg.n_sparse, cfg.nnz), jnp.int32),
        "sparse_mask": sds((B, cfg.n_sparse, cfg.nnz), jnp.bool_),
    }
    if cell.kind == "train":
        specs["labels"] = sds((B,), jnp.int32)
    return specs


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="recsys",
    config_for_shape=lambda shape: config(),
    smoke_config=smoke_config,
    shapes=SHAPES,
    input_specs=input_specs,
    notes="embedding bag = take + masked mean (no native EmbeddingBag in "
          "JAX); retrieval_cand scores via the Pallas score_topk kernel",
))
