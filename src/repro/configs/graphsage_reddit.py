"""graphsage-reddit — 2 layers d_hidden=128 mean aggregator, sample 25-10.
[arXiv:1706.02216]"""
from __future__ import annotations

import dataclasses

from repro.configs.gnn_common import GNN_SIZES, gnn_input_specs, gnn_shapes
from repro.configs.registry import ArchSpec, register
from repro.models.gnn.graphsage import SAGEConfig

ARCH_ID = "graphsage-reddit"


def config_for_shape(shape: str) -> SAGEConfig:
    s = GNN_SIZES[shape]
    fan = s.get("fanout", (25, 10))
    return SAGEConfig(
        name=ARCH_ID, n_layers=2, d_in=s["d_feat"], d_hidden=128,
        n_classes=max(s["n_classes"], 2), sample_sizes=tuple(fan),
    )


def smoke_config() -> SAGEConfig:
    return SAGEConfig(name=ARCH_ID, n_layers=2, d_in=16, d_hidden=8,
                      n_classes=4, sample_sizes=(3, 2))


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="gnn",
    config_for_shape=config_for_shape,
    smoke_config=smoke_config,
    shapes=gnn_shapes(),
    input_specs=lambda cfg, shape: gnn_input_specs("graphsage", cfg, shape),
    notes="paper sampler 25-10; the minibatch_lg cell uses the assignment's "
          "15-10 fanout via its own block sizes",
))
