"""gatedgcn — 16 layers d_hidden=70 gated aggregator.  [arXiv:2003.00982]"""
from __future__ import annotations

from repro.configs.gnn_common import D_EDGE, GNN_SIZES, gnn_input_specs, gnn_shapes
from repro.configs.registry import ArchSpec, register
from repro.models.gnn.gatedgcn import GatedGCNConfig

ARCH_ID = "gatedgcn"


def config_for_shape(shape: str) -> GatedGCNConfig:
    s = GNN_SIZES[shape]
    return GatedGCNConfig(
        name=ARCH_ID, n_layers=16, d_in=s["d_feat"], d_edge_in=D_EDGE,
        d_hidden=70, n_classes=max(s["n_classes"], 2),
    )


def smoke_config() -> GatedGCNConfig:
    return GatedGCNConfig(name=ARCH_ID, n_layers=3, d_in=12, d_edge_in=D_EDGE,
                          d_hidden=16, n_classes=3)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="gnn",
    config_for_shape=config_for_shape,
    smoke_config=smoke_config,
    shapes=gnn_shapes(),
    input_specs=lambda cfg, shape: gnn_input_specs("gatedgcn", cfg, shape),
    notes="edge-featured MPNN; benchmark BatchNorm → LayerNorm (DESIGN.md)",
))
