"""phi3.5-moe-42b-a6.6b — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct]"""
from __future__ import annotations

from repro.configs.lm_common import lm_input_specs, lm_shapes, smoke_lm
from repro.configs.registry import ArchSpec, register
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=6400,
        vocab=32064,
        rope_theta=10_000.0,
        moe=MoEConfig(n_experts=16, top_k=2, d_model=4096, d_ff=6400,
                      capacity_factor=1.25, gated=True),
    )


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="lm",
    config_for_shape=lambda shape: config(),
    smoke_config=lambda: smoke_lm(config()),
    shapes=lm_shapes(
        long_skip="pure full attention at 524k ctx (no sub-quadratic path); "
                  "see DESIGN.md §Arch-applicability",
    ),
    input_specs=lambda cfg, shape: lm_input_specs(
        cfg, lm_shapes()[shape]
    ),
    notes="16-expert top-2 MoE; 42B total / 6.6B active params",
))
