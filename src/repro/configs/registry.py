"""Architecture registry: arch-id → (configs, shapes, step kinds, input specs).

Every assigned architecture registers an :class:`ArchSpec`; the dry-run,
smoke tests, benchmarks and launchers all consume this single source of
truth. ``input_specs`` returns ShapeDtypeStructs only — nothing allocates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

f32 = jnp.float32
i32 = jnp.int32
bf16 = jnp.bfloat16


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                    # train | prefill | decode | serve | retrieval | forward
    sizes: dict[str, int]
    skip: str | None = None      # reason when this (arch, shape) is skipped


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                  # lm | gnn | recsys | ipgm
    config_for_shape: Callable[[str], Any]
    smoke_config: Callable[[], Any]
    shapes: dict[str, ShapeCell]
    input_specs: Callable[[Any, str], dict]   # (cfg, shape) → batch SDS pytree
    notes: str = ""


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, ArchSpec]:
    _ensure_loaded()
    return dict(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    # import side-effect registration
    from repro.configs import (  # noqa: F401
        dimenet as _a,
        dlrm_rm2 as _b,
        gat_cora as _c,
        gatedgcn as _d,
        gemma2_27b as _e,
        graphsage_reddit as _f,
        ipgm_ann as _k,
        llama4_scout as _g,
        mistral_nemo_12b as _h,
        phi35_moe as _i,
        qwen3_1p7b as _j,
    )
    _LOADED = True
