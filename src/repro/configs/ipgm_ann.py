"""ipgm-online — the paper's own system as a dry-runnable architecture.

Shapes cover the three op classes of GRAPH-MAINTENANCE (Alg 3) on the
production mesh: sharded query fan-out/merge, routed insert, GLOBAL-repair
delete. Per-shard capacities × 256 (single-pod) give a ~2M-vector index for
d=128 (SIFT-like) and a ~0.5M-vector index for d=960 (GIST-like).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.registry import ArchSpec, ShapeCell, register, sds
from repro.core.params import IndexParams, SearchParams

ARCH_ID = "ipgm-online"

SHAPES = {
    "serve_d128": ShapeCell(
        "serve_d128", "ipgm_query",
        {"q_batch": 4096, "cap_local": 8192, "dim": 128},
    ),
    "serve_d960": ShapeCell(
        "serve_d960", "ipgm_query",
        {"q_batch": 1024, "cap_local": 2048, "dim": 960},
    ),
    "update_global": ShapeCell(
        "update_global", "ipgm_delete",
        {"batch": 512, "cap_local": 8192, "dim": 128},
    ),
    "insert_stream": ShapeCell(
        "insert_stream", "ipgm_insert",
        {"batch": 64, "cap_local": 8192, "dim": 128},
    ),
}


def config_for_shape(shape: str) -> IndexParams:
    cell = SHAPES[shape]
    return IndexParams(
        capacity=cell.sizes["cap_local"],
        dim=cell.sizes["dim"],
        d_out=32,
        search=SearchParams(pool_size=64, max_steps=128, num_starts=2),
    )


def smoke_config() -> IndexParams:
    return IndexParams(
        capacity=128, dim=16, d_out=8,
        search=SearchParams(pool_size=16, max_steps=32, num_starts=2),
    )


def input_specs(cfg: IndexParams, shape: str) -> dict:
    cell = SHAPES[shape]
    if cell.kind == "ipgm_query":
        return {"queries": sds((cell.sizes["q_batch"], cfg.dim), jnp.float32)}
    if cell.kind == "ipgm_delete":
        return {"gids": sds((cell.sizes["batch"],), jnp.int32)}
    if cell.kind == "ipgm_insert":
        return {
            "vecs": sds((cell.sizes["batch"], cfg.dim), jnp.float32),
            "route": sds((cell.sizes["batch"],), jnp.int32),
        }
    raise ValueError(cell.kind)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="ipgm",
    config_for_shape=config_for_shape,
    smoke_config=smoke_config,
    shapes=SHAPES,
    input_specs=input_specs,
    notes="shard-per-device subgraphs; GLOBAL delete repair = batched "
          "shard-local searches (DESIGN.md §5)",
))
