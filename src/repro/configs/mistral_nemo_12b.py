"""mistral-nemo-12b — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx.  [hf:mistralai/Mistral-Nemo-Base-2407]"""
from __future__ import annotations

from repro.configs.lm_common import lm_input_specs, lm_shapes, smoke_lm
from repro.configs.registry import ArchSpec, register
from repro.models.transformer import TransformerConfig

ARCH_ID = "mistral-nemo-12b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab=131_072,
        rope_theta=1_000_000.0,
    )


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="lm",
    config_for_shape=lambda shape: config(),
    smoke_config=lambda: smoke_lm(config()),
    shapes=lm_shapes(
        long_skip="pure full attention at 524k ctx (no sub-quadratic path)",
    ),
    input_specs=lambda cfg, shape: lm_input_specs(cfg, lm_shapes()[shape]),
    notes="dense GQA, 128k-context rope_theta=1e6, decoupled head_dim",
))
