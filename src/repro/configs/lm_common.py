"""Shared LM-family shape cells + input-spec builders."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.registry import ShapeCell, sds
from repro.models import transformer as tfm


def lm_shapes(*, long_skip: str | None = None) -> dict[str, ShapeCell]:
    cells = {
        "train_4k": ShapeCell("train_4k", "train",
                              {"seq": 4096, "batch": 256}),
        "prefill_32k": ShapeCell("prefill_32k", "prefill",
                                 {"seq": 32768, "batch": 32}),
        "decode_32k": ShapeCell("decode_32k", "decode",
                                {"seq": 32768, "batch": 128}),
        "long_500k": ShapeCell("long_500k", "decode",
                               {"seq": 524288, "batch": 1}, skip=long_skip),
    }
    return cells


def lm_input_specs(cfg: tfm.TransformerConfig, cell: ShapeCell) -> dict:
    B, S = cell.sizes["batch"], cell.sizes["seq"]
    if cell.kind == "train":
        return {
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
            "mask": sds((B, S), jnp.bool_),
        }
    if cell.kind == "prefill":
        return {"tokens": sds((B, S), jnp.int32)}
    if cell.kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32)}
    raise ValueError(cell.kind)


def lm_cache_specs(cfg: tfm.TransformerConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct KV cache for decode cells."""
    B, S = cell.sizes["batch"], cell.sizes["seq"]
    shape = (cfg.n_groups, B, S, cfg.n_kv_heads, cfg.d_head)
    return {
        "kv": [
            (sds(shape, cfg.compute_dtype), sds(shape, cfg.compute_dtype))
            for _ in range(cfg.period)
        ],
        "len": sds((B,), jnp.int32),
    }


def smoke_lm(cfg: tfm.TransformerConfig) -> tfm.TransformerConfig:
    """Family-preserving reduction for CPU smoke tests."""
    import dataclasses

    from repro.models.moe import MoEConfig

    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(
            n_experts=4, top_k=cfg.moe.top_k, d_model=64, d_ff=96,
            capacity_factor=2.0, n_shared=cfg.moe.n_shared, gated=cfg.moe.gated,
        )
    return dataclasses.replace(
        cfg,
        n_layers=2 * cfg.period, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=96, vocab=128, moe=moe,
        window=8 if cfg.window else None,
        compute_dtype=jnp.float32, block_q=16, block_kv=16, xent_chunk=16,
    )
