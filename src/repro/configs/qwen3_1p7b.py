"""qwen3-1.7b — 28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936,
qk_norm.  [hf:Qwen/Qwen3-1.7B]"""
from __future__ import annotations

from repro.configs.lm_common import lm_input_specs, lm_shapes, smoke_lm
from repro.configs.registry import ArchSpec, register
from repro.models.transformer import TransformerConfig

ARCH_ID = "qwen3-1.7b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_head=128,
        d_ff=6144,
        vocab=151_936,
        rope_theta=1_000_000.0,
        qk_norm=True,
    )


SPEC = register(ArchSpec(
    arch_id=ARCH_ID,
    family="lm",
    config_for_shape=lambda shape: config(),
    smoke_config=lambda: smoke_lm(config()),
    shapes=lm_shapes(
        long_skip="pure full attention at 524k ctx (no sub-quadratic path)",
    ),
    input_specs=lambda cfg, shape: lm_input_specs(cfg, lm_shapes()[shape]),
    notes="dense GQA with per-head qk RMSNorm",
))
