"""Write-ahead op journal — the redo log between checkpoints (DESIGN.md §11).

Checkpoints (manager.py) bound recovery *state*; the journal bounds recovery
*loss*: every op the session acknowledges is appended here before device
dispatch, so a crash loses at most the ops whose records were not yet
durable under the configured fsync policy. Recovery = newest complete
checkpoint + deterministic replay of the journaled suffix (bit-exact because
op keys are a pure function of logical stream position — DESIGN.md §7/§8).

Record format (little-endian)::

    u32 MAGIC | u32 body_len | u32 crc32(body) | body
    body = u32 header_len | header JSON | payload f32 bytes | ids i32 bytes

The header carries ``code`` (OP_*/JR_*), ``seq`` (op counter at append),
``cseq`` (the record's replay-dedup counter: a maintenance record — one
whose code appears in the maintenance-op registry, ``core/maint.py`` —
snapshots its own op's counter, e.g. JR_CONSOLIDATE the consolidate counter
and JR_REFINE the refine counter; replay hooks on the registry entries
re-derive the skip decision from it), free-form ``aux`` (e.g. the delete
chunk width — delete results legitimately depend on it), and the array
shapes. The journal layer itself is policy-free: it never interprets
``code``/``cseq`` — the session/tiered ``recover`` paths dispatch records
through the registry.
Self-delimiting + per-record CRC means a torn tail (partial write at the
kill point) or bit rot is detected at scan; everything from the first bad
byte on is dropped — redo-log prefix semantics, exactly what a write-ahead
discipline guarantees.

fsync policy (``"always" | "flush" | "never"``): ``"always"`` flushes and
fsyncs per record (max durability, max cost); ``"flush"`` — the default —
buffers appends and makes them durable when the session syncs
(``Session.flush`` / checkpoint save): a crash then loses at most the ops
since the last flush, which is also the session's acknowledgement barrier,
so nothing acknowledged is ever lost; ``"never"`` flushes the userspace
buffer at sync but leaves persistence to the OS.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import zlib
from pathlib import Path
from typing import Any

import numpy as np

MAGIC = 0x4C4E524A  # "JRNL" little-endian
_REC = struct.Struct("<III")   # magic, body_len, crc32
_U32 = struct.Struct("<I")
# A body larger than this is framing corruption, not a real record (largest
# legitimate record is one op chunk of f32 rows — far below this).
_MAX_BODY = 1 << 28

FSYNC_POLICIES = ("always", "flush", "never")


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    """One decoded journal record."""

    code: int
    seq: int            # session op counter at append time
    cseq: int           # the record's replay-dedup counter at append time
                        # (maintenance records: their own op's counter —
                        # see the registry in core/maint.py)
    aux: dict[str, Any]
    payload: np.ndarray | None  # f32[n, dim] (query/insert rows)
    ids: np.ndarray | None      # i32[n] (delete targets)

    @property
    def name(self) -> str:
        """Human-readable record name (``ops.JR_NAMES``/``OP_NAMES``)."""
        from repro.core import ops as ops_mod

        return ops_mod.JR_NAMES.get(
            self.code, ops_mod.OP_NAMES.get(self.code, f"code{self.code}"))


def _encode(code: int, seq: int, cseq: int,
            payload: np.ndarray | None, ids: np.ndarray | None,
            aux: dict[str, Any] | None) -> bytes:
    header: dict[str, Any] = {"code": int(code), "seq": int(seq),
                              "cseq": int(cseq), "aux": aux or {}}
    p_bytes = b""
    if payload is not None:
        p = np.ascontiguousarray(payload, dtype=np.float32)
        header["p_shape"] = list(p.shape)
        p_bytes = p.tobytes()
    i_bytes = b""
    if ids is not None:
        i = np.ascontiguousarray(ids, dtype=np.int32)
        header["i_shape"] = list(i.shape)
        i_bytes = i.tobytes()
    h = json.dumps(header, separators=(",", ":")).encode()
    body = _U32.pack(len(h)) + h + p_bytes + i_bytes
    return _REC.pack(MAGIC, len(body), zlib.crc32(body)) + body


def _decode_body(body: bytes) -> JournalRecord:
    (hlen,) = _U32.unpack_from(body, 0)
    off = _U32.size
    header = json.loads(body[off:off + hlen].decode())
    off += hlen
    payload = ids = None
    if "p_shape" in header:
        shape = tuple(header["p_shape"])
        n = int(np.prod(shape, dtype=np.int64)) * 4
        payload = np.frombuffer(body[off:off + n], np.float32).reshape(shape)
        off += n
    if "i_shape" in header:
        shape = tuple(header["i_shape"])
        n = int(np.prod(shape, dtype=np.int64)) * 4
        ids = np.frombuffer(body[off:off + n], np.int32).reshape(shape)
        off += n
    if off != len(body):
        raise ValueError("journal body length mismatch")
    return JournalRecord(code=header["code"], seq=header["seq"],
                         cseq=header["cseq"], aux=header["aux"],
                         payload=payload, ids=ids)


def scan_file(path: str | Path) -> tuple[list[JournalRecord], int, int]:
    """Decode the longest valid record prefix of ``path``.

    Returns ``(records, valid_bytes, dropped_bytes)``. Never raises on
    corruption: a bad magic, an oversized length, a CRC mismatch or a torn
    final record simply ends the prefix — redo-log semantics. A missing
    file is an empty journal.
    """
    path = Path(path)
    if not path.exists():
        return [], 0, 0
    data = path.read_bytes()
    records: list[JournalRecord] = []
    off = 0
    while off + _REC.size <= len(data):
        magic, body_len, crc = _REC.unpack_from(data, off)
        if magic != MAGIC or body_len > _MAX_BODY:
            break
        start = off + _REC.size
        end = start + body_len
        if end > len(data):
            break  # torn tail: header landed, body didn't
        body = data[start:end]
        if zlib.crc32(body) != crc:
            break
        try:
            records.append(_decode_body(body))
        except Exception:
            break
        off = end
    return records, off, len(data) - off


class OpJournal:
    """Appendable write-ahead log over one file.

    The constructor opens for append without touching existing bytes —
    callers decide whether the file is a live tail to extend
    (``Session.recover`` repairs torn bytes first via :meth:`repair`) or a
    dead timeline to discard (a *fresh* session calls :meth:`reset`).
    """

    def __init__(self, path: str | Path, *, fsync: str = "flush"):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(
                f"fsync policy {fsync!r} not in {FSYNC_POLICIES}")
        self.path = Path(path)
        self.fsync_policy = fsync
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "ab")
        self.n_appended = 0

    # -- write side ---------------------------------------------------------

    def append(self, code: int, *, seq: int, cseq: int = 0,
               payload: np.ndarray | None = None,
               ids: np.ndarray | None = None,
               aux: dict[str, Any] | None = None) -> None:
        self._f.write(_encode(code, seq, cseq, payload, ids, aux))
        # only "always" pays a barrier per record; under "flush"/"never"
        # bytes may sit in the userspace buffer until sync() — consistent
        # with the documented loss window (durability is promised at the
        # ack barrier, not per append), and a partially buffered record at
        # a kill is exactly the torn-tail case scan_file already drops
        if self.fsync_policy == "always":
            self._f.flush()
            os.fsync(self._f.fileno())
        self.n_appended += 1

    def sync(self) -> None:
        """Durability barrier (no-op only under policy ``"never"``)."""
        self._f.flush()
        if self.fsync_policy != "never":
            os.fsync(self._f.fileno())

    def truncate(self) -> None:
        """Drop every record — called after a checkpoint publishes, which
        subsumes the journal's whole prefix."""
        self._f.flush()
        self._f.truncate(0)
        self._f.seek(0)
        os.fsync(self._f.fileno())
        self.n_appended = 0

    def reset(self, *, meta: dict[str, Any] | None = None) -> None:
        """Truncate and stamp a fresh JR_META header record.

        The META record pins the session fingerprint so a journal can never
        be silently replayed into a session with different geometry/policy.
        """
        from repro.core import ops as ops_mod

        self.truncate()
        self.append(ops_mod.JR_META, seq=0, cseq=0, aux=meta or {})
        self._f.flush()  # resets are rare; keep the META header on disk

    def repair(self) -> tuple[list[JournalRecord], int]:
        """Scan, physically drop the torn/corrupt tail, return the prefix.

        After this the file ends exactly at the last valid record, so
        subsequent appends extend a clean prefix. Returns
        ``(records, dropped_bytes)``.
        """
        self._f.flush()
        records, valid, dropped = scan_file(self.path)
        if dropped:
            self._f.truncate(valid)
            self._f.seek(valid)
            os.fsync(self._f.fileno())
        return records, dropped

    def close(self) -> None:
        try:
            self._f.close()
        except Exception:
            pass

    def __del__(self):  # best effort — tests create many short-lived logs
        self.close()
