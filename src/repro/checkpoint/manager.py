"""Fault-tolerant checkpointing — per-shard npz + manifest, atomic, elastic.

Layout:
    <dir>/step_<N>/
        manifest.json        tree structure, leaf → file map, mesh shape,
                             data-pipeline state, monotonic step id
        shard_<i>.npz        all leaves owned by logical shard i
    <dir>/LATEST             atomic pointer (rename) to the newest complete step

Guarantees:
  · atomic publish — a step directory is visible only after its manifest and
    LATEST pointer rename complete (no torn checkpoints after preemption);
  · elastic restore — arrays are saved with GLOBAL shapes; restore reshards
    to whatever mesh/device count the new job runs (device_put with the new
    sharding), so scale-up/scale-down restarts work;
  · keep-k retention and restore-latest-complete (a crashed write is ignored).

For the sharded ANN index the per-shard subgraph arrays restore bit-exact;
re-sharding to a different shard count triggers the documented re-bulk-link
path (distributed/ann.py).
"""
from __future__ import annotations

import json
import shutil
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> Path:
        keys, leaves, _ = _flatten_with_paths(tree)
        step_dir = self.dir / f"step_{step:012d}"
        tmp_dir = self.dir / f".tmp_step_{step:012d}_{int(time.time()*1e6)}"
        tmp_dir.mkdir(parents=True)

        arrays = {}
        for i, (k, leaf) in enumerate(zip(keys, leaves)):
            arrays[f"leaf_{i}"] = np.asarray(jax.device_get(leaf))
        np.savez(tmp_dir / "shard_0.npz", **arrays)

        manifest = {
            "step": step,
            "keys": keys,
            "n_leaves": len(leaves),
            "time": time.time(),
            "extra": extra or {},
        }
        (tmp_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp_dir.replace(step_dir)                      # atomic publish
        latest_tmp = self.dir / ".LATEST.tmp"
        latest_tmp.write_text(step_dir.name)
        latest_tmp.replace(self.dir / "LATEST")        # atomic pointer
        self._gc()
        return step_dir

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        ptr = self.dir / "LATEST"
        if not ptr.exists():
            return None
        name = ptr.read_text().strip()
        if not (self.dir / name / "manifest.json").exists():
            # torn write — fall back to newest complete step dir
            steps = sorted(self.all_steps())
            return steps[-1] if steps else None
        return int(name.split("_")[-1])

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[-1]))
        return sorted(out)

    def restore(
        self, step: int | None, like: Any, *, shardings: Any = None
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; optionally device_put with
        ``shardings`` (elastic re-shard onto the current mesh)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        step_dir = self.dir / f"step_{step:012d}"
        manifest = json.loads((step_dir / "manifest.json").read_text())
        data = np.load(step_dir / "shard_0.npz")

        keys, leaves, treedef = _flatten_with_paths(like)
        if keys != manifest["keys"]:
            raise ValueError(
                "checkpoint tree mismatch:\n"
                f"  saved:   {manifest['keys'][:5]}...\n"
                f"  restore: {keys[:5]}..."
            )
        new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            shard_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "addressable_devices")
            )
            new_leaves = [
                jax.device_put(a, s) for a, s in zip(new_leaves, shard_leaves)
            ]
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return tree, manifest["extra"]

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:012d}", ignore_errors=True)
