"""Fault-tolerant checkpointing — per-shard npz + manifest, atomic, elastic.

Layout:
    <dir>/step_<N>/
        manifest.json        tree structure, leaf → file map, mesh shape,
                             data-pipeline state, monotonic step id
        shard_<i>.npz        all leaves owned by logical shard i
    <dir>/LATEST             atomic pointer (rename) to the newest complete step

Guarantees:
  · atomic publish — a step directory is visible only after its manifest and
    LATEST pointer rename complete (no torn checkpoints after preemption);
  · elastic restore — arrays are saved with GLOBAL shapes; restore reshards
    to whatever mesh/device count the new job runs (device_put with the new
    sharding), so scale-up/scale-down restarts work;
  · keep-k retention and restore-latest-complete (a crashed write is ignored);
  · corruption containment — a truncated/garbled manifest or shard raises a
    typed :class:`CheckpointCorruptError`, and ``restore(None, ...)`` falls
    back through older complete steps instead of crashing on ``np.load``
    (DESIGN.md §11: a half-dead checkpoint must degrade recovery, not end it).

For the sharded ANN index the per-shard subgraph arrays restore bit-exact;
re-sharding to a different shard count triggers the documented re-bulk-link
path (distributed/ann.py).
"""
from __future__ import annotations

import json
import shutil
import time
import zlib
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.testing import faults


class CheckpointCorruptError(RuntimeError):
    """A step directory exists but cannot be trusted (torn or garbled)."""


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 keep_last: int | None = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        # ``keep_last`` is the retention-GC spelling used in ops configs;
        # both name the same K (keep_last wins when given).
        self.keep = keep if keep_last is None else keep_last

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> Path:
        keys, leaves, _ = _flatten_with_paths(tree)
        step_dir = self.dir / f"step_{step:012d}"
        tmp_dir = self.dir / f".tmp_step_{step:012d}_{int(time.time()*1e6)}"
        tmp_dir.mkdir(parents=True)

        arrays = {}
        for i, (k, leaf) in enumerate(zip(keys, leaves)):
            arrays[f"leaf_{i}"] = np.asarray(jax.device_get(leaf))
        np.savez(tmp_dir / "shard_0.npz", **arrays)
        shard_crc = zlib.crc32((tmp_dir / "shard_0.npz").read_bytes())

        # the classic torn-save window: data written, manifest/publish not
        faults.crash_point("mid-checkpoint-save")

        manifest = {
            "step": step,
            "keys": keys,
            "n_leaves": len(leaves),
            "shard_crc": {"shard_0.npz": shard_crc},
            "time": time.time(),
            "extra": extra or {},
        }
        (tmp_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp_dir.replace(step_dir)                      # atomic publish
        latest_tmp = self.dir / ".LATEST.tmp"
        latest_tmp.write_text(step_dir.name)
        latest_tmp.replace(self.dir / "LATEST")        # atomic pointer
        self._gc()
        return step_dir

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        ptr = self.dir / "LATEST"
        if not ptr.exists():
            return None
        name = ptr.read_text().strip()
        if not (self.dir / name / "manifest.json").exists():
            # torn write — fall back to newest complete step dir
            steps = sorted(self.all_steps())
            return steps[-1] if steps else None
        return int(name.split("_")[-1])

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[-1]))
        return sorted(out)

    def _load_step(self, step: int) -> tuple[dict, Any]:
        """Read + validate one step dir; CheckpointCorruptError on any rot."""
        step_dir = self.dir / f"step_{step:012d}"
        try:
            manifest = json.loads((step_dir / "manifest.json").read_text())
        except FileNotFoundError:
            raise CheckpointCorruptError(f"{step_dir}: no manifest")
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CheckpointCorruptError(f"{step_dir}: bad manifest: {e}")
        shard = step_dir / "shard_0.npz"
        want_crc = manifest.get("shard_crc", {}).get("shard_0.npz")
        try:
            if want_crc is not None:
                got_crc = zlib.crc32(shard.read_bytes())
                if got_crc != want_crc:
                    raise CheckpointCorruptError(
                        f"{shard}: crc mismatch "
                        f"(manifest {want_crc:#x}, file {got_crc:#x})")
            data = np.load(shard)
            n = manifest.get("n_leaves")
            if n is not None and len(data.files) != n:
                raise CheckpointCorruptError(
                    f"{shard}: {len(data.files)} arrays, manifest says {n}")
        except CheckpointCorruptError:
            raise
        except FileNotFoundError:
            raise CheckpointCorruptError(f"{shard}: missing shard")
        except Exception as e:  # truncated zip, bad npy header, ...
            raise CheckpointCorruptError(f"{shard}: unreadable: {e}")
        return manifest, data

    def restore(
        self, step: int | None, like: Any, *, shardings: Any = None
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; optionally device_put with
        ``shardings`` (elastic re-shard onto the current mesh).

        ``step=None`` restores the newest step that validates, falling back
        through older complete steps past any corrupt ones (each skip is a
        durability loss already paid — better a stale index than none). An
        explicit ``step`` raises :class:`CheckpointCorruptError` instead.
        """
        if step is not None:
            manifest, data = self._load_step(step)
        else:
            latest = self.latest_step()
            if latest is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
            candidates = [s for s in sorted(self.all_steps()) if s <= latest]
            candidates += [s for s in sorted(self.all_steps()) if s > latest]
            errors: list[str] = []
            manifest = data = None
            for s in reversed(candidates):
                try:
                    manifest, data = self._load_step(s)
                    break
                except CheckpointCorruptError as e:
                    errors.append(str(e))
            if manifest is None:
                raise CheckpointCorruptError(
                    "every checkpoint step is corrupt:\n  "
                    + "\n  ".join(errors))

        keys, leaves, treedef = _flatten_with_paths(like)
        if keys != manifest["keys"]:
            raise ValueError(
                "checkpoint tree mismatch:\n"
                f"  saved:   {manifest['keys'][:5]}...\n"
                f"  restore: {keys[:5]}..."
            )
        new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            shard_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "addressable_devices")
            )
            new_leaves = [
                jax.device_put(a, s) for a, s in zip(new_leaves, shard_leaves)
            ]
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return tree, manifest["extra"]

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:012d}", ignore_errors=True)
