"""Gradient compression for cross-replica sync — int8 quantized all-reduce.

1-bit/8-bit gradient compression (Seide et al. 2014 lineage): inside
shard_map, per-tensor-block scales are computed locally, gradients quantize
to int8, psum runs on int8-widened int32 (exact), and the result dequantizes
— 4× wire-bytes reduction on the DP all-reduce with unbiased stochastic
rounding and local error feedback.

Used by the training loop when ``DistTrainConfig.compress_grads=True`` for
the cross-pod gradient sync (the slow inter-pod links are the target; the
intra-pod FSDP reduce-scatter stays fp32).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# The *deterministic* per-row quantizer used for the index's vector codes
# (DESIGN.md §10) lives in core (no key — the transactional invariant
# ``codes == quantize_rows(vectors)`` must be exactly re-checkable);
# re-exported here so both int8 schemes are visible from one module.
from repro.core.quantize import (  # noqa: F401
    VECTOR_CODE_SCHEME,
    dequantize_rows,
    quantize_rows,
)


def quantize_int8(x: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stochastic-rounding int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grads: Any, key: jax.Array, axis_name: str | tuple[str, ...]
) -> Any:
    """int8-compressed mean-all-reduce over ``axis_name`` (inside shard_map).

    Exactness: int8 payloads are widened to int32 before psum, so the
    reduction itself is exact; the only error is the local quantization
    (unbiased via stochastic rounding). Scales psum in fp32 (tiny).
    """
    n = jax.lax.psum(1, axis_name)
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        q, scale = quantize_int8(leaf, k)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_sum = jax.lax.psum(scale, axis_name)
        # mean of per-replica dequantized grads ≈ (Σq·s̄)/n with shared scale
        mean_scale = s_sum / n
        out.append((q_sum.astype(jnp.float32) * mean_scale / n).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def wire_bytes_saved(grads: Any) -> tuple[int, int]:
    """(fp32_bytes, int8_bytes) for reporting."""
    import numpy as np
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(grads))
    return 4 * n, n + 4 * len(jax.tree.leaves(grads))
