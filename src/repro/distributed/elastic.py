"""Elastic re-sharding — restart the sharded index on a different fleet.

Checkpoints store per-shard subgraphs for P shards; a restart may come up
with P' ≠ P devices (node failures, scale-up). Vectors are re-routed by the
same hash rule and each new shard **re-bulk-links** its subgraph with the
exact-kNN constructor (rebuild.bulk_knn_build) — edges are shard-local so
only graphs, not data, need recomputation; the alive/masked bits survive.

This is the recovery path the 1000-node deployment runs after losing a
slice: O(n/P'² · d) FLOPs per shard, fully parallel, no global rebuild.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import rebuild
from repro.core.graph import GraphState
from repro.core.params import IndexParams


def _stride_of(params: IndexParams, cap_live: int) -> int:
    """The gid stride of a sharded session under ``params``: pinned to
    ``max_capacity`` when growth is armed (DESIGN.md §9 — gids survive tier
    moves), the live per-shard capacity otherwise (legacy encoding). Must
    mirror ``DistParams.gid_stride``."""
    mp = params.maintenance
    return mp.max_capacity if mp.max_capacity is not None else cap_live


def gather_alive(
    state_stacked: GraphState, *, stride: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side: (vectors, global_ids) of every alive vertex across shards.

    ``stride`` is the gid encoding stride (``gid = shard · stride + lid``);
    defaults to the live per-shard capacity — pass the armed session's
    stride (= ``max_capacity``) so the returned gids match the ids the
    session actually handed out.
    """
    vecs = np.asarray(jax.device_get(state_stacked.vectors))
    alive = np.asarray(jax.device_get(state_stacked.alive))
    P, cap, dim = vecs.shape
    stride = cap if stride is None else stride
    flat = vecs.reshape(P * cap, dim)
    mask = alive.reshape(P * cap)
    idx = np.flatnonzero(mask)
    gids = (idx // cap) * stride + (idx % cap)
    return flat[mask], gids


def reshard(
    state_stacked: GraphState,
    old_params: IndexParams,
    new_params: IndexParams,
    n_new_shards: int,
    *,
    route: str = "hash",
) -> tuple[GraphState, np.ndarray]:
    """Re-shard a stacked index to ``n_new_shards`` shards.

    Returns (new stacked state [P', cap', ...], id remap array old_gid →
    new_gid). Each new shard is re-bulk-linked independently. Both sides of
    the remap live in the *session's* gid space (DESIGN.md §9): old gids are
    decoded with the old config's stride, new gids encoded with the new
    config's — so growth-armed sessions (stride = ``max_capacity``) can
    translate the ids they handed out across the reshard.
    """
    old_stride = _stride_of(old_params, int(state_stacked.vectors.shape[1]))
    new_stride = _stride_of(new_params, new_params.capacity)
    vecs, old_gids = gather_alive(state_stacked, stride=old_stride)
    n = vecs.shape[0]
    cap = new_params.capacity
    if route == "hash":
        owner = (old_gids % n_new_shards).astype(np.int64)
    else:  # round-robin balance
        owner = np.arange(n) % n_new_shards

    shard_states = []
    remap = np.full(int(old_gids.max(initial=0)) + 1, -1, np.int64)
    for s in range(n_new_shards):
        mine = owner == s
        count = int(mine.sum())
        if count > cap:
            raise ValueError(
                f"shard {s} would hold {count} > capacity {cap}; "
                f"raise capacity or shard count"
            )
        padded = np.zeros((cap, new_params.dim), np.float32)
        padded[:count] = vecs[mine]
        valid = jnp.arange(cap) < count
        st = rebuild.bulk_knn_build(jnp.asarray(padded), valid, new_params)
        shard_states.append(st)
        remap[old_gids[mine]] = s * new_stride + np.arange(count)

    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs), *shard_states
    )
    return stacked, remap
