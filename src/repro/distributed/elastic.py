"""Elastic re-sharding — restart the sharded index on a different fleet.

Checkpoints store per-shard subgraphs for P shards; a restart may come up
with P' ≠ P devices (node failures, scale-up). Vectors are re-routed by the
same hash rule and each new shard **re-bulk-links** its subgraph with the
exact-kNN constructor (rebuild.bulk_knn_build) — edges are shard-local so
only graphs, not data, need recomputation; the alive/masked bits survive.

This is the recovery path the 1000-node deployment runs after losing a
slice: O(n/P'² · d) FLOPs per shard, fully parallel, no global rebuild.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import rebuild
from repro.core.graph import GraphState
from repro.core.params import IndexParams


def gather_alive(state_stacked: GraphState) -> tuple[np.ndarray, np.ndarray]:
    """Host-side: (vectors, global_ids) of every alive vertex across shards."""
    vecs = np.asarray(jax.device_get(state_stacked.vectors))
    alive = np.asarray(jax.device_get(state_stacked.alive))
    P, cap, dim = vecs.shape
    flat = vecs.reshape(P * cap, dim)
    mask = alive.reshape(P * cap)
    gids = np.flatnonzero(mask)
    return flat[mask], gids


def reshard(
    state_stacked: GraphState,
    old_params: IndexParams,
    new_params: IndexParams,
    n_new_shards: int,
    *,
    route: str = "hash",
) -> tuple[GraphState, np.ndarray]:
    """Re-shard a stacked index to ``n_new_shards`` shards.

    Returns (new stacked state [P', cap', ...], id remap array old_gid →
    new_gid). Each new shard is re-bulk-linked independently.
    """
    vecs, old_gids = gather_alive(state_stacked)
    n = vecs.shape[0]
    cap = new_params.capacity
    if route == "hash":
        owner = (old_gids % n_new_shards).astype(np.int64)
    else:  # round-robin balance
        owner = np.arange(n) % n_new_shards

    shard_states = []
    remap = np.full(int(old_gids.max(initial=0)) + 1, -1, np.int64)
    for s in range(n_new_shards):
        mine = owner == s
        count = int(mine.sum())
        if count > cap:
            raise ValueError(
                f"shard {s} would hold {count} > capacity {cap}; "
                f"raise capacity or shard count"
            )
        padded = np.zeros((cap, new_params.dim), np.float32)
        padded[:count] = vecs[mine]
        valid = jnp.arange(cap) < count
        st = rebuild.bulk_knn_build(jnp.asarray(padded), valid, new_params)
        shard_states.append(st)
        remap[old_gids[mine]] = s * cap + np.arange(count)

    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs), *shard_states
    )
    return stacked, remap
