from repro.distributed.ann import (
    DistParams,
    distributed_delete,
    distributed_insert,
    distributed_query,
    init_sharded_state,
)

__all__ = [
    "DistParams",
    "distributed_delete",
    "distributed_insert",
    "distributed_query",
    "init_sharded_state",
]
