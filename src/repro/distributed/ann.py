"""Sharded online ANN index — the paper's system at 256–512+ chips.

Layout (DESIGN.md §5): shard-per-device subgraphs. Each device on the
flattened ('data','model') axes owns ``cap_local`` slots and an independent
proximity graph over them; there are NO cross-shard edges, so the paper's
delete/repair algorithms run unmodified (and fully parallel) inside every
shard. The 'pod' axis holds index replicas and shards the query stream
(fault-tolerance + QPS scaling).

  query : queries replicated within a pod → every shard beam-searches its
          subgraph → all_gather(k per shard) → top-k merge. Collective bytes
          per query = P·k·8 — independent of index size.
  insert: routed by hash → SPMD masked insert (only the owner's mask is
          hot) through the vectorized insert pipeline (DESIGN.md §4): every
          shard runs ONE batched search + scatter edge application for its
          routed slice, inline inside shard_map (no nested jit).
  delete: global id = shard·cap_local + local id → owner-masked
          delete_batch with the configured strategy (GLOBAL repair searches
          are shard-local by construction).

Straggler/fault story: the merge consumes per-shard partial top-k, so a lost
shard degrades recall by ~1/P instead of failing the query; the checkpoint
manager (checkpoint/manager.py) restores per-shard states independently and
supports re-sharding to a different device count.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import consolidate as consolidate_mod
from repro.core import delete as delete_mod
from repro.core import insert as insert_mod
from repro.core import ops as ops_mod
from repro.core import search as search_mod
from repro.core.graph import (
    NULL,
    GraphState,
    grow_state,
    init_graph,
    mask_to_slots,
    next_capacity_tier,
)
from repro.core.params import IndexParams
from repro.testing import faults


@dataclasses.dataclass(frozen=True)
class DistParams:
    """Distribution config for the sharded index."""
    index: IndexParams           # per-shard params (capacity = cap_local)
    shard_axes: tuple[str, ...] = ("data", "model")
    pod_axis: str | None = None  # set for multi-pod meshes
    hierarchical_merge: bool = True  # §Perf C: two-stage top-k fan-in —
                                     # merge within 'model' first, then
                                     # across 'data': AG bytes drop from
                                     # P·B·k to (m+n)·B·k per device
    vec_dtype: str = "float32"       # "bfloat16" halves gather traffic

    @property
    def axes(self) -> tuple[str, ...]:
        return self.shard_axes

    def gid_stride(self) -> int:
        """Global-id stride: ``gid = shard · stride + local id``.

        Pinned to ``maintenance.max_capacity`` when capacity growth is armed
        (DESIGN.md §9), so gids handed out at one tier stay valid after
        every shard grows to a larger one; with growth disarmed it equals
        the (then-fixed) per-shard capacity — the legacy encoding.
        """
        mp = self.index.maintenance
        return (mp.max_capacity if mp.max_capacity is not None
                else self.index.capacity)


def init_sharded_state(dp: DistParams, mesh) -> GraphState:
    """Host-side init of the stacked per-shard states [P, cap_local, ...]."""
    n_shards = 1
    for a in dp.shard_axes:
        n_shards *= mesh.shape[a]
    one = init_graph(
        dp.index.capacity, dp.index.dim, d_out=dp.index.d_out,
        d_in=dp.index.eff_d_in, metric=dp.index.metric,
        dtype=jnp.dtype(dp.vec_dtype),
    )
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_shards,) + x.shape), one
    )


def _local(state_stacked: GraphState) -> GraphState:
    """Drop the (length-1 after shard_map) shard axis."""
    return jax.tree.map(lambda x: x[0], state_stacked)


def _restack(state: GraphState) -> GraphState:
    return jax.tree.map(lambda x: x[None], state)


def _shard_index(axes) -> jax.Array:
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
    return idx


def topk_union(flat_scores: jax.Array, flat_ids: jax.Array,
               k: int) -> tuple[jax.Array, jax.Array]:
    """Merge concatenated partial top-k lists into one top-k per row.

    ``flat_scores``/``flat_ids``: [B, m·k] candidates from m sources
    (higher score = better; invalid lanes carry -inf / NULL). The fan-in
    tail shared by the sharded query merge below and the two-tier fan-out
    union (``core/tiered.py``).
    """
    top_s, idx = jax.lax.top_k(flat_scores, k)
    return top_s, jnp.take_along_axis(flat_ids, idx, axis=1)


def make_query_step(dp: DistParams, mesh):
    """Build the jitted distributed query step.

    queries f32[B, dim] (replicated intra-pod / sharded over pod) →
    (gids i32[B, k], scores f32[B, k]).
    """
    sp = dp.index.search
    axes = dp.axes
    state_spec = jax.tree.map(lambda _: P(axes), init_specs_tree(dp))
    q_spec = P(dp.pod_axis) if dp.pod_axis else P()

    def _merge(scores, ids, axis, k):
        all_s = jax.lax.all_gather(scores, axis)            # [m, B, k]
        all_i = jax.lax.all_gather(ids, axis)
        m, B, _ = all_s.shape
        flat_s = jnp.transpose(all_s, (1, 0, 2)).reshape(B, -1)
        flat_i = jnp.transpose(all_i, (1, 0, 2)).reshape(B, -1)
        return topk_union(flat_s, flat_i, k)

    stride = dp.gid_stride()

    def _step(state_stacked: GraphState, queries, key):
        state = _local(state_stacked)
        shard = _shard_index(axes)
        key = jax.random.fold_in(key, shard)
        # per-shard fan-out runs the batched beam engine inline (no nested
        # jit inside shard_map): every shard beam-searches its subgraph with
        # one engine call, then the partial top-k's cross the mesh
        starts = search_mod.batch_entry_points(
            state, key, queries.shape[0], sp.num_starts
        )
        res = search_mod.beam_search(state, queries, starts, sp)
        gids = jnp.where(
            res.ids != NULL, res.ids + shard * stride, NULL
        )
        k = sp.pool_size
        if dp.hierarchical_merge and len(axes) > 1:
            # two-stage fan-in (§Perf C): intra-'model' merge shrinks the
            # candidate set 16× before it crosses the 'data' axis
            s, i = _merge(res.scores, gids, axes[-1], k)
            top_s, top_i = _merge(s, i, axes[:-1], k)
        else:
            top_s, top_i = _merge(res.scores, gids, axes, k)
        return top_i, top_s

    smapped = compat.shard_map(
        _step, mesh=mesh,
        in_specs=(state_spec, q_spec, P()),
        out_specs=(q_spec, q_spec),
        check_vma=False,
    )
    return jax.jit(smapped)


def make_insert_step(dp: DistParams, mesh):
    """Routed batch insert: vectors f32[B, dim] + router ids i32[B]."""
    axes = dp.axes
    state_spec = jax.tree.map(lambda _: P(axes), init_specs_tree(dp))
    stride = dp.gid_stride()

    def _step(state_stacked, vecs, route, key):
        state = _local(state_stacked)
        shard = _shard_index(axes)
        n_shards = 1
        for a in axes:
            n_shards *= compat.axis_size(a)
        mine = (route % n_shards) == shard
        key = jax.random.fold_in(key, shard)
        # traceable impl, not the jitted wrapper: runs inline in shard_map
        state, ids = insert_mod.insert_batch_impl(
            state, vecs, mine, key, dp.index
        )
        gids = jnp.where(ids != NULL, ids + shard * stride, NULL)
        # owner announces its assigned gid; everyone else holds NULL(-1);
        # pmax is exact since real gids are >= 0
        gids = jax.lax.pmax(jnp.where(mine, gids, NULL), axes)
        return _restack(state), gids

    smapped = compat.shard_map(
        _step, mesh=mesh,
        in_specs=(state_spec, P(), P(), P()),
        out_specs=(state_spec, P()),
        check_vma=False,
    )
    return jax.jit(smapped, donate_argnums=(0,))


def make_delete_step(dp: DistParams, mesh, strategy: str):
    """Owner-masked distributed delete over global ids i32[B]."""
    axes = dp.axes
    state_spec = jax.tree.map(lambda _: P(axes), init_specs_tree(dp))

    stride = dp.gid_stride()

    def _step(state_stacked, gids, key):
        state = _local(state_stacked)
        shard = _shard_index(axes)
        owner = gids // stride
        lids = (gids % stride).astype(jnp.int32)
        # with growth armed the stride exceeds the live tier — local ids are
        # only valid below the *current* per-shard capacity
        valid = ((gids != NULL) & (owner == shard)
                 & (lids < dp.index.capacity))
        key = jax.random.fold_in(key, shard)
        state = delete_mod.delete_batch(
            state, lids, valid, key, strategy, dp.index
        )
        return _restack(state)

    smapped = compat.shard_map(
        _step, mesh=mesh,
        in_specs=(state_spec, P(), P()),
        out_specs=state_spec,
        check_vma=False,
    )
    return jax.jit(smapped, donate_argnums=(0,))


def make_consolidate_step(dp: DistParams, mesh):
    """One per-shard compaction pass (DESIGN.md §8), SPMD over the mesh.

    Every shard independently picks its ``consolidate_chunk`` lowest-id
    tombstones and runs the jitted compaction step on its subgraph (repair
    searches are shard-local by construction — there are no cross-shard
    edges). Shards with fewer tombstones than the chunk run a partially
    valid frame; fully drained shards no-op. The host loops passes until
    the most-loaded shard is drained.
    """
    axes = dp.axes
    state_spec = jax.tree.map(lambda _: P(axes), init_specs_tree(dp))
    mp = dp.index.maintenance
    chunk = mp.consolidate_chunk or mp.delete_chunk

    def _step(state_stacked: GraphState, key):
        state = _local(state_stacked)
        shard = _shard_index(axes)
        key = jax.random.fold_in(key, shard)
        tomb, tv = mask_to_slots(state.masked, chunk)
        state, _ = consolidate_mod.consolidate_chunk_impl(
            state, tomb, tv, key, dp.index
        )
        return _restack(state)

    smapped = compat.shard_map(
        _step, mesh=mesh,
        in_specs=(state_spec, P()),
        out_specs=state_spec,
        check_vma=False,
    )
    return jax.jit(smapped, donate_argnums=(0,))


def init_specs_tree(dp: DistParams) -> GraphState:
    """A GraphState-shaped tree of placeholders (for building spec pytrees)."""
    import numpy as np

    cap, dim = dp.index.capacity, dp.index.dim
    z = lambda *s: np.zeros(s, np.int8)  # noqa: E731 — structure only
    return GraphState(
        vectors=z(1, cap, dim), sqnorms=z(1, cap),
        codes=z(1, cap, dim), scales=z(1, cap),
        adj=z(1, cap, dp.index.d_out), radj=z(1, cap, dp.index.eff_d_in),
        alive=z(1, cap), present=z(1, cap), size=z(1),
        stamps=z(1, cap), clock=z(1),
        touch=z(1, cap), tclock=z(1),
        capacity=cap, dim=dim, d_out=dp.index.d_out,
        d_in=dp.index.eff_d_in, metric=dp.index.metric,
    )


# convenience host-level wrappers -------------------------------------------

def distributed_query(state, queries, key, dp, mesh):
    return make_query_step(dp, mesh)(state, queries, key)


def distributed_insert(state, vecs, route, key, dp, mesh):
    return make_insert_step(dp, mesh)(state, vecs, route, key)


def distributed_delete(state, gids, key, dp, mesh, strategy="global"):
    return make_delete_step(dp, mesh, strategy)(state, gids, key)


class ShardedSession:
    """Session-style driver over the sharded index (DESIGN.md §7).

    The distributed twin of :class:`repro.core.session.Session`: owns the
    stacked per-shard ``GraphState`` (donated through the jitted
    insert/delete steps — no stacked-buffer copies per update), builds each
    mesh program once *per capacity tier* (DESIGN.md §9: with
    ``maintenance.max_capacity`` armed, the insert gate grows every shard
    in lockstep and the programs rebuild for the new tier; gids stay valid
    because the encoding is strided by ``max_capacity``), derives op keys
    from one seed chain, and dispatches asynchronously — callers hold the
    returned device arrays and the host only blocks in ``flush()`` / result
    consumption.
    """

    def __init__(self, dp: DistParams, mesh, *, strategy: str | None = None,
                 seed: int = 0):
        from repro.core.session import PhaseTimers

        self.dp = dp
        self.mesh = mesh
        self._strategy = (strategy if strategy is not None
                          else dp.index.maintenance.strategy)
        self._build_steps()
        self.state = init_sharded_state(dp, mesh)
        self._base_key = jax.random.PRNGKey(seed)
        self._op_counter = 0
        self._pending: list[jax.Array] = []  # result arrays not yet flushed
        self._insert_results: list[jax.Array] = []  # gid arrays → n_refused
        self._window_t0: float | None = None
        self.timers = PhaseTimers()
        # consolidation bookkeeping — same host-gate scheme as the core
        # Session (DESIGN.md §8): overestimated tombstone count vs
        # underestimated present count, device-exact check only on crossing
        self._consolidate_counter = 0
        self._in_consolidate = False
        self._masked_hint = 0
        self._present_floor = 0
        # growth bookkeeping (DESIGN.md §9): `_free_floor` underestimates
        # the free-slot count of the *most loaded* shard (each insert op
        # subtracts its full batch — the router could land everything on one
        # shard), so the per-shard device-exact check runs only on crossing
        self._free_floor = dp.index.capacity

    def _build_steps(self) -> None:
        """(Re)build the four mesh programs for the current capacity tier."""
        self._query_step = make_query_step(self.dp, self.mesh)
        self._insert_step = make_insert_step(self.dp, self.mesh)
        self._delete_step = make_delete_step(self.dp, self.mesh,
                                             self._strategy)
        self._consolidate_step = make_consolidate_step(self.dp, self.mesh)

    @property
    def strategy(self) -> str:
        return self._strategy

    @strategy.setter
    def strategy(self, value: str) -> None:
        # the delete step bakes the strategy at build time — rebuild it so
        # reassignment behaves like the core Session's per-dispatch strategy
        self._strategy = value
        self._delete_step = make_delete_step(self.dp, self.mesh, value)

    def _op_key(self) -> jax.Array:
        if self._window_t0 is None:
            self._window_t0 = time.perf_counter()
        key = jax.random.fold_in(self._base_key, self._op_counter)
        self._op_counter += 1
        return key

    def query(self, queries) -> tuple[jax.Array, jax.Array]:
        """Fan-out query → (global ids i32[B,k], scores f32[B,k]), async."""
        t0 = time.perf_counter()
        gids, scores = self._query_step(
            self.state, jnp.asarray(queries), self._op_key()
        )
        self._pending += [gids, scores]
        self.timers.query_s += time.perf_counter() - t0
        self.timers.n_queries += int(jnp.shape(queries)[0])
        self.timers.n_ops += 1
        return gids, scores

    def insert(self, vecs, route) -> jax.Array:
        """Routed insert; returns assigned global ids (async device array).

        The insert boundary is also the growth trigger point (DESIGN.md
        §9): ``_ensure_room`` grows every shard in lockstep (and/or drains
        tombstones) before the batch lands. Rows a full shard still refuses
        come back as NULL gids and are counted into ``timers.n_refused`` at
        the next ``flush``.
        """
        n = int(jnp.shape(vecs)[0])
        if n:  # outside the insert stopwatch — gate work bills to its own
            self._ensure_room(n)  # consolidate_s / grow_s phases
        faults.crash_point("sharded-pre-dispatch")
        t0 = time.perf_counter()
        self.state, gids = self._insert_step(
            self.state, jnp.asarray(vecs),
            jnp.asarray(route, jnp.int32), self._op_key(),
        )
        self._free_floor = max(self._free_floor - n, 0)
        self._pending.append(gids)
        self._insert_results.append(gids)
        self.timers.insert_s += time.perf_counter() - t0
        self.timers.n_inserts += n
        self.timers.n_ops += 1
        faults.crash_point("sharded-post-dispatch")
        return gids

    def delete(self, gids) -> None:
        """Owner-masked distributed delete of global ids (async)."""
        faults.crash_point("sharded-pre-dispatch")
        t0 = time.perf_counter()
        self.state = self._delete_step(
            self.state, jnp.asarray(gids, jnp.int32), self._op_key()
        )
        self.timers.delete_s += time.perf_counter() - t0
        self.timers.n_deletes += int(jnp.shape(gids)[0])
        self.timers.n_ops += 1
        if self._strategy == "mask":
            self._masked_hint += int(jnp.shape(gids)[0])
            self._maybe_consolidate()
        else:
            self._present_floor = max(
                self._present_floor - int(jnp.shape(gids)[0]), 0)
        faults.crash_point("sharded-post-dispatch")

    # -- capacity growth (DESIGN.md §9, lockstep over shards) --------------
    def _per_shard_present(self) -> "np.ndarray":
        """Per-shard present counts (synchronizes on the stream)."""
        return np.asarray(jnp.sum(
            self.state.present,
            axis=tuple(range(1, self.state.present.ndim)),
        ))

    def _ensure_room(self, n: int) -> None:
        """Per-shard grow/consolidate gate at the insert boundary.

        Worst-case routing (whole batch on one shard) drives the host hint,
        so the exact per-shard measurement runs only when the most-loaded
        shard could conceivably refuse. Arbitration mirrors the core
        session: drain tombstones inside the compiled tier first, grow all
        shards to the next tier only when compaction cannot make room.
        """
        if self._free_floor >= n:
            return
        mp = self.dp.index.maintenance
        cap = self.dp.index.capacity
        present = self._per_shard_present()
        masked = self._per_shard_masked()
        self._masked_hint = int(masked.sum())
        self._present_floor = int(present.sum())
        free = cap - present
        min_free = int(free.min())
        if min_free < n and masked.sum() > 0 and (
                mp.consolidate_threshold is not None
                or mp.max_capacity is not None):
            self.consolidate(_per_shard=masked)
            min_free = int((free + masked).min())
        if min_free < n and mp.max_capacity is not None:
            target = next_capacity_tier(
                cap, cap - min_free + n, mp.growth_factor, mp.max_capacity)
            if target > cap:
                self.grow(target)
                min_free += target - cap
        self._free_floor = min_free

    def grow(self, new_capacity: int) -> None:
        """Grow every shard to ``new_capacity`` slots in lockstep.

        One `grow_state` pad over the stacked axis-1 layout keeps all
        shards in a single shape family; the four mesh programs are rebuilt
        once for the new tier. Requires ``maintenance.max_capacity`` to be
        set — the global-id stride is pinned to it (``DistParams.
        gid_stride``), which is what keeps gids handed out at smaller tiers
        decodable after the move.
        """
        mp = self.dp.index.maintenance
        if mp.max_capacity is None:
            raise ValueError(
                "ShardedSession growth requires maintenance.max_capacity: "
                "the global-id stride is pinned to it so existing gids "
                "survive the tier move")
        if new_capacity > mp.max_capacity:
            raise ValueError(
                f"new_capacity {new_capacity} exceeds max_capacity "
                f"{mp.max_capacity}")
        if new_capacity == self.dp.index.capacity:
            return
        faults.crash_point("sharded-pre-grow")
        t0 = time.perf_counter()
        if self._window_t0 is None:
            self._window_t0 = t0
        delta = new_capacity - self.dp.index.capacity
        self.state = grow_state(self.state, new_capacity, axis=1)
        self.dp = dataclasses.replace(
            self.dp,
            index=dataclasses.replace(self.dp.index, capacity=new_capacity),
        )
        self._build_steps()
        self._free_floor += delta
        self.timers.n_grows += 1
        self.timers.grow_s += time.perf_counter() - t0
        faults.crash_point("sharded-post-grow")

    # -- consolidation (DESIGN.md §8, per-shard) ---------------------------
    def _per_shard_masked(self) -> "np.ndarray":
        """Per-shard tombstone counts (synchronizes on the stream)."""
        return np.asarray(jnp.sum(
            self.state.masked,
            axis=tuple(range(1, self.state.present.ndim)),
        ))

    def consolidate(self, *, _per_shard=None) -> int:
        """Drain every shard's tombstones through the per-shard compaction
        step. Runs ``ceil(max_shard_tombstones / chunk)`` SPMD passes — the
        least-loaded shards no-op while the stragglers drain. Returns the
        total number of consolidated vertices (synchronizes on the count
        read — the auto-trigger hands over the counts it just measured via
        ``_per_shard`` instead of reducing twice; the passes themselves
        dispatch async)."""
        t0 = time.perf_counter()
        per_shard = (self._per_shard_masked() if _per_shard is None
                     else _per_shard)
        total = int(per_shard.sum())
        if total == 0:
            self._masked_hint = 0
            self.timers.consolidate_s += time.perf_counter() - t0
            return 0
        if self._window_t0 is None:
            self._window_t0 = time.perf_counter()
        mp = self.dp.index.maintenance
        chunk = mp.consolidate_chunk or mp.delete_chunk
        base = jax.random.fold_in(self._base_key,
                                  ops_mod.CONSOLIDATE_KEY_STREAM)
        for _ in range(-(-int(per_shard.max()) // chunk)):
            # lockstep SPMD passes: a kill between passes leaves some shards
            # drained further than others — exactly the torn-maintenance
            # state the recovery matrix must prove replayable
            faults.crash_point("sharded-consolidate-pass")
            key = jax.random.fold_in(base, self._consolidate_counter)
            self._consolidate_counter += 1
            self.state = self._consolidate_step(self.state, key)
        self.timers.consolidate_s += time.perf_counter() - t0
        self.timers.n_consolidations += 1
        self.timers.n_consolidated += total
        self.timers.n_ops += 1
        self._masked_hint = 0
        self._present_floor = max(self._present_floor - total, 0)
        return total

    def _maybe_consolidate(self) -> int:
        from repro.core.session import consolidate_gate_crossed

        thr = self.dp.index.maintenance.consolidate_threshold
        if self._in_consolidate or not consolidate_gate_crossed(
                thr, self._masked_hint, self._present_floor):
            return 0
        # exact check (synchronizes), then fire if the share really crossed
        per_shard = self._per_shard_masked()
        self._masked_hint = int(per_shard.sum())
        self._present_floor = int(jnp.sum(self.state.present))
        if not consolidate_gate_crossed(
                thr, self._masked_hint, self._present_floor):
            return 0
        self._in_consolidate = True
        try:
            return self.consolidate(_per_shard=per_shard)
        finally:
            self._in_consolidate = False

    def flush(self):
        """Block until every dispatched op landed (state AND the result
        arrays handed out since the last flush); settle the timers. Also a
        consolidation trigger point (DESIGN.md §8)."""
        self._maybe_consolidate()
        t0 = time.perf_counter()
        jax.block_until_ready(self._pending)
        jax.block_until_ready(self.state.adj)
        # refusal accounting (DESIGN.md §9): a full shard answers NULL gids;
        # they are counted here (the arrays are already materialized) so a
        # net-growing stream can never lose inserts silently
        for gids in self._insert_results:
            self.timers.n_refused += int((np.asarray(gids) == NULL).sum())
        self._insert_results.clear()
        self._pending.clear()
        self.timers.flush_s += time.perf_counter() - t0
        if self._window_t0 is not None:
            self.timers.wall_s += time.perf_counter() - self._window_t0
            self._window_t0 = None
        return self.timers

    def n_alive(self) -> int:
        return int(jnp.sum(self.state.alive))
