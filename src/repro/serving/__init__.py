from repro.serving.batcher import BatchedServer, ServeConfig

__all__ = ["BatchedServer", "ServeConfig"]
