"""Request batching + quorum degradation — the online serving front-end.

Production behaviours the 1000-node story needs (DESIGN.md §5, §11):

  · **adaptive batching** — requests accumulate until ``max_batch`` or
    ``max_wait_s``; the device step always runs at a pad-stable shape so
    one compiled program serves every batch size (no recompiles at p99).
  · **quorum degradation** — the fan-out/merge query only *needs* all
    shards for exact results; with ``quorum < 1.0`` the merge accepts the
    first ⌈quorum·P⌉ shard results and degrades recall by ≤ (1-quorum)
    instead of stalling on a straggler. Simulated here by masking shard
    contributions (the merge math is identical to dropping late arrivals).
  · **graceful degradation under overload/recovery** — a bounded queue
    that sheds with a typed error past ``max_queue`` (backpressure to the
    caller, not an unbounded latency cliff), a per-request deadline so
    requests that waited too long fail fast instead of wasting a device
    step, and a readiness gate that holds traffic while the underlying
    session is replaying its journal after a crash.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import numpy as np

from repro.core.graph import NULL
from repro.core.maintenance import IPGMIndex
from repro.core.session import Session


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 64
    max_wait_s: float = 0.005
    k: int = 10
    quorum: float = 1.0        # fraction of shards required (sharded mode)
    max_queue: int | None = None   # bound on queued requests (None = ∞)
    deadline_s: float | None = None  # per-request age limit at drain time


class ServerOverloadError(RuntimeError):
    """submit() refused: the bounded queue is full (load shed)."""


class ServerNotReadyError(RuntimeError):
    """submit() refused: the server is holding traffic (e.g. recovery)."""


class BatchedServer:
    """Pad-stable batched front-end over a :class:`Session`.

    Accepts a streaming ``Session`` directly, or an :class:`IPGMIndex`
    facade (whose underlying session is used). Every device step is one
    op-IR query micro-batch at the ``max_batch`` shape — the same padded
    program for every batch size — dispatched async and consumed when the
    step's results are handed back.

    Compile note: a session with ``unified_dispatch=True`` traces the full
    op switch (incl. the insert/delete-repair branches) at the serving
    shape on the first step; a query-only server can avoid that cold-start
    cost by handing in ``Session(..., unified_dispatch=False)`` (what the
    ``IPGMIndex`` facade uses), which compiles only the query branch.

    ``clock``/``sleep`` are injectable for deterministic tests of the
    batching window (tests/test_serving.py).
    """

    _POLL_S = 0.0005  # wait-slice; bounds drain latency jitter, not a spin

    def __init__(
        self,
        index: IPGMIndex | Session,
        cfg: ServeConfig = ServeConfig(),
        *,
        clock: Callable[[], float] = time.perf_counter,
        sleep: Callable[[float], None] = time.sleep,
    ):
        # `index` is kept only for caller introspection (back-compat attr);
        # every device step goes through `self.session` — don't mix paths
        self.index = index
        self.session = index.session if isinstance(index, IPGMIndex) else index
        self.cfg = cfg
        self._clock = clock
        self._sleep = sleep
        # queue entries carry their submit time for the deadline check
        self._queue: deque[tuple[int, np.ndarray, float]] = deque()
        self._next_id = 0
        self._ready = True
        self.stats = {"batches": 0, "requests": 0, "pad_waste": 0.0,
                      "shed_overload": 0, "shed_deadline": 0}
        # rid → reason for every request shed after admission (deadline):
        # callers poll this the same way they poll step() results
        self.failed: dict[int, str] = {}

    @property
    def ready(self) -> bool:
        """False while traffic must be held: an explicit ``set_ready(False)``
        or the underlying session replaying its journal (DESIGN.md §11)."""
        return self._ready and not getattr(self.session, "recovering", False)

    def set_ready(self, ready: bool) -> None:
        self._ready = bool(ready)

    def submit(self, query: np.ndarray) -> int:
        if not self.ready:
            raise ServerNotReadyError(
                "server is not accepting traffic (recovery in progress?)")
        if (self.cfg.max_queue is not None
                and len(self._queue) >= self.cfg.max_queue):
            self.stats["shed_overload"] += 1
            raise ServerOverloadError(
                f"queue full ({self.cfg.max_queue} pending); load shed")
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, np.asarray(query, np.float32), self._clock()))
        return rid

    def _expire(self) -> None:
        """Fail queued requests whose age exceeds ``deadline_s`` — they shed
        *before* padding/dispatch, so a stale backlog never spends a device
        step producing answers nobody is waiting for. Submit times are
        monotone, so expired entries are always a queue prefix (O(1)
        amortized per drained request)."""
        if self.cfg.deadline_s is None:
            return
        now = self._clock()
        while self._queue and now - self._queue[0][2] > self.cfg.deadline_s:
            rid, _, _ = self._queue.popleft()
            self.failed[rid] = "deadline"
            self.stats["shed_deadline"] += 1

    def _drain(self) -> list[tuple[int, np.ndarray]]:
        """Collect up to ``max_batch`` requests for one device step.

        The ``max_wait_s`` window is armed when the drain begins; once at
        least one request is in hand the drain honors it — sleeping in
        short slices (never spinning hot) so requests submitted
        concurrently during the window still join the batch. An idle queue
        returns immediately instead of holding the window open. Worst-case
        added latency per request is therefore queue-age at drain entry
        plus ``max_wait_s``.
        """
        out: list[tuple[int, np.ndarray]] = []
        deadline = self._clock() + self.cfg.max_wait_s
        while len(out) < self.cfg.max_batch:
            self._expire()
            if self._queue:
                rid, q, _ = self._queue.popleft()
                out.append((rid, q))
                continue
            if not out:
                break  # idle server: nothing to wait *for*
            remaining = deadline - self._clock()
            if remaining <= 0:
                break
            self._sleep(min(remaining, self._POLL_S))
        return out

    def step(self) -> dict[int, tuple[np.ndarray, np.ndarray]]:
        """Serve one batch; returns {request_id: (ids, scores)}."""
        batch = self._drain()
        if not batch:
            return {}
        B = self.cfg.max_batch
        dim = batch[0][1].shape[-1]
        padded = np.zeros((B, dim), np.float32)
        for i, (_, q) in enumerate(batch):
            padded[i] = q
        # one op-IR micro-batch at the pad-stable max_batch shape; the
        # handle resolves (blocks) only when this step's results are needed
        ids, scores = self.session.query(
            padded, k=self.cfg.k, chunk=B
        ).result()
        self.stats["batches"] += 1
        self.stats["requests"] += len(batch)
        self.stats["pad_waste"] += 1.0 - len(batch) / B
        return {rid: (ids[i], scores[i]) for i, (rid, _) in enumerate(batch)}


def quorum_merge(
    shard_ids: np.ndarray,     # i32[P, B, k] per-shard top-k (global ids)
    shard_scores: np.ndarray,  # f32[P, B, k]
    arrived: np.ndarray,       # bool[P] which shards answered in time
    k: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Merge only the arrived shards — the straggler-tolerant fan-in.

    Recall loss is bounded by the fraction of ground-truth neighbors living
    on the missing shards (≤ (P-|arrived|)/P in expectation under hashing).
    """
    P, B, kk = shard_ids.shape
    s = np.where(arrived[:, None, None], shard_scores, -np.inf)
    flat_s = np.transpose(s, (1, 0, 2)).reshape(B, P * kk)
    flat_i = np.transpose(shard_ids, (1, 0, 2)).reshape(B, P * kk)
    order = np.argsort(-flat_s, axis=1)[:, :k]
    top_s = np.take_along_axis(flat_s, order, axis=1)
    top_i = np.take_along_axis(flat_i, order, axis=1)
    top_i = np.where(np.isfinite(top_s), top_i, NULL)
    return top_i, top_s
