"""End-to-end training driver: data → step → checkpoint → restart.

Runs any registry LM arch (smoke or full config) with AdamW, periodic
atomic checkpoints, preemption simulation (--preempt-at) and exact resume —
the fault-tolerance path exercised by tests/test_checkpoint.py and
examples/train_lm.py.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import registry as reg
from repro.data.tokens import TokenStream
from repro.models import transformer as tfm
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.steps import make_lm_train_step


def train_lm(
    arch: str = "qwen3-1.7b",
    *,
    smoke: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 64,
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    resume: bool = False,
    preempt_at: int | None = None,
    seed: int = 0,
    log_every: int = 10,
) -> dict:
    spec = reg.get_arch(arch)
    cfg = spec.smoke_config() if smoke else spec.config_for_shape("train_4k")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps)

    params = tfm.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw_init(params)
    stream = TokenStream(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)
    start = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if resume and mgr and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore(
            None, (params, opt_state)
        )
        stream.load_state_dict(extra["stream"])
        start = int(extra["host_step"])
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_lm_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    losses = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch_np = stream.next_batch()
        batch_dev = jax.tree.map(jax.numpy.asarray, batch_np)
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state),
                     extra={"stream": stream.state_dict(),
                            "host_step": step + 1})
        if preempt_at is not None and step + 1 >= preempt_at:
            print(f"simulated preemption at step {step + 1}")
            return {"losses": losses, "preempted_at": step + 1,
                    "params": params}
    dt = time.perf_counter() - t0
    return {"losses": losses, "seconds": dt, "params": params,
            "final_loss": losses[-1] if losses else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--preempt-at", type=int, default=None)
    args = ap.parse_args()
    out = train_lm(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, preempt_at=args.preempt_at,
    )
    if "final_loss" in out and out["final_loss"] is not None:
        print(f"final loss {out['final_loss']:.4f} in {out['seconds']:.1f}s")


if __name__ == "__main__":
    main()
