"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape) cell on the 16×16
single-pod mesh AND the 2×16×16 multi-pod mesh, records
``memory_analysis()`` / ``cost_analysis()`` / per-collective byte counts
into ``results/dryrun_manifest.json`` (incremental + atomic), and fails
loudly on sharding bugs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--skip-existing] [--list]
"""
from __future__ import annotations

import os  # XLA_FLAGS must precede every other jax-touching import
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro import compat

MANIFEST = Path(__file__).resolve().parents[3] / "results" / "dryrun_manifest.json"

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\])"
    r"[^=]*?\b(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)\b"
)
_TUPLE_ELEM_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum per-partition result bytes of every collective op in optimized HLO."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1]
        # result type: between '=' and the op name
        head = line.split(m.group("op"))[0]
        total = 0
        if m.group("dtype"):
            total = _shape_bytes(m.group("dtype"), m.group("dims"))
        else:  # tuple result
            seg = head.split("=", 1)[-1]
            for dt, dims in _TUPLE_ELEM_RE.findall(seg):
                total += _shape_bytes(dt, dims)
        out[op] = out.get(op, 0) + total
    return out


def _load_manifest() -> dict:
    if MANIFEST.exists():
        return json.loads(MANIFEST.read_text())
    return {}


def _save_manifest(m: dict) -> None:
    MANIFEST.parent.mkdir(parents=True, exist_ok=True)
    tmp = MANIFEST.with_suffix(".tmp")
    tmp.write_text(json.dumps(m, indent=1, sort_keys=True))
    tmp.replace(MANIFEST)


def run_cell(arch_id: str, shape: str, mesh_kind: str) -> dict:
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    with compat.use_mesh(mesh):
        cell = build_cell(arch_id, shape, mesh)
        lowered = cell.fn.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        rec: dict = {
            "status": "ok",
            "kind": cell.kind,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "meta": cell.meta,
        }
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes",
                )
                if hasattr(ma, k)
            }
        except Exception as e:  # CPU backend may not support it
            rec["memory"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            rec["cost"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "optimal_seconds")
                    or k.startswith("bytes accessed")
                )
            }
        except Exception as e:
            rec["cost"] = {"error": str(e)}
        try:
            rec["collectives"] = collective_bytes(compiled.as_text())
        except Exception as e:
            rec["collectives"] = {"error": str(e)}
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    from repro.launch.cells import all_cells

    cells = all_cells()
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    manifest = _load_manifest()
    n_ok = n_skip = n_fail = 0

    for arch_id, shape, skip in cells:
        if args.arch and arch_id != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        for mk in meshes:
            key = f"{arch_id}|{shape}|{mk}"
            if skip:
                manifest[key] = {"status": "skipped", "reason": skip}
                n_skip += 1
                print(f"SKIP {key}: {skip}")
                continue
            if args.list:
                print(f"CELL {key}")
                continue
            if args.skip_existing and manifest.get(key, {}).get("status") == "ok":
                print(f"HAVE {key}")
                continue
            print(f"RUN  {key} ...", flush=True)
            try:
                rec = run_cell(arch_id, shape, mk)
                manifest[key] = rec
                n_ok += 1
                flops = rec.get("cost", {}).get("flops", float("nan"))
                print(
                    f"  ok: lower {rec['lower_s']}s compile {rec['compile_s']}s"
                    f" flops/dev {flops:.3e}"
                    f" coll {rec.get('collectives', {})}"
                )
            except Exception as e:
                manifest[key] = {
                    "status": "fail",
                    "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:],
                }
                n_fail += 1
                print(f"  FAIL: {type(e).__name__}: {e}")
            _save_manifest(manifest)

    print(f"\ndone: ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
