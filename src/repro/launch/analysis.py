"""Trip-count-aware jaxpr cost analyzer — the roofline's FLOP/byte source.

Why not ``compiled.cost_analysis()``: XLA's CPU analysis counts while/scan
bodies ONCE (verified: a 10-step scan of matmuls reports 1 matmul), which
undercounts scanned-layer models by O(layers × seq-blocks). This walker
recurses the *jaxpr* instead, multiplying scan bodies by their trip counts
(fori_loop/lax.map lower to scan with static length), so the numbers are
exact for everything the zoo uses. ``while`` trip counts are unknowable
statically; the only whiles in the system are the IPGM searches, whose cost
is bounded analytically by ``max_steps`` (callers pass ``while_trip``).

Outputs (GLOBAL logical program, divide by chip count for per-chip terms):
  flops       — dot/conv exact (2·M·N·K·batch); elementwise/reduce 1/elem
  hbm_bytes   — roofline traffic model: operands+results of dots, gathers,
                scatters, and program I/O (elementwise assumed fused)
  comm_bytes  — explicit collectives in the jaxpr (shard_map programs);
                pjit-auto collectives are modeled separately
                (launch/collectives.py) since GSPMD inserts them post-jaxpr.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.extend.core  # explicit — not re-exported via the jax namespace
import numpy as np


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    comm_bytes: float = 0.0
    gather_bytes: float = 0.0
    unknown_whiles: int = 0

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(
            self.flops + o.flops, self.hbm_bytes + o.hbm_bytes,
            self.comm_bytes + o.comm_bytes,
            self.gather_bytes + o.gather_bytes,
            self.unknown_whiles + o.unknown_whiles,
        )

    def scale(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.hbm_bytes * k, self.comm_bytes * k,
                    self.gather_bytes * k, self.unknown_whiles)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


def _nbytes(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    try:
        itemsize = np.dtype(aval.dtype).itemsize
    except TypeError:  # extended dtypes (typed PRNG keys etc.)
        itemsize = getattr(aval.dtype, "itemsize", 4)
    return float(np.prod(aval.shape, dtype=np.float64) * itemsize)


def _nelems(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64))


_ELEMWISE_SKIP = {
    "broadcast_in_dim", "reshape", "transpose", "convert_element_type",
    "squeeze", "slice", "concatenate", "pad", "iota", "rev",
    "dynamic_slice", "dynamic_update_slice", "bitcast_convert_type",
    "copy", "stop_gradient", "select_n",
}

_COLLECTIVES = {"psum", "all_gather", "ppermute", "all_to_all",
                "reduce_scatter", "psum_scatter", "pmax", "pmin"}


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = np.prod([lhs.shape[i] for i in lb], dtype=np.float64) if lb else 1.0
    contract = np.prod([lhs.shape[i] for i in lc], dtype=np.float64) if lc else 1.0
    m = np.prod(
        [s for i, s in enumerate(lhs.shape) if i not in set(lc) | set(lb)],
        dtype=np.float64,
    )
    n = np.prod(
        [s for i, s in enumerate(rhs.shape) if i not in set(rc) | set(rb)],
        dtype=np.float64,
    )
    return float(2.0 * batch * m * n * contract)


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        if isinstance(v, jax.extend.core.ClosedJaxpr):
            yield v
        elif isinstance(v, jax.extend.core.Jaxpr):
            yield jax.extend.core.ClosedJaxpr(v, ())
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jax.extend.core.ClosedJaxpr):
                    yield x


def jaxpr_cost(closed, *, while_trip: int = 1) -> Cost:
    total = Cost()
    for eqn in closed.jaxpr.eqns:
        name = eqn.primitive.name
        out_bytes = sum(_nbytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(_nbytes(v.aval) for v in eqn.invars)

        if name == "dot_general":
            total.flops += _dot_flops(eqn)
            total.hbm_bytes += in_bytes + out_bytes
        elif name in ("conv_general_dilated",):
            # flops ≈ 2 · out_elems · (in_ch · prod(kernel_spatial))
            out = eqn.outvars[0].aval
            rhs = eqn.invars[1].aval
            k_elems = np.prod(rhs.shape, dtype=np.float64) / rhs.shape[
                eqn.params["dimension_numbers"].rhs_spec[0]
            ]
            total.flops += float(2 * _nelems(out) * k_elems)
            total.hbm_bytes += in_bytes + out_bytes
        elif name in ("gather",):
            total.hbm_bytes += out_bytes * 2  # index read + row read ≈ result
            total.gather_bytes += out_bytes
        elif name in ("scatter", "scatter-add", "scatter_add", "scatter_min",
                      "scatter_max", "scatter_mul"):
            # XLA aliases functional updates in-place (donated carries), so
            # traffic = touched elements (read+write) + indices, NOT a full
            # rewrite of the result array.
            upd = _nbytes(eqn.invars[-1].aval)
            idx = _nbytes(eqn.invars[1].aval) if len(eqn.invars) > 2 else 0.0
            total.hbm_bytes += 2 * upd + idx
            total.gather_bytes += upd
        elif name in ("scan",):
            inner = jaxpr_cost(eqn.params["jaxpr"], while_trip=while_trip)
            total = total + inner.scale(eqn.params["length"])
        elif name in ("while",):
            inner = jaxpr_cost(eqn.params["body_jaxpr"], while_trip=while_trip)
            total = total + inner.scale(while_trip)
            total.unknown_whiles += 1 if while_trip == 1 else 0
        elif name in ("cond",):
            branches = [jaxpr_cost(b, while_trip=while_trip)
                        for b in eqn.params["branches"]]
            worst = max(branches, key=lambda c: c.flops + c.hbm_bytes)
            total = total + worst
        elif name in ("pjit", "jit", "closed_call", "core_call", "remat_call",
                      "custom_jvp_call", "custom_vjp_call", "checkpoint",
                      "custom_vjp_call_jaxpr", "shard_map", "remat2"):
            for sub in _sub_jaxprs(eqn):
                total = total + jaxpr_cost(sub, while_trip=while_trip)
        elif name in _COLLECTIVES:
            total.comm_bytes += max(in_bytes, out_bytes)
            total.hbm_bytes += in_bytes + out_bytes
        elif name in ("sort",):
            n = _nelems(eqn.invars[0].aval)
            total.flops += float(n * max(np.log2(max(n, 2)), 1))
            total.hbm_bytes += in_bytes + out_bytes
        elif name in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                      "reduce_and", "reduce_or", "argmax", "argmin",
                      "reduce_precision", "cumsum", "cumlogsumexp", "cummax",
                      "cummin", "cumprod"):
            total.flops += sum(_nelems(v.aval) for v in eqn.invars)
        elif name in ("top_k",):
            n = _nelems(eqn.invars[0].aval)
            total.flops += float(n * np.log2(max(eqn.params.get("k", 2), 2)))
            total.hbm_bytes += in_bytes + out_bytes
        elif name in _ELEMWISE_SKIP:
            pass  # layout/movement — assumed fused or free at roofline level
        elif name == "pallas_call":
            # interpret-mode kernels: cost their jaxpr body directly
            for sub in _sub_jaxprs(eqn):
                total = total + jaxpr_cost(sub, while_trip=while_trip)
        else:
            # default: elementwise-ish → 1 flop per output element
            total.flops += sum(_nelems(v.aval) for v in eqn.outvars)
    return total


def cost_of(fn, *args, while_trip: int = 1, io_bytes: bool = True) -> Cost:
    """Trace ``fn(*args)`` (ShapeDtypeStructs fine) and walk its jaxpr."""
    closed = jax.make_jaxpr(fn)(*args)
    c = jaxpr_cost(closed, while_trip=while_trip)
    if io_bytes:
        for v in closed.jaxpr.invars:
            c.hbm_bytes += _nbytes(v.aval)
        for v in closed.jaxpr.outvars:
            c.hbm_bytes += _nbytes(v.aval)
    return c
