"""Online ANN serving driver — the paper's production loop (Alg 3 at scale).

Drives an (op, payload) stream through a streaming :class:`Session`
(DESIGN.md §7): each maintenance step dispatches its delete and insert ops
asynchronously through the unified op IR and synchronizes once per step
(``flush``), so host-side bookkeeping overlaps device execution; queries run
through the same session for recall accounting. Per-phase latency books come
from the session's flush-based ``PhaseTimers``.

    PYTHONPATH=src python -m repro.launch.serve --scale 2000 --steps 3
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (
    IndexParams,
    MaintenanceParams,
    SearchParams,
    Session,
    TieredSession,
)
from repro.data.workload import make_workload


def serve_online(
    *,
    dataset: str = "sift",
    strategy: str = "global",
    n_base: int = 2000,
    n_steps: int = 3,
    batch_size: int = 200,
    n_queries: int = 256,
    d_out: int = 12,
    pool: int = 32,
    seed: int = 0,
    k: int = 10,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 0,
    recover: bool = False,
    tiered: bool = False,
    fresh_capacity: int | None = None,
) -> list[dict]:
    wl = make_workload(
        dataset, n_base=n_base, n_steps=n_steps, batch_size=batch_size,
        n_queries=n_queries, pattern="random", seed=seed,
    )
    dim = wl.base.shape[1]
    capacity = n_base + n_steps * batch_size + 16
    maintenance = MaintenanceParams(strategy=strategy)
    if tiered:
        # two-tier serving (DESIGN.md §12): inserts land in a small fresh
        # tier, deletes of main-resident points tombstone, and the
        # streaming merge drains fresh→main one chunk per op
        fresh_capacity = fresh_capacity or max(2 * batch_size, 256)
        maintenance = MaintenanceParams(
            strategy="mask", merge_fresh_threshold=0.5,
            merge_tombstone_threshold=0.25,
            max_capacity=2 * capacity)
    params = IndexParams(
        capacity=capacity, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2),
        maintenance=maintenance,
    )
    if recover:
        # crash restart: newest complete checkpoint + journal replay
        # (DESIGN.md §11) — params/strategy/seed must match the dead run
        if checkpoint_dir is None:
            raise ValueError("--recover requires --checkpoint-dir")
        t0 = time.perf_counter()
        if tiered:
            session = TieredSession.recover(
                checkpoint_dir, params, fresh_capacity=fresh_capacity,
                seed=seed)
        else:
            session = Session.recover(
                checkpoint_dir, params, strategy=strategy, seed=seed)
        info = session.recovery_info or {}
        print(
            f"recovered from {checkpoint_dir}: step={info.get('step')} "
            f"replayed={info.get('n_replayed', 0)} ops "
            f"(skipped {info.get('n_skipped', 0)}, "
            f"dropped {info.get('dropped_bytes', 0)}B torn tail) "
            f"in {time.perf_counter() - t0:.2f}s"
        )
    elif tiered:
        session = TieredSession(params, fresh_capacity=fresh_capacity,
                                seed=seed, checkpoint_dir=checkpoint_dir)
    else:
        # a checkpoint_dir arms the write-ahead journal automatically, so
        # every acknowledged op survives a crash up to the fsync policy
        session = Session(params, seed=seed, checkpoint_dir=checkpoint_dir)

    if recover and session._op_counter > 0:
        # the recovered timeline already contains the base build (and
        # whatever stream prefix was acknowledged before the crash); the
        # deterministic workload lets us rebuild the id map host-side
        print("skipping base build (recovered mid-stream)")
        id_map = list(range(n_base))
    else:
        print(f"building base index ({n_base} × d={dim}) ...")
        t0 = time.perf_counter()
        if tiered:
            # a fresh tier only holds fresh_capacity rows at once: bulk-load
            # in fresh-sized waves, the merge engine drains between them
            id_map = []
            for lo in range(0, n_base, fresh_capacity):
                ids = session.insert(wl.base[lo:lo + fresh_capacity]).result()
                id_map.extend(ids)
        else:
            ids = session.insert(wl.base).result()
            id_map = list(np.asarray(ids))   # pool position → graph id
        session.flush()
        print(f"  built in {time.perf_counter() - t0:.1f}s")

    records = []
    for step in range(wl.n_steps):
        rec = {"step": step}
        dele_pos = wl.step_deletes[step]
        gids = [id_map[p] for p in dele_pos]
        # one maintenance step = delete + insert dispatched back-to-back,
        # one synchronization point
        t0 = time.perf_counter()
        session.delete(np.asarray(gids))
        h_ins = session.insert(wl.step_inserts[step])
        new_ids = h_ins.result()
        session.flush()
        rec["update_s"] = time.perf_counter() - t0
        rec["update_ops_per_s"] = (len(gids) + len(new_ids)) / rec["update_s"]
        id_map.extend(new_ids)

        t0 = time.perf_counter()
        rec["recall@10"] = session.recall(wl.queries, k=k)
        rec["query_s"] = time.perf_counter() - t0
        rec["qps"] = n_queries / rec["query_s"]
        rec.update(session.stats())
        if (checkpoint_dir is not None and checkpoint_every
                and (step + 1) % checkpoint_every == 0):
            session.save(step)   # publishes atomically, truncates the journal
            rec["checkpointed"] = True
        records.append(rec)
        print(
            f"step {step}: recall@{k}={rec['recall@10']:.3f} "
            f"qps={rec['qps']:.1f} upd={rec['update_s']:.2f}s "
            f"({rec['update_ops_per_s']:.0f} ops/s) alive={rec['n_alive']}"
        )
    print("session timers:", session.flush().to_dict())
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift")
    ap.add_argument("--strategy", default="global")
    ap.add_argument("--scale", type=int, default=2000)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="arm checkpoints + the write-ahead op journal")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="save every N maintenance steps (0 = never)")
    ap.add_argument("--recover", action="store_true",
                    help="restart from checkpoint-dir: newest complete "
                         "checkpoint + journal replay (DESIGN.md §11)")
    ap.add_argument("--tiered", action="store_true",
                    help="serve through the two-tier index (fresh tier + "
                         "streaming merge, DESIGN.md §12)")
    ap.add_argument("--fresh-capacity", type=int, default=None,
                    help="fresh-tier slot count (tiered mode only)")
    args = ap.parse_args()
    serve_online(
        dataset=args.dataset, strategy=args.strategy, n_base=args.scale,
        n_steps=args.steps, batch_size=max(args.scale // 10, 10),
        n_queries=min(256, args.scale),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        recover=args.recover,
        tiered=args.tiered,
        fresh_capacity=args.fresh_capacity,
    )


if __name__ == "__main__":
    main()
