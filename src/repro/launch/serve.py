"""Online ANN serving driver — the paper's production loop (Alg 3 at scale).

Drives an (op, payload) stream through a streaming :class:`Session`
(DESIGN.md §7): each maintenance step dispatches its delete and insert ops
asynchronously through the unified op IR and synchronizes once per step
(``flush``), so host-side bookkeeping overlaps device execution; queries run
through the same session for recall accounting. Per-phase latency books come
from the session's flush-based ``PhaseTimers``.

    PYTHONPATH=src python -m repro.launch.serve --scale 2000 --steps 3
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import IndexParams, MaintenanceParams, SearchParams, Session
from repro.data.workload import make_workload


def serve_online(
    *,
    dataset: str = "sift",
    strategy: str = "global",
    n_base: int = 2000,
    n_steps: int = 3,
    batch_size: int = 200,
    n_queries: int = 256,
    d_out: int = 12,
    pool: int = 32,
    seed: int = 0,
    k: int = 10,
) -> list[dict]:
    wl = make_workload(
        dataset, n_base=n_base, n_steps=n_steps, batch_size=batch_size,
        n_queries=n_queries, pattern="random", seed=seed,
    )
    dim = wl.base.shape[1]
    capacity = n_base + n_steps * batch_size + 16
    params = IndexParams(
        capacity=capacity, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2),
        maintenance=MaintenanceParams(strategy=strategy),
    )
    session = Session(params, seed=seed)

    print(f"building base index ({n_base} × d={dim}) ...")
    t0 = time.perf_counter()
    ids = session.insert(wl.base).result()
    session.flush()
    id_map = list(np.asarray(ids))       # pool position → graph id
    print(f"  built in {time.perf_counter() - t0:.1f}s")

    records = []
    for step in range(wl.n_steps):
        rec = {"step": step}
        dele_pos = wl.step_deletes[step]
        gids = [id_map[p] for p in dele_pos]
        # one maintenance step = delete + insert dispatched back-to-back,
        # one synchronization point
        t0 = time.perf_counter()
        session.delete(np.asarray(gids))
        h_ins = session.insert(wl.step_inserts[step])
        new_ids = h_ins.result()
        session.flush()
        rec["update_s"] = time.perf_counter() - t0
        rec["update_ops_per_s"] = (len(gids) + len(new_ids)) / rec["update_s"]
        id_map.extend(new_ids)

        t0 = time.perf_counter()
        rec["recall@10"] = session.recall(wl.queries, k=k)
        rec["query_s"] = time.perf_counter() - t0
        rec["qps"] = n_queries / rec["query_s"]
        rec.update(session.stats())
        records.append(rec)
        print(
            f"step {step}: recall@{k}={rec['recall@10']:.3f} "
            f"qps={rec['qps']:.1f} upd={rec['update_s']:.2f}s "
            f"({rec['update_ops_per_s']:.0f} ops/s) alive={rec['n_alive']}"
        )
    print("session timers:", session.flush().to_dict())
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift")
    ap.add_argument("--strategy", default="global")
    ap.add_argument("--scale", type=int, default=2000)
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()
    serve_online(
        dataset=args.dataset, strategy=args.strategy, n_base=args.scale,
        n_steps=args.steps, batch_size=max(args.scale // 10, 10),
        n_queries=min(256, args.scale),
    )


if __name__ == "__main__":
    main()
