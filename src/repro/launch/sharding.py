"""Sharding rules per family — FSDP('data') × TP('model') (+ DP over 'pod').

Parameter rules are name-based tree maps; every rule is divisibility-checked
against the assigned configs in tests/test_sharding.py. Optimizer moments
shard identically to their parameter.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import all_axes, batch_axes

FSDP, TP = "data", "model"


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_rule(path: tuple, leaf) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1] if keys else ""
    joined = "/".join(str(k) for k in keys)
    if "embed" in joined:
        return P(FSDP, TP)
    if name == "w":  # dense layers inside stacked blocks: [G, d_in, d_out]
        if any(f"/{k}/" in f"/{joined}/" for k in ("wq", "wk", "wv", "w_gate", "w_up")):
            return P(None, FSDP, TP)
        if any(f"/{k}/" in f"/{joined}/" for k in ("wo", "w_down")):
            return P(None, TP, FSDP)
    if name == "router":            # [G, d, E]
        return P(None, FSDP, None)
    if name == "w_in":              # [G, E, d, n_in]
        return P(None, FSDP, None, TP)
    if name == "w_out":             # [G, E, f, d]
        return P(None, FSDP, TP, None)
    if name == "shared_in":         # [G, d, n_in]
        return P(None, FSDP, TP)
    if name == "shared_out":        # [G, f*, d]
        return P(None, TP, FSDP)
    return P()  # norms, scalars → replicated


def lm_param_specs(params_shape: Any) -> Any:
    return jax.tree_util.tree_map_with_path(_lm_rule, params_shape)


def _lm_rule_inference(path: tuple, leaf) -> P:
    """Serving sharding (§Perf hillclimb B): params replicated over 'data'
    (no per-step FSDP all-gather), TP over 'model'; MoE experts stay EP over
    'data' (stationary weights, token a2a)."""
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1] if keys else ""
    joined = "/".join(str(k) for k in keys)
    if "embed" in joined:
        return P(TP, None)
    if name == "w":
        if any(f"/{k}/" in f"/{joined}/" for k in ("wq", "wk", "wv", "w_gate", "w_up")):
            return P(None, None, TP)
        if any(f"/{k}/" in f"/{joined}/" for k in ("wo", "w_down")):
            return P(None, TP, None)
    if name == "router":
        return P()
    if name == "w_in":              # [G, E, d, n_in] — EP: E stays on data
        return P(None, FSDP, None, TP)
    if name == "w_out":
        return P(None, FSDP, TP, None)
    if name == "shared_in":
        return P(None, None, TP)
    if name == "shared_out":
        return P(None, TP, None)
    return P()


def lm_param_specs_inference(params_shape: Any) -> Any:
    return jax.tree_util.tree_map_with_path(_lm_rule_inference, params_shape)


def sharded_bytes_per_dev(sds_tree: Any, spec_tree: Any, mesh) -> float:
    """Per-device bytes of a sharded pytree — the roofline's HBM-IO term."""
    import numpy as np
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    leaves = jax.tree.leaves(sds_tree)
    specs = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: x is None or type(x).__name__ == "PartitionSpec")
    total = 0.0
    for leaf, sp in zip(leaves, specs, strict=True):
        try:
            itemsize = np.dtype(leaf.dtype).itemsize
        except TypeError:
            itemsize = 4
        n = float(np.prod(leaf.shape, dtype=np.float64)) * itemsize
        div = 1
        if sp is not None:
            for part in tuple(sp):
                if part is None:
                    continue
                names = (part,) if isinstance(part, str) else tuple(part)
                for nm in names:
                    div *= axes.get(nm, 1)
        total += n / div
    return total


def lm_batch_specs(cell_kind: str, mesh, specs: dict) -> dict:
    ba = batch_axes(mesh)
    if cell_kind == "train":
        return {k: P(ba) for k in specs}
    if cell_kind == "prefill":
        return {"tokens": P(ba)}
    if cell_kind == "decode":
        return {"tokens": P(ba)}
    raise ValueError(cell_kind)


def lm_cache_specs_sharding(cell, mesh) -> dict:
    """KV cache [G, B, S, Hkv, dh]: batch over data axes, seq over model —
    except long_500k (B=1) where seq shards over everything."""
    ba = batch_axes(mesh)
    B = cell.sizes["batch"]
    if B == 1:
        kv = P(None, None, all_axes(mesh), None, None)
        tok = P()
    else:
        kv = P(None, ba, TP, None, None)
        tok = P(ba)
    return {"kv_spec": kv, "len_spec": P(ba) if B > 1 else P(), "tok_spec": tok}


# ---------------------------------------------------------------------------
# GNN family — small params replicated; graph data sharded over all axes
# ---------------------------------------------------------------------------

def gnn_param_specs(params_shape: Any) -> Any:
    return jax.tree.map(lambda _: P(), params_shape)


def gnn_batch_specs(batch_specs: Any, mesh) -> Any:
    ax = all_axes(mesh)

    def rule(leaf):
        # shard the leading (node/edge/triplet/block) dim over all axes;
        # small leaves (graph targets, odd block sizes) stay replicated
        if (hasattr(leaf, "shape") and len(leaf.shape) >= 1
                and leaf.shape[0] % 512 == 0 and leaf.shape[0] > 0):
            return P(ax)
        return P()

    return jax.tree.map(rule, batch_specs)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def dlrm_param_specs(params_shape: Any) -> Any:
    def rule(path, leaf):
        keys = "/".join(str(getattr(k, "key", k)) for k in path)
        if "tables" in keys:        # [F, R, D]: rows sharded over everything
            return P(None, ("data", "model"), None)
        return P()
    return jax.tree_util.tree_map_with_path(rule, params_shape)


def dlrm_batch_specs(cell_kind: str, specs: dict, mesh) -> dict:
    ba = batch_axes(mesh)
    out = {}
    for k, v in specs.items():
        if k == "candidates":       # [M, D] candidate store (M = exactly 1e6,
            out[k] = P(ba, None)    # divisible by data axes, not by model)
        elif v.shape[0] == 1:       # retrieval query batch B=1
            out[k] = P()
        else:
            out[k] = P(ba)
    return out


# ---------------------------------------------------------------------------
# optimizer state
# ---------------------------------------------------------------------------

def opt_specs(param_specs: Any) -> dict:
    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }
