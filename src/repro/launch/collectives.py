"""Analytic per-device collective-byte model for the pjit-sharded families.

GSPMD inserts collectives *after* jaxpr (invisible to the jaxpr walker) and
``compiled.as_text()`` counts while-bodies once, so the roofline's
collective term is derived from the sharding rules instead (standard
practice — the rules are ours, so the formulas are exact up to GSPMD
resharding noise, which the one-shot HLO counts in the manifest bound).

Conventions: ring algorithms — all-gather of a tensor sharded G ways
delivers (G-1)/G·size ≈ size bytes per device; reduce-scatter the same;
all-reduce = 2×. Params/grads fp32, activations compute-dtype (bf16).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np


def _spec_axes(sp) -> list[str]:
    if sp is None:
        return []
    out = []
    for part in tuple(sp):
        if part is None:
            continue
        if isinstance(part, str):
            out.append(part)
        else:
            out.extend(part)
    return out


def _is_spec(x) -> bool:
    return x is None or type(x).__name__ == "PartitionSpec"


def lm_collectives(cfg, cell, mesh, params_sds, p_specs) -> dict[str, float]:
    """Per-device collective bytes for one LM step.

    Reflects the §Perf hillclimbs: A1 — params cross the wire in bf16 (cast
    before the FSDP all-gather; grads reduce-scatter in bf16); A2 — MoE
    expert weights (w_in/w_out) are EP-stationary: tokens a2a to the expert
    shard instead of gathering weights; B — inference specs carry no 'data'
    placement on non-expert params, so their AG term vanishes naturally.
    """
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data, n_model, n_pod = (axes.get(k, 1) for k in ("data", "model", "pod"))
    B, S = cell.sizes["batch"], cell.sizes["seq"]
    cdt = 2  # bf16 wire dtype for weights & activations (A1)

    flat, _ = jax.tree_util.tree_flatten_with_path(params_sds)
    specs = jax.tree.leaves(p_specs, is_leaf=_is_spec)
    total_pb = 0.0
    fsdp_wire = 0.0   # non-expert params all-gathered per step (bf16 wire)
    expert_pb = 0.0   # EP-stationary expert weights — never gathered
    for (path, leaf), sp in zip(flat, specs, strict=True):
        nbytes_w = float(np.prod(leaf.shape, dtype=np.float64)) * cdt
        total_pb += float(np.prod(leaf.shape, dtype=np.float64)) * 4
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        is_expert = name.endswith("w_in") or name.endswith("w_out")
        if is_expert and getattr(cfg.moe, "ep_axis", None):
            expert_pb += nbytes_w
        elif "data" in _spec_axes(sp):
            fsdp_wire += nbytes_w

    tokens_local = B * S / max(n_data * n_pod, 1)
    d = cfg.d_model
    moe_a2a = 0.0
    if cfg.moe is not None and cfg.moe.ep_axis:
        trips = 3.0 if cell.kind == "train" else 1.0  # in+out fwd (+bwd grads)
        tl = tokens_local if cell.kind != "decode" else B / max(n_data * n_pod, 1)
        moe_a2a = (cfg.n_layers * trips * max(tl, 1)
                   * cfg.moe.top_k * d * cdt * cfg.moe.capacity_factor)

    if cell.kind == "train":
        ag = 2.0 * fsdp_wire               # FSDP param AG (bf16), fwd + bwd
        rs = 1.0 * fsdp_wire               # grad reduce-scatter (bf16)
        ar_pod = (
            2.0 * total_pb / (n_data * n_model) * (n_pod - 1) / n_pod
            if n_pod > 1 else 0.0
        )                                  # DP grad sync across pods
        # TP psums: 2 contractions/layer (attn-out, ffn-out), fwd + bwd
        tp = cfg.n_layers * 2 * 2 * 2.0 * tokens_local * d * cdt
        return {"all_gather": ag, "reduce_scatter": rs,
                "all_reduce": ar_pod, "tp_psum": tp, "moe_a2a": moe_a2a}

    # inference: single forward — TP psums fwd only
    tokens_local = (B * 1 if cell.kind == "decode" else B * S) / max(
        n_data * n_pod, 1
    )
    ag = 1.0 * fsdp_wire
    tp = cfg.n_layers * 2 * 2.0 * max(tokens_local, 1) * d * cdt
    return {"all_gather": ag, "reduce_scatter": 0.0, "all_reduce": 0.0,
            "tp_psum": tp, "moe_a2a": moe_a2a}


def gnn_collectives(cfg, cell, mesh, params_sds) -> dict[str, float]:
    """Replicated params → grad all-reduce; cross-shard message scatter ≈
    all-to-all of edge messages + gathered sender rows."""
    n_chips = int(np.prod(mesh.devices.shape))
    dt = 4
    pbytes = sum(
        float(np.prod(x.shape, dtype=np.float64)) * dt
        for x in jax.tree.leaves(params_sds)
    )
    E = cell.sizes.get("n_edges", 0)
    d_hidden = getattr(cfg, "d_hidden", 64)
    n_layers = getattr(cfg, "n_layers", getattr(cfg, "n_blocks", 2))
    a2a = 2.0 * 2.0 * n_layers * (E / n_chips) * d_hidden * dt  # fwd+bwd, in+out
    ar = 2.0 * pbytes
    return {"all_reduce": ar, "all_to_all": a2a, "all_gather": 0.0,
            "reduce_scatter": 0.0}


def dlrm_collectives(cfg, cell, mesh) -> dict[str, float]:
    n_chips = int(np.prod(mesh.devices.shape))
    dt = 4
    B = cell.sizes["batch"]
    if cell.kind == "retrieval":
        k = 100
        return {"all_gather": float(k * 8 * n_chips), "all_reduce": 0.0,
                "all_to_all": 0.0, "reduce_scatter": 0.0}
    F, D, nnz = cfg.n_sparse, cfg.embed_dim, cfg.nnz
    rows = B / n_chips * F * nnz * D * dt
    a2a = (3.0 if cell.kind == "train" else 1.0) * rows  # fwd rows + bwd grads
    mlp_params = sum(
        a * b for a, b in zip((cfg.n_dense,) + cfg.bot_mlp, cfg.bot_mlp)
    ) + sum(a * b for a, b in zip(
        (cfg.n_interact + cfg.bot_mlp[-1],) + cfg.top_mlp, cfg.top_mlp))
    ar = (2.0 * mlp_params * dt) if cell.kind == "train" else 0.0
    return {"all_to_all": a2a, "all_reduce": ar, "all_gather": 0.0,
            "reduce_scatter": 0.0}


def ipgm_collectives(cfg, cell, mesh) -> dict[str, float]:
    n_chips = int(np.prod(mesh.devices.shape))
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if cell.kind == "ipgm_query":
        B, k = cell.sizes["q_batch"], cfg.search.pool_size
        # hierarchical two-stage merge (§Perf C): AG within 'model' (m×B×k)
        # then across 'data' (n×B×k) — vs the flat P×B×k fan-in
        m, n = axes.get("model", 1), axes.get("data", 1) * axes.get("pod", 1)
        return {"all_gather": float((m + n) * B * k * 8), "all_reduce": 0.0,
                "all_to_all": 0.0, "reduce_scatter": 0.0}
    if cell.kind == "ipgm_insert":
        B = cell.sizes["batch"]
        return {"all_reduce": float(2 * B * 4), "all_gather": 0.0,
                "all_to_all": 0.0, "reduce_scatter": 0.0}
    return {"all_gather": 0.0, "all_reduce": 0.0, "all_to_all": 0.0,
            "reduce_scatter": 0.0}


def collectives_for(family: str, cfg, cell, mesh, params_sds=None,
                    p_specs=None) -> dict[str, float]:
    if family == "lm":
        return lm_collectives(cfg, cell, mesh, params_sds, p_specs)
    if family == "gnn":
        return gnn_collectives(cfg, cell, mesh, params_sds)
    if family == "recsys":
        return dlrm_collectives(cfg, cell, mesh)
    if family == "ipgm":
        return ipgm_collectives(cfg, cell, mesh)
    raise ValueError(family)
