"""Production mesh builders.

Functions (not module constants) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod pass."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CPU tests (requires host device count ≥ product)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes the batch/token dim shards over (pod extends data when present)."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
