"""Cell builder: (arch × shape × mesh) → a lowerable jitted step.

The single glue point between the registry, the sharding rules and the step
functions. Everything is ShapeDtypeStruct-based — building a cell never
allocates a parameter.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import registry as reg
from repro.launch import sharding as shr
from repro.launch.mesh import all_axes, batch_axes
from repro.models import transformer as tfm
from repro.train import steps as steps_mod
from repro.train.optimizer import AdamWConfig

OPT = AdamWConfig()


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape: str
    kind: str
    fn: Callable            # jitted (with in_shardings) — call .lower(*args)
    args: tuple             # ShapeDtypeStruct pytrees
    meta: dict              # model_flops etc. for the roofline
    param_specs: object = None  # PartitionSpec tree for args[0] (IO model)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _eval_shapes(fn) -> Any:
    return jax.eval_shape(fn)


# ---------------------------------------------------------------------------


def _bf16_serving(params_sds):
    """Serving checkpoints store bf16 weights (§Perf hillclimb B)."""
    def cast(x):
        if hasattr(x, "dtype") and x.dtype == jnp.float32 and len(x.shape) >= 2:
            return jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        return x
    return jax.tree.map(cast, params_sds)


def _lm_cell(spec: reg.ArchSpec, shape: str, mesh) -> Cell:
    cfg = spec.config_for_shape(shape)
    cell = spec.shapes[shape]
    from repro.configs.lm_common import lm_cache_specs

    params_sds = _eval_shapes(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg)
    )
    if cell.kind in ("prefill", "decode"):
        params_sds = _bf16_serving(params_sds)
        p_spec = shr.lm_param_specs_inference(params_sds)
    else:
        p_spec = shr.lm_param_specs(params_sds)
    batch_sds = spec.input_specs(cfg, shape)
    b_spec = shr.lm_batch_specs(cell.kind, mesh, batch_sds)
    B, S = cell.sizes["batch"], cell.sizes["seq"]
    ntok_train = B * S

    if cell.kind == "train":
        from repro.train.optimizer import adamw_init
        opt_sds = _eval_shapes(lambda: adamw_init(params_sds))
        o_spec = shr.opt_specs(p_spec)
        fn = jax.jit(
            steps_mod.make_lm_train_step(cfg, OPT),
            in_shardings=(_ns(mesh, p_spec), _ns(mesh, o_spec),
                          _ns(mesh, b_spec)),
            donate_argnums=(0, 1),
        )
        flops = 6 * cfg.n_active_params() * ntok_train
        return Cell(spec.arch_id, shape, cell.kind, fn,
                    (params_sds, opt_sds, batch_sds),
                    {"model_flops": flops, "n_params": cfg.n_params()},
                    param_specs=p_spec)

    if cell.kind == "prefill":
        cache_spec_sh = shr.lm_cache_specs_sharding(cell, mesh)
        cache_out_spec = {
            "kv": [(cache_spec_sh["kv_spec"], cache_spec_sh["kv_spec"])
                   for _ in range(cfg.period)],
            "len": cache_spec_sh["len_spec"],
        }
        logits_spec = P(batch_axes(mesh), shr.TP)
        fn = jax.jit(
            steps_mod.make_lm_prefill_step(cfg, pad_to=S),
            in_shardings=(_ns(mesh, p_spec), _ns(mesh, b_spec)),
            out_shardings=(_ns(mesh, logits_spec), _ns(mesh, cache_out_spec)),
        )
        flops = 2 * cfg.n_active_params() * ntok_train
        return Cell(spec.arch_id, shape, cell.kind, fn,
                    (params_sds, batch_sds),
                    {"model_flops": flops, "n_params": cfg.n_params()},
                    param_specs=p_spec)

    # decode
    cache_sds = lm_cache_specs(cfg, cell)
    csh = shr.lm_cache_specs_sharding(cell, mesh)
    cache_spec = {
        "kv": [(csh["kv_spec"], csh["kv_spec"]) for _ in range(cfg.period)],
        "len": csh["len_spec"],
    }
    logits_spec = P(batch_axes(mesh), shr.TP) if B > 1 else P(None, shr.TP)
    fn = jax.jit(
        steps_mod.make_lm_decode_step(cfg),
        in_shardings=(_ns(mesh, p_spec), _ns(mesh, cache_spec),
                      _ns(mesh, {"tokens": csh["tok_spec"]})),
        out_shardings=(_ns(mesh, logits_spec), _ns(mesh, cache_spec)),
        donate_argnums=(1,),
    )
    # decode flops: one token per sequence + attention against S-cache
    attn_read = (
        cfg.n_layers * 2 * 2 * B * S * cfg.n_kv_heads * cfg.d_head
    )
    flops = 2 * cfg.n_active_params() * B + attn_read
    return Cell(spec.arch_id, shape, cell.kind, fn,
                (params_sds, cache_sds, batch_sds),
                {"model_flops": flops, "n_params": cfg.n_params()},
                param_specs=p_spec)


# ---------------------------------------------------------------------------


def _gnn_cell(spec: reg.ArchSpec, shape: str, mesh) -> Cell:
    cfg = spec.config_for_shape(shape)
    cell = spec.shapes[shape]
    arch = {
        "graphsage-reddit": "graphsage", "gat-cora": "gat",
        "gatedgcn": "gatedgcn", "dimenet": "dimenet",
    }[spec.arch_id]

    def init():
        from repro.models.gnn import dimenet as dmod
        from repro.models.gnn import gat as gmod
        from repro.models.gnn import gatedgcn as ggmod
        from repro.models.gnn import graphsage as smod
        key = jax.random.PRNGKey(0)
        return {
            "graphsage": smod.init_params, "gat": gmod.init_params,
            "gatedgcn": ggmod.init_params, "dimenet": dmod.init_params,
        }[arch](key, cfg)

    params_sds = _eval_shapes(init)
    p_spec = shr.gnn_param_specs(params_sds)
    batch_sds = spec.input_specs(cfg, shape)
    b_spec = shr.gnn_batch_specs(batch_sds, mesh)

    from repro.train.optimizer import adamw_init
    opt_sds = _eval_shapes(lambda: adamw_init(params_sds))
    o_spec = shr.opt_specs(p_spec)
    fn = jax.jit(
        steps_mod.make_gnn_train_step(arch, cfg, OPT),
        in_shardings=(_ns(mesh, p_spec), _ns(mesh, o_spec), _ns(mesh, b_spec)),
        donate_argnums=(0, 1),
    )
    sizes = cell.sizes
    n_param = sum(
        int(jnp.prod(jnp.asarray(x.shape))) for x in jax.tree.leaves(params_sds)
    )
    flops = gnn_model_flops(arch, cfg, sizes, shape)
    return Cell(spec.arch_id, shape, "train", fn,
                (params_sds, opt_sds, batch_sds),
                {"model_flops": int(flops), "n_params": int(n_param)},
                param_specs=p_spec)


def gnn_model_flops(arch: str, cfg, sizes: dict, shape: str) -> float:
    """Analytic fwd+bwd useful FLOPs per family (3× forward convention)."""
    N, E = sizes["n_nodes"], sizes["n_edges"]
    if arch == "graphsage":
        if shape == "minibatch_lg":
            B, (f1, f2) = sizes["batch_nodes"], sizes["fanout"]
            n1, n2 = B * f1, B * f1 * f2
            fwd = 2 * 2 * (n1 * cfg.d_in * cfg.d_hidden
                           + B * cfg.d_hidden * cfg.n_classes)
            fwd += (n2 * cfg.d_in + n1 * cfg.d_hidden)  # masked-mean adds
            return 3 * fwd
        d = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
        fwd = sum(2 * 2 * N * d[i] * d[i + 1] for i in range(cfg.n_layers))
        fwd += cfg.n_layers * E * max(d[:-1])  # segment means
        return 3 * fwd
    if arch == "gat":
        H, dh = cfg.n_heads, cfg.d_hidden
        fwd = 2 * N * cfg.d_in * H * dh + 2 * N * H * dh * cfg.n_classes
        fwd += cfg.n_layers * E * H * (2 * dh + 6)  # scores + softmax + agg
        return 3 * fwd
    if arch == "gatedgcn":
        d = cfg.d_hidden
        per_layer = 2 * (3 * E + 2 * N) * d * d + 8 * E * d
        fwd = (2 * N * cfg.d_in * d + 2 * E * cfg.d_edge_in * d
               + cfg.n_layers * per_layer + 2 * N * d * cfg.n_classes)
        return 3 * fwd
    if arch == "dimenet":
        from repro.configs.gnn_common import max_triplets
        T = max_triplets(shape)
        d, nb = cfg.d_hidden, cfg.n_bilinear
        per_block = (
            2 * T * nb * d * d          # bilinear contraction (dominant)
            + 2 * T * cfg.n_spherical * cfg.n_radial * nb
            + 3 * 2 * E * d * d         # edge MLPs
        )
        fwd = cfg.n_blocks * per_block + 2 * E * (2 * d + cfg.n_radial) * d
        return 3 * fwd
    raise ValueError(arch)


# ---------------------------------------------------------------------------


def _dlrm_cell(spec: reg.ArchSpec, shape: str, mesh) -> Cell:
    from repro.models import dlrm as dlrm_mod
    cfg = spec.config_for_shape(shape)
    cell = spec.shapes[shape]

    params_sds = _eval_shapes(
        lambda: dlrm_mod.init_params(jax.random.PRNGKey(0), cfg)
    )
    p_spec = shr.dlrm_param_specs(params_sds)
    batch_sds = spec.input_specs(cfg, shape)
    b_spec = shr.dlrm_batch_specs(cell.kind, batch_sds, mesh)
    B = cell.sizes["batch"]
    mlp_flops = 2 * B * (
        sum(a * b for a, b in zip((cfg.n_dense,) + cfg.bot_mlp, cfg.bot_mlp))
        + sum(a * b for a, b in zip(
            (cfg.n_interact + cfg.bot_mlp[-1],) + cfg.top_mlp, cfg.top_mlp))
    )

    if cell.kind == "train":
        from repro.train.optimizer import adamw_init
        opt_sds = _eval_shapes(lambda: adamw_init(params_sds))
        o_spec = shr.opt_specs(p_spec)
        fn = jax.jit(
            steps_mod.make_dlrm_train_step(cfg, OPT),
            in_shardings=(_ns(mesh, p_spec), _ns(mesh, o_spec),
                          _ns(mesh, b_spec)),
            donate_argnums=(0, 1),
        )
        return Cell(spec.arch_id, shape, cell.kind, fn,
                    (params_sds, opt_sds, batch_sds),
                    {"model_flops": 3 * mlp_flops}, param_specs=p_spec)
    if cell.kind == "serve":
        fn = jax.jit(
            steps_mod.make_dlrm_serve_step(cfg),
            in_shardings=(_ns(mesh, p_spec), _ns(mesh, b_spec)),
        )
        return Cell(spec.arch_id, shape, cell.kind, fn,
                    (params_sds, batch_sds), {"model_flops": mlp_flops},
                    param_specs=p_spec)
    # retrieval
    M = cell.sizes["n_candidates"]
    fn = jax.jit(
        steps_mod.make_dlrm_retrieval_step(cfg),
        in_shardings=(_ns(mesh, p_spec), _ns(mesh, b_spec)),
    )
    flops = 2 * M * cfg.bot_mlp[-1] + mlp_flops
    return Cell(spec.arch_id, shape, cell.kind, fn,
                (params_sds, batch_sds), {"model_flops": flops},
                param_specs=p_spec)


# ---------------------------------------------------------------------------


def _ipgm_cell(spec: reg.ArchSpec, shape: str, mesh) -> Cell:
    from repro.distributed import ann
    cfg = spec.config_for_shape(shape)
    cell = spec.shapes[shape]
    dp = ann.DistParams(
        index=cfg,
        pod_axis="pod" if "pod" in mesh.axis_names else None,
        vec_dtype="bfloat16",  # §Perf C: halves beam-expansion gather bytes
    )
    state_sds = _eval_shapes(lambda: ann.init_sharded_state(dp, mesh))
    state_spec = jax.tree.map(lambda _: P(dp.axes), state_sds)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    inputs = spec.input_specs(cfg, shape)
    # per-query hop expansion: pool·d_out candidate scorings of dim floats
    sp = cfg.search
    per_q = sp.max_steps * cfg.d_out * cfg.dim * 2
    if cell.kind == "ipgm_query":
        fn = ann.make_query_step(dp, mesh)
        args = (state_sds, inputs["queries"], key_sds)
        flops = cell.sizes["q_batch"] * per_q
    elif cell.kind == "ipgm_delete":
        fn = ann.make_delete_step(dp, mesh, "global")
        args = (state_sds, inputs["gids"], key_sds)
        flops = cell.sizes["batch"] * cfg.eff_d_in * per_q
    else:
        fn = ann.make_insert_step(dp, mesh)
        args = (state_sds, inputs["vecs"], inputs["route"], key_sds)
        flops = cell.sizes["batch"] * per_q
    return Cell(spec.arch_id, shape, cell.kind, fn, args,
                {"model_flops": int(flops)}, param_specs=state_spec)


# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape: str, mesh) -> Cell:
    spec = reg.get_arch(arch_id)
    cell = spec.shapes[shape]
    if cell.skip:
        raise ValueError(f"cell ({arch_id}, {shape}) skipped: {cell.skip}")
    fam = spec.family
    if fam == "lm":
        return _lm_cell(spec, shape, mesh)
    if fam == "gnn":
        return _gnn_cell(spec, shape, mesh)
    if fam == "recsys":
        return _dlrm_cell(spec, shape, mesh)
    if fam == "ipgm":
        return _ipgm_cell(spec, shape, mesh)
    raise ValueError(fam)


def all_cells(include_skipped: bool = False) -> list[tuple[str, str, str | None]]:
    """[(arch, shape, skip_reason)] over the full assignment matrix."""
    out = []
    for arch_id, spec in reg.all_archs().items():
        for shape, cell in spec.shapes.items():
            out.append((arch_id, shape, cell.skip))
    return out
