"""AdamW (decoupled weight decay) built from scratch — no optax.

Optimizer state is a pytree mirroring params (m, v) + a step counter; it
shards exactly like the params (FSDP-friendly). Includes global-norm
clipping and an optional int8 gradient-compression hook for the
cross-replica all-reduce (distributed/compression.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac)
    )
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(
    params: Any, grads: Any, opt_state: dict, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step_vec = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * step_vec).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
