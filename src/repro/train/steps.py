"""Per-family train/serve step functions — the units the dry-run lowers.

Every step is a pure function (params, opt_state, batch) → (params,
opt_state, metrics) or (state..., outputs) suitable for ``jax.jit`` with
explicit in/out shardings. Loss functions per family:

  lm     : sequence-chunked causal cross-entropy (+ MoE aux loss)
  gnn    : masked node cross-entropy (classification) or graph MSE (dimenet)
  recsys : BCE on CTR logits
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import dlrm as dlrm_mod
from repro.models import transformer as tfm
from repro.models.gnn import dimenet as dimenet_mod
from repro.models.gnn import gat as gat_mod
from repro.models.gnn import gatedgcn as ggcn_mod
from repro.models.gnn import graphsage as sage_mod
from repro.train.optimizer import AdamWConfig, adamw_update

AUX_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _cast_params(params, dtype):
    """bf16 compute cast at the step boundary so FSDP all-gathers (and the
    matching grad reduce-scatters) move 2-byte payloads instead of fp32 —
    §Perf hillclimb A1. Norm scales stay fp32 (cheap + precision-sensitive)."""
    def cast(p):
        if p.dtype == jnp.float32 and p.ndim >= 2:
            return p.astype(dtype)
        return p
    return jax.tree.map(cast, params)


def lm_loss(params, batch, cfg: tfm.TransformerConfig):
    h, aux, _ = tfm.forward(params, batch["tokens"], cfg)
    loss = tfm.chunked_xent(params, h, batch["labels"], batch["mask"], cfg)
    return loss + AUX_WEIGHT * aux, {"xent": loss, "aux": aux}


def make_lm_train_step(
    cfg: tfm.TransformerConfig, opt: AdamWConfig, *, cast_bf16: bool = True
) -> Callable:
    def step(params, opt_state, batch):
        def loss_fn(p):
            pc = _cast_params(p, cfg.compute_dtype) if cast_bf16 else p
            return lm_loss(pc, batch, cfg)
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, {"loss": loss, **parts, **om}
    return step


def make_lm_prefill_step(cfg: tfm.TransformerConfig, pad_to: int) -> Callable:
    def step(params, batch):
        h, _, cache = tfm.forward(
            params, batch["tokens"], cfg, return_cache_pad=pad_to
        )
        logits = tfm.logits_from_hidden(params, h[:, -1], cfg)
        return logits, cache
    return step


def make_lm_decode_step(cfg: tfm.TransformerConfig) -> Callable:
    def step(params, cache, batch):
        return tfm.decode_step(params, cache, batch["tokens"], cfg)
    return step


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _node_xent(logits, labels, mask):
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.where(mask, lse - gold, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def gnn_loss(params, batch, arch: str, cfg):
    if arch == "graphsage" and "blocks" in batch:
        logits = sage_mod.forward_sampled(params, batch["blocks"], cfg)
        return _node_xent(logits, batch["block_labels"],
                          batch["block_label_mask"]), {}
    g = batch["graph"]
    if arch == "graphsage":
        logits = sage_mod.forward(params, g, cfg)
    elif arch == "gat":
        logits = gat_mod.forward(params, g, cfg)
    elif arch == "gatedgcn":
        logits = ggcn_mod.forward(params, g, cfg)
    elif arch == "dimenet":
        pred = dimenet_mod.forward(params, g, batch["triplets"], cfg)
        return jnp.mean(jnp.square(pred - g.targets)), {}
    else:
        raise ValueError(arch)
    return _node_xent(logits, g.labels, g.label_mask & g.node_mask), {}


def make_gnn_train_step(arch: str, cfg, opt: AdamWConfig) -> Callable:
    def step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            gnn_loss, has_aux=True)(params, batch, arch, cfg)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, {"loss": loss, **parts, **om}
    return step


def make_gnn_forward(arch: str, cfg) -> Callable:
    fwd = {
        "graphsage": sage_mod.forward,
        "gat": gat_mod.forward,
        "gatedgcn": ggcn_mod.forward,
    }
    if arch == "dimenet":
        def step(params, batch):
            return dimenet_mod.forward(params, batch["graph"],
                                       batch["triplets"], cfg)
        return step

    def step(params, batch):
        return fwd[arch](params, batch["graph"], cfg)
    return step


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def make_dlrm_train_step(cfg: dlrm_mod.DLRMConfig, opt: AdamWConfig) -> Callable:
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(dlrm_mod.bce_loss)(params, batch, cfg)
        params, opt_state, om = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, {"loss": loss, **om}
    return step


def make_dlrm_serve_step(cfg: dlrm_mod.DLRMConfig) -> Callable:
    def step(params, batch):
        return jax.nn.sigmoid(dlrm_mod.forward(params, batch, cfg))
    return step


def make_dlrm_retrieval_step(cfg: dlrm_mod.DLRMConfig, k: int = 100) -> Callable:
    def step(params, batch):
        # bottom-MLP the query's dense features → query embedding; score the
        # candidate store (use_pallas=False keeps the dry-run XLA-pure; the
        # serving benchmark flips it on)
        q = dlrm_mod._mlp(params["bot"], batch["dense"], final_act=True)
        return dlrm_mod.retrieval_scores(
            q, batch["candidates"], k, use_pallas=False
        )
    return step
