"""Deterministic, resumable synthetic token stream for LM training.

Each batch is derived purely from (seed, step) — restarting at step k
reproduces the exact stream, which is what makes checkpoint/restart
bit-reproducible (asserted in tests/test_checkpoint.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        # markov-ish stream so the loss actually decreases
        base = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1))
        drift = np.arange(self.seq + 1) % max(self.vocab // 7, 1)
        toks = (base + drift) % self.vocab
        self.step += 1
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((self.batch, self.seq), bool),
        }

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, s: dict) -> None:
        self.step, self.seed = int(s["step"]), int(s["seed"])
