from repro.data.synthetic import make_dataset
from repro.data.workload import UpdateWorkload, make_workload

__all__ = ["make_dataset", "UpdateWorkload", "make_workload"]
