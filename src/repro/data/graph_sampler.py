"""Neighbor sampler — the real minibatch_lg data path (GraphSAGE-style).

Host-side CSR uniform fanout sampling producing fixed-size padded blocks
(deepest-hop-first) matching configs/gnn_common layouts. Resumable: the
sampler carries an epoch/cursor state for preemption restarts.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # i64[N+1]
    indices: np.ndarray  # i32[E]
    feats: np.ndarray    # f32[N, F]
    labels: np.ndarray   # i64[N]

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1


def build_csr(n_nodes: int, senders: np.ndarray, receivers: np.ndarray,
              feats: np.ndarray, labels: np.ndarray) -> CSRGraph:
    order = np.argsort(senders, kind="stable")
    s, r = senders[order], receivers[order]
    counts = np.bincount(s, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr, r.astype(np.int32), feats, labels)


def random_graph(n_nodes: int, avg_degree: int, d_feat: int, n_classes: int,
                 *, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    E = n_nodes * avg_degree
    senders = rng.integers(0, n_nodes, E)
    receivers = rng.integers(0, n_nodes, E)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes)
    return build_csr(n_nodes, senders, receivers, feats, labels)


@dataclasses.dataclass
class SamplerState:
    epoch: int = 0
    cursor: int = 0

    def state_dict(self) -> dict:
        return dataclasses.asdict(self)

    def load_state_dict(self, d: dict) -> None:
        self.epoch, self.cursor = int(d["epoch"]), int(d["cursor"])


class NeighborSampler:
    """Uniform fanout sampler with -1 padding for low-degree nodes."""

    def __init__(self, g: CSRGraph, fanout: tuple[int, ...], batch: int,
                 *, seed: int = 0):
        self.g = g
        self.fanout = fanout
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        self.state = SamplerState()
        self._perm = self.rng.permutation(g.n_nodes)

    def _sample_neighbors(self, nodes: np.ndarray, fan: int) -> np.ndarray:
        """[len(nodes)·fan] sampled neighbor ids (-1 padded)."""
        out = np.full((nodes.shape[0], fan), -1, np.int64)
        for i, n in enumerate(nodes):
            if n < 0:
                continue
            lo, hi = self.g.indptr[n], self.g.indptr[n + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = self.rng.integers(lo, hi, size=fan) if deg > fan else \
                np.concatenate([np.arange(lo, hi),
                                self.rng.integers(lo, hi, size=fan - deg)])
            out[i] = self.g.indices[take[:fan]]
        return out.reshape(-1)

    def next_batch(self) -> dict:
        """Blocks dict matching configs/gnn_common minibatch layout."""
        N = self.g.n_nodes
        if self.state.cursor + self.batch > N:
            self.state.epoch += 1
            self.state.cursor = 0
            self._perm = self.rng.permutation(N)
        targets = self._perm[self.state.cursor:self.state.cursor + self.batch]
        self.state.cursor += self.batch

        layers = [targets]
        for fan in self.fanout:
            layers.append(self._sample_neighbors(layers[-1], fan))
        # deepest-first feature blocks + masks
        feats, masks = [], []
        for nodes in reversed(layers):
            m = nodes >= 0
            f = np.zeros((nodes.shape[0], self.g.feats.shape[1]), np.float32)
            f[m] = self.g.feats[nodes[m]]
            feats.append(f)
            masks.append(m)
        return {
            "blocks": {"feats": feats, "masks": masks},
            "block_labels": self.g.labels[targets].astype(np.int32),
            "block_label_mask": np.ones(self.batch, bool),
        }

    def as_subgraph(self) -> dict:
        """One sampled batch as a merged edge-list subgraph (for non-SAGE
        archs on the minibatch_lg cell)."""
        N = self.g.n_nodes
        if self.state.cursor + self.batch > N:
            self.state.epoch += 1
            self.state.cursor = 0
            self._perm = self.rng.permutation(N)
        targets = self._perm[self.state.cursor:self.state.cursor + self.batch]
        self.state.cursor += self.batch

        layers = [targets]
        senders, receivers = [], []
        offset = 0
        next_offset = self.batch
        for fan in self.fanout:
            nbrs = self._sample_neighbors(layers[-1], fan)
            src_pos = np.arange(nbrs.shape[0]) + next_offset
            dst_pos = np.repeat(np.arange(layers[-1].shape[0]) + offset, fan)
            valid = nbrs >= 0
            senders.append(src_pos[valid])
            receivers.append(dst_pos[valid])
            offset = next_offset
            next_offset += nbrs.shape[0]
            layers.append(nbrs)
        all_nodes = np.concatenate(layers)
        node_mask = all_nodes >= 0
        feats = np.zeros((all_nodes.shape[0], self.g.feats.shape[1]), np.float32)
        feats[node_mask] = self.g.feats[all_nodes[node_mask]]
        labels = np.zeros(all_nodes.shape[0], np.int32)
        labels[: self.batch] = self.g.labels[targets]
        label_mask = np.zeros(all_nodes.shape[0], bool)
        label_mask[: self.batch] = True
        return {
            "x": feats,
            "senders": np.concatenate(senders).astype(np.int32),
            "receivers": np.concatenate(receivers).astype(np.int32),
            "node_mask": node_mask,
            "labels": labels,
            "label_mask": label_mask,
        }
