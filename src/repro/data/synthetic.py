"""Synthetic ANN datasets — matched-moment surrogates of the paper's corpora.

The paper evaluates on SIFT (d=128), GloVe200 (d=200), NYTimes (d=256) and
GIST (d=960); the raw files are not redistributable in this container, so we
generate surrogates with the property the paper actually leans on: SIFT/GIST
are comparatively uniform while GloVe/NYTimes are *skewed* (clustered). The
``skew`` knob controls the number/spread of Gaussian mixture components.
"""
from __future__ import annotations

import numpy as np

DATASET_SPECS = {
    # name: (dim, skewed?) — mirrors §6 "Data"
    "sift": (128, False),
    "glove200": (200, True),
    "nytimes": (256, True),
    "gist": (960, False),
}


def make_dataset(
    name: str,
    n: int,
    *,
    seed: int = 0,
    dim: int | None = None,
) -> np.ndarray:
    """Generate ``n`` float32 vectors shaped like the named benchmark set."""
    if name not in DATASET_SPECS:
        raise ValueError(f"unknown dataset {name!r}; have {list(DATASET_SPECS)}")
    d, skewed = DATASET_SPECS[name]
    d = dim if dim is not None else d
    rng = np.random.default_rng(seed)
    if not skewed:
        # near-uniform cloud with mild local structure
        base = rng.normal(0.0, 1.0, size=(n, d))
        return base.astype(np.float32)
    # skewed: Gaussian mixture with power-law component weights
    n_comp = max(8, d // 16)
    weights = rng.pareto(1.5, size=n_comp) + 1.0
    weights = weights / weights.sum()
    centers = rng.normal(0.0, 4.0, size=(n_comp, d))
    scales = rng.uniform(0.3, 1.2, size=n_comp)
    comp = rng.choice(n_comp, size=n, p=weights)
    out = centers[comp] + rng.normal(size=(n, d)) * scales[comp][:, None]
    return out.astype(np.float32)


def kmeans(
    x: np.ndarray, k: int, *, iters: int = 12, seed: int = 0
) -> np.ndarray:
    """Tiny k-means (labels only) for the clustered-update pattern (§6)."""
    rng = np.random.default_rng(seed)
    centers = x[rng.choice(x.shape[0], size=k, replace=False)].copy()
    labels = np.zeros(x.shape[0], np.int64)
    for _ in range(iters):
        # ||x - c||^2 = ||x||^2 - 2 x·c + ||c||^2 (chunked to bound memory)
        cn = (centers**2).sum(1)
        new_labels = np.empty_like(labels)
        for lo in range(0, x.shape[0], 65536):
            blk = x[lo:lo + 65536]
            d2 = cn[None, :] - 2.0 * blk @ centers.T
            new_labels[lo:lo + 65536] = d2.argmin(1)
        if (new_labels == labels).all():
            break
        labels = new_labels
        for j in range(k):
            m = labels == j
            if m.any():
                centers[j] = x[m].mean(0)
    return labels
