"""Online-update workload builder — the exact §6 protocol, scalable.

Paper protocol: from a base set, run ``n_steps`` batches; each batch deletes
``batch_size`` vectors, inserts ``batch_size`` fresh vectors, then issues
``n_queries`` top-K queries. Two update patterns:

  random   — base/delete/insert/query drawn from a global permutation.
  clustered— k-means the corpus into 10 clusters, lay clusters out in a
             sequence, and delete/insert whole cluster spans (so a vector
             AND its nearest neighbors expire together — the hard case for
             edge repair, §6.1.2).

The workload carries *resumable* state (a step cursor) so the data pipeline
can restart mid-stream after preemption (used by the fault-tolerance tests).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import kmeans, make_dataset


@dataclasses.dataclass
class UpdateWorkload:
    base: np.ndarray            # [n_base, d] initial corpus
    step_deletes: list[np.ndarray]   # per-step indices *into the live pool*
    step_inserts: list[np.ndarray]   # per-step fresh vectors
    queries: np.ndarray         # [n_query, d] query set (reused every step)
    pattern: str
    cursor: int = 0             # resumable step pointer

    @property
    def n_steps(self) -> int:
        return len(self.step_inserts)

    def state_dict(self) -> dict:
        return {"cursor": self.cursor}

    def load_state_dict(self, s: dict) -> None:
        self.cursor = int(s["cursor"])


def make_workload(
    dataset: str,
    *,
    n_base: int = 9000,
    n_steps: int = 10,
    batch_size: int = 1000,
    n_queries: int = 1000,
    pattern: str = "random",
    seed: int = 0,
    dim: int | None = None,
) -> UpdateWorkload:
    """Build the §6 workload at an arbitrary scale (paper: 900k/10k/10k)."""
    assert pattern in ("random", "clustered")
    total = n_base + n_steps * batch_size + n_queries
    x = make_dataset(dataset, total, seed=seed, dim=dim)
    rng = np.random.default_rng(seed + 1)

    if pattern == "random":
        perm = rng.permutation(total)
        x = x[perm]
        base = x[:n_base]
        ins_pool = x[n_base:n_base + n_steps * batch_size]
        queries = x[n_base + n_steps * batch_size:]
        step_inserts = [
            ins_pool[i * batch_size:(i + 1) * batch_size] for i in range(n_steps)
        ]
        # deletes: random sample of *live* pool positions; a step removes
        # first, then digests its inserts (§6 "Workload"). The driver
        # translates pool positions → live graph ids.
        live = np.zeros(n_base + n_steps * batch_size, bool)
        live[:n_base] = True
        step_deletes = []
        for i in range(n_steps):
            pick = rng.choice(np.flatnonzero(live), size=batch_size, replace=False)
            live[pick] = False
            step_deletes.append(pick)
            live[n_base + i * batch_size: n_base + (i + 1) * batch_size] = True
    else:
        # clustered: order the corpus by k-means cluster, base = leading span,
        # each step deletes the oldest remaining span and inserts the next one
        corpus = x[:n_base + n_steps * batch_size]
        queries = x[n_base + n_steps * batch_size:]
        labels = kmeans(corpus, 10, seed=seed)
        order = np.argsort(labels, kind="stable")
        corpus = corpus[order]
        base = corpus[:n_base]
        step_inserts = [
            corpus[n_base + i * batch_size: n_base + (i + 1) * batch_size]
            for i in range(n_steps)
        ]
        # delete the oldest span (cluster-contiguous ids)
        step_deletes = [
            np.arange(i * batch_size, (i + 1) * batch_size) for i in range(n_steps)
        ]

    return UpdateWorkload(
        base=base,
        step_deletes=[d.astype(np.int64) for d in step_deletes],
        step_inserts=list(step_inserts),
        queries=queries,
        pattern=pattern,
    )
