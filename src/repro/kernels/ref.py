"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are allclose-checked against in
``tests/test_kernels.py`` across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


def ref_score_matrix(
    x: jax.Array,      # [M, d] database
    xsq: jax.Array,    # [M]    ||x||^2 (used for l2)
    q: jax.Array,      # [B, d] queries
    metric: str = "l2",
) -> jax.Array:
    """[B, M] similarity scores (2<q,x> - ||x||^2 for l2; <q,x> otherwise)."""
    dots = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    if metric == "l2":
        return 2.0 * dots - xsq.astype(jnp.float32)[None, :]
    return dots


def ref_score_topk(
    x: jax.Array, xsq: jax.Array, q: jax.Array, k: int, metric: str = "l2"
) -> tuple[jax.Array, jax.Array]:
    """Exact top-k of the score matrix: (scores f32[B,k], ids i32[B,k])."""
    s = ref_score_matrix(x, xsq, q, metric)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i.astype(jnp.int32)


def ref_gather_scores(
    table: jax.Array,   # [N, d] full vector table
    tsq: jax.Array,     # [N]
    ids: jax.Array,     # i32[B, C] candidate ids (assumed in-range)
    q: jax.Array,       # [B, d]
    metric: str = "l2",
) -> jax.Array:
    """[B, C] scores of each query against its own gathered candidates."""
    rows = table[ids]                       # [B, C, d]
    dots = jnp.einsum(
        "bcd,bd->bc", rows.astype(jnp.float32), q.astype(jnp.float32)
    )
    if metric == "l2":
        return 2.0 * dots - tsq[ids].astype(jnp.float32)
    return dots


def ref_gather_scores_q8(
    codes: jax.Array,   # i8[N, d] per-row int8 vector codes
    scales: jax.Array,  # f32[N]   per-row dequant scales
    ids: jax.Array,     # i32[B, C] candidate ids (assumed in-range)
    q: jax.Array,       # [B, d] uncompressed queries
    metric: str = "l2",
) -> jax.Array:
    """[B, C] asymmetric scores of each query vs its gathered int8 rows:
    l2 → s·(2·<codes,q> − s·Σcodes²), ip/cos → s·<codes,q> (DESIGN.md §10)."""
    rows = codes[ids].astype(jnp.float32)   # [B, C, d]
    s = scales[ids].astype(jnp.float32)     # [B, C]
    dots = jnp.einsum("bcd,bd->bc", rows, q.astype(jnp.float32))
    if metric == "l2":
        return s * (2.0 * dots - s * jnp.sum(rows * rows, axis=-1))
    return s * dots
