"""jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, invalid-id fixup, dtype policy (bf16/f32
inputs, fp32 accumulation), and the interpret-mode switch (interpret=True on
CPU — the container target; False when an actual TPU backend is present).

Capacity-tier contract (DESIGN.md §9): the growth engine produces table
sizes that are NOT powers of two (geometric tiers, ``max_capacity`` clips),
so every wrapper must stay exact for arbitrary M. The padded-tail story,
audited per kernel and pinned by the {2^k, 2^k+1, 3·2^k} sweep in
``tests/test_kernels.py``:

  · ``score_matrix`` — rows/cols padded up to block multiples, output
    cropped ``[:B, :M]``; tail blocks compute garbage that is never read.
  · ``score_topk``   — the kernel masks row ids ≥ ``n_valid`` to -inf
    (authoritative for every metric) AND ``xsq`` is padded with +inf (l2
    belt-and-braces), so a padded tail row can never win a top-k slot.
  · ``gather_scores`` — ids are validated against the true M here and
    clamped before the kernel; the row BlockSpec indexes exact rows, so no
    tail row is ever DMA'd, and invalid lanes resolve to -inf outside.
  · ``gather_scores_q8`` — identical id-validation/clamp/-inf contract as
    ``gather_scores``, over int8 codes + per-row scales (DESIGN.md §10);
    the dim pad value 0 is inert in both the dot and the Σcodes² term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import distance_matrix as _dm
from repro.kernels import gather_distance as _gd

NEG_INF = float("-inf")


def on_tpu() -> bool:
    """True when the default backend is a real TPU (not interpret mode)."""
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


_on_tpu = on_tpu  # internal alias kept for the jit'd wrappers below


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("metric", "block_b", "block_m", "block_d", "interpret")
)
def score_matrix(
    x: jax.Array,
    xsq: jax.Array,
    q: jax.Array,
    *,
    metric: str = "l2",
    block_b: int = 128,
    block_m: int = 256,
    block_d: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """[B, M] fp32 scores via the tiled Pallas kernel (padded + cropped)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, M = q.shape[0], x.shape[0]
    block_b = min(block_b, max(8, B))
    block_m = min(block_m, max(8, M))
    xp = _pad_to(_pad_to(x, 0, block_m), 1, block_d)
    qp = _pad_to(_pad_to(q, 0, block_b), 1, block_d)
    xsqp = _pad_to(xsq, 0, block_m)
    out = _dm.score_matrix_pallas(
        xp, xsqp, qp, metric=metric, block_b=block_b, block_m=block_m,
        block_d=block_d, interpret=interpret,
    )
    return out[:B, :M]


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "block_b", "block_m", "interpret")
)
def score_topk(
    x: jax.Array,
    xsq: jax.Array,
    q: jax.Array,
    k: int,
    *,
    metric: str = "l2",
    block_b: int = 64,
    block_m: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused brute-force top-k: (scores f32[B,k], ids i32[B,k])."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, M = q.shape[0], x.shape[0]
    block_b = min(block_b, max(8, B))
    block_m = min(block_m, max(k, 8, M))
    # Padded-row masking happens in TWO places, both required:
    #   1. the kernel masks rows with id >= n_valid to -inf (authoritative —
    #      covers every metric, including ip/cos where xsq is unused and a
    #      zero-padded row would otherwise score 0 and beat negative scores);
    #   2. xsq is padded with +inf so l2 scores (2<q,x> - ||x||^2) of padded
    #      rows are -inf even before the n_valid mask.
    xp = _pad_to(_pad_to(x, 0, block_m), 1, 128)
    qp = _pad_to(_pad_to(q, 0, block_b), 1, 128)
    xsqp = _pad_to(xsq, 0, block_m, value=jnp.inf)
    s, i = _dm.score_topk_pallas(
        xp, xsqp, qp, k, metric=metric, block_b=block_b, block_m=block_m,
        n_valid=M, interpret=interpret,
    )
    s, i = s[:B], i[:B]
    ok = (i >= 0) & (i < M)
    return jnp.where(ok, s, NEG_INF), jnp.where(ok, i, -1)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def gather_scores(
    table: jax.Array,
    tsq: jax.Array,
    ids: jax.Array,
    q: jax.Array,
    *,
    metric: str = "l2",
    interpret: bool | None = None,
) -> jax.Array:
    """[B, C] fused gather+distance; invalid ids (< 0 or >= N) → -inf."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    N = table.shape[0]
    valid = (ids >= 0) & (ids < N)
    safe = jnp.where(valid, ids, 0).astype(jnp.int32)
    tp = _pad_to(table, 1, 128)
    qp = _pad_to(q, 1, 128)
    s = _gd.gather_scores_pallas(
        tp, tsq.astype(jnp.float32), safe, qp, metric=metric,
        interpret=interpret,
    )
    return jnp.where(valid, s, NEG_INF)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def gather_scores_q8(
    codes: jax.Array,   # i8[N, d] per-row int8 vector codes
    scales: jax.Array,  # f32[N]   per-row dequant scales
    ids: jax.Array,     # i32[B, C] candidate ids (any value; validated here)
    q: jax.Array,       # f32[B, d] uncompressed queries
    *,
    metric: str = "l2",
    interpret: bool | None = None,
) -> jax.Array:
    """[B, C] fused gather+asymmetric-distance over int8 codes; invalid ids
    (< 0 or >= N) → -inf. Same contract as ``gather_scores`` with the fp32
    row read replaced by a d-byte code row dequantized in-register."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    N = codes.shape[0]
    valid = (ids >= 0) & (ids < N)
    safe = jnp.where(valid, ids, 0).astype(jnp.int32)
    cp = _pad_to(codes, 1, 128, value=0)
    qp = _pad_to(q, 1, 128)
    s = _gd.gather_scores_q8_pallas(
        cp, scales.astype(jnp.float32), safe, qp, metric=metric,
        interpret=interpret,
    )
    return jnp.where(valid, s, NEG_INF)
