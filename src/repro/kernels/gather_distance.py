"""Fused gather + distance Pallas kernel — the beam-expansion hot loop.

Greedy search expands ``C`` candidate ids per query per step; XLA's gather
materializes ``[B, C, d]`` in HBM before the dot. This kernel instead drives
the table row DMA *from scalar-prefetched ids* (the paged-attention /
embedding-lookup TPU pattern): the BlockSpec index_map of the vector table
reads ``ids_ref[b, c]``, so each grid step pipelines exactly one needed row
HBM→VMEM, fuses the dot + norm correction, and writes a single score.

HBM traffic: ``B·C·d`` reads + ``B·C`` writes (vs ``2·B·C·d + B·C`` for the
unfused gather-then-einsum), and no intermediate buffer.

Caller contract (ops.py enforces): ids are pre-clamped to [0, N); invalid
lanes are fixed up outside (scores → -inf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gd_kernel(ids_ref, x_ref, xsq_ref, q_ref, o_ref, *, metric: str):
    del ids_ref  # consumed by the index_maps
    row = x_ref[0, :].astype(jnp.float32)
    qv = q_ref[0, :].astype(jnp.float32)
    dot = jnp.sum(row * qv)
    if metric == "l2":
        o_ref[0, 0] = 2.0 * dot - xsq_ref[0]
    else:
        o_ref[0, 0] = dot


def gather_scores_pallas(
    table: jax.Array,   # [N, d]  (d padded to 128 lanes by ops.py)
    tsq: jax.Array,     # f32[N]
    ids: jax.Array,     # i32[B, C]  pre-clamped to [0, N)
    q: jax.Array,       # [B, d]
    *,
    metric: str = "l2",
    interpret: bool = True,
) -> jax.Array:
    B, C = ids.shape
    d = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, C),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, c, ids_ref: (ids_ref[b, c], 0)),
            pl.BlockSpec((1,), lambda b, c, ids_ref: (ids_ref[b, c],)),
            pl.BlockSpec((1, d), lambda b, c, ids_ref: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, c, ids_ref: (b, c)),
    )
    return pl.pallas_call(
        functools.partial(_gd_kernel, metric=metric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(ids, table, tsq, q)


# ---------------------------------------------------------------------------
# Compressed variant — int8 codes + per-row scale, dequantized in-register
# (DESIGN.md §10). Same scalar-prefetch DMA pattern, but each gathered row
# moves d bytes instead of 4·d: the beam expansion's HBM traffic drops ~4x
# at identical grid/BlockSpec structure.
# ---------------------------------------------------------------------------

def _gdq_kernel(ids_ref, c_ref, s_ref, q_ref, o_ref, *, metric: str):
    del ids_ref  # consumed by the index_maps
    row = c_ref[0, :].astype(jnp.float32)
    qv = q_ref[0, :].astype(jnp.float32)
    s = s_ref[0]
    dot = jnp.sum(row * qv)
    if metric == "l2":
        # asymmetric l2 on the dequantized row x̂ = s·codes:
        #   2<x̂,q> − ||x̂||² = s·(2·<codes,q> − s·Σcodes²)
        o_ref[0, 0] = s * (2.0 * dot - s * jnp.sum(row * row))
    else:
        o_ref[0, 0] = s * dot


def gather_scores_q8_pallas(
    codes: jax.Array,   # i8[N, d]  (d padded to 128 lanes by ops.py)
    scales: jax.Array,  # f32[N]
    ids: jax.Array,     # i32[B, C]  pre-clamped to [0, N)
    q: jax.Array,       # [B, d] uncompressed queries
    *,
    metric: str = "l2",
    interpret: bool = True,
) -> jax.Array:
    B, C = ids.shape
    d = codes.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, C),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, c, ids_ref: (ids_ref[b, c], 0)),
            pl.BlockSpec((1,), lambda b, c, ids_ref: (ids_ref[b, c],)),
            pl.BlockSpec((1, d), lambda b, c, ids_ref: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, c, ids_ref: (b, c)),
    )
    return pl.pallas_call(
        functools.partial(_gdq_kernel, metric=metric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(ids, codes, scales, q)
