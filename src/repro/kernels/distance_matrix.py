"""Tiled score-matrix + fused streaming top-k Pallas kernels (TPU target).

Two kernels:

``_score_kernel`` — the MXU workhorse: grid (B/bB, M/bM, d/bD), fp32
accumulation in the output block, L2 norm correction folded into the last
d-tile. Block shapes default to (128, 256, 128): q-block 64KB + x-block
128KB + out-block 128KB ≈ 0.3MB of VMEM per step, well under the ~16MB/core
budget with double buffering.

``_topk_kernel`` — fused scoring + streaming top-k: grid (B/bB, M/bM) with
the full (padded) feature dim in VMEM; a scratch-carried running top-k is
merged per M-tile with an iterative max-extract (k compile-time steps of
elementwise max/min reductions — no sort/top_k primitive needed, so it
lowers on TPU). Avoids materializing the [B, M] matrix in HBM entirely:
bytes written drop from O(B·M) to O(B·k).

Used by: brute-force ground truth, ReBuild bulk kNN, DLRM retrieval_cand
(1M-candidate scoring), and the distributed result merge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# score matrix
# ---------------------------------------------------------------------------

def _score_kernel(x_ref, xsq_ref, q_ref, o_ref, *, n_d_tiles: int, metric: str):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    acc = 2.0 * jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) if metric == "l2" else jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += acc

    @pl.when(kd == n_d_tiles - 1)
    def _finish():
        if metric == "l2":
            o_ref[...] -= xsq_ref[...][None, :].astype(jnp.float32)


def score_matrix_pallas(
    x: jax.Array,     # [M, d]
    xsq: jax.Array,   # [M]
    q: jax.Array,     # [B, d]
    *,
    metric: str = "l2",
    block_b: int = 128,
    block_m: int = 256,
    block_d: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """[B, M] scores. Caller pads B/M/d to block multiples (see ops.py)."""
    B, d = q.shape
    M = x.shape[0]
    assert B % block_b == 0 and M % block_m == 0 and d % block_d == 0
    grid = (B // block_b, M // block_m, d // block_d)
    return pl.pallas_call(
        functools.partial(_score_kernel, n_d_tiles=grid[2], metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_d), lambda b, m, kd: (m, kd)),
            pl.BlockSpec((block_m,), lambda b, m, kd: (m,)),
            pl.BlockSpec((block_b, block_d), lambda b, m, kd: (b, kd)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda b, m, kd: (b, m)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        interpret=interpret,
    )(x, xsq, q)


# ---------------------------------------------------------------------------
# fused score + streaming top-k
# ---------------------------------------------------------------------------

def _iter_topk(scores: jax.Array, ids: jax.Array, k: int):
    """k-step max-extract top-k over the last axis (TPU-lowerable: only
    elementwise ops + max/min reductions, no sort)."""
    n = scores.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, scores.shape, len(scores.shape) - 1)
    out_s, out_i = [], []
    cur = scores
    for _ in range(k):
        m = jnp.max(cur, axis=-1, keepdims=True)                    # [B,1]
        is_max = cur == m
        pos = jnp.min(jnp.where(is_max, iota, n), axis=-1, keepdims=True)
        sel = iota == pos                                           # first max
        picked_id = jnp.sum(jnp.where(sel, ids, 0), axis=-1)
        out_s.append(m[..., 0])
        out_i.append(picked_id)
        cur = jnp.where(sel, NEG_INF, cur)
    return jnp.stack(out_s, axis=-1), jnp.stack(out_i, axis=-1)


def _topk_kernel(
    x_ref, xsq_ref, q_ref, os_ref, oi_ref, rs_ref, ri_ref,
    *, k: int, block_m: int, n_m_tiles: int, n_valid: int, metric: str,
):
    m_idx = pl.program_id(1)

    @pl.when(m_idx == 0)
    def _init():
        rs_ref[...] = jnp.full_like(rs_ref, NEG_INF)
        ri_ref[...] = jnp.full_like(ri_ref, -1)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    scores = 2.0 * dots - xsq_ref[...][None, :] if metric == "l2" else dots
    local_ids = (
        jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + m_idx * block_m
    )
    scores = jnp.where(local_ids < n_valid, scores, NEG_INF)  # padded rows lose

    comb_s = jnp.concatenate([rs_ref[...], scores], axis=1)
    comb_i = jnp.concatenate([ri_ref[...], local_ids], axis=1)
    top_s, top_i = _iter_topk(comb_s, comb_i, k)
    rs_ref[...] = top_s
    ri_ref[...] = top_i

    @pl.when(m_idx == n_m_tiles - 1)
    def _flush():
        os_ref[...] = rs_ref[...]
        oi_ref[...] = ri_ref[...]


def score_topk_pallas(
    x: jax.Array,
    xsq: jax.Array,
    q: jax.Array,
    k: int,
    *,
    metric: str = "l2",
    block_b: int = 64,
    block_m: int = 256,
    n_valid: int | None = None,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused (scores f32[B,k], ids i32[B,k]) without the [B,M] HBM matrix."""
    B, d = q.shape
    M = x.shape[0]
    assert B % block_b == 0 and M % block_m == 0
    grid = (B // block_b, M // block_m)
    return pl.pallas_call(
        functools.partial(
            _topk_kernel, k=k, block_m=block_m, n_m_tiles=grid[1],
            n_valid=n_valid if n_valid is not None else M, metric=metric,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda b, m: (m, 0)),
            pl.BlockSpec((block_m,), lambda b, m: (m,)),
            pl.BlockSpec((block_b, d), lambda b, m: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda b, m: (b, 0)),
            pl.BlockSpec((block_b, k), lambda b, m: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, k), jnp.float32),
            pltpu.VMEM((block_b, k), jnp.int32),
        ],
        interpret=interpret,
    )(x, xsq, q)
