"""jax version-compat shims.

The codebase targets the current jax API (``jax.shard_map`` with
``check_vma``, ``jax.set_mesh``, dict-valued ``cost_analysis``); the pinned
container image ships an older jax (0.4.x: ``jax.experimental.shard_map``
with ``check_rep``, context-manager ``Mesh``, list-valued
``cost_analysis``). Everything that touches the moving surface goes through
here so both generations run the same code.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with graceful fallback to the experimental API."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # 0.4.x: Mesh is itself a context manager


def axis_size(name):
    """``jax.lax.axis_size`` (newer jax) or the psum(1) equivalent."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def compiled_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict on every jax version."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca
