"""Workload generator: §6 protocol properties + resumability."""
import numpy as np

from repro.data.synthetic import kmeans, make_dataset
from repro.data.workload import make_workload


def test_dataset_shapes_and_skew():
    for name, d in [("sift", 128), ("glove200", 200), ("nytimes", 256),
                    ("gist", 960)]:
        x = make_dataset(name, 500, seed=0)
        assert x.shape == (500, d) and x.dtype == np.float32
    # skewed sets have higher cluster concentration than uniform ones
    sift = make_dataset("sift", 2000, seed=1)
    glove = make_dataset("glove200", 2000, seed=1)
    lab_s = kmeans(sift, 10, seed=0)
    lab_g = kmeans(glove, 10, seed=0)
    top_s = np.bincount(lab_s, minlength=10).max() / 2000
    top_g = np.bincount(lab_g, minlength=10).max() / 2000
    assert top_g > top_s, "glove surrogate must be more skewed than sift"


def test_random_workload_delete_liveness():
    wl = make_workload("sift", n_base=300, n_steps=5, batch_size=50,
                       n_queries=40, pattern="random", dim=16)
    live = np.zeros(300 + 5 * 50, bool)
    live[:300] = True
    for i in range(5):
        d = wl.step_deletes[i]
        assert live[d].all(), "must only delete live vectors"
        live[d] = False
        live[300 + i * 50: 300 + (i + 1) * 50] = True
    assert wl.queries.shape[0] == 40


def test_clustered_workload_spans():
    wl = make_workload("glove200", n_base=300, n_steps=3, batch_size=50,
                       n_queries=40, pattern="clustered", dim=24)
    for i in range(3):
        d = wl.step_deletes[i]
        np.testing.assert_array_equal(d, np.arange(i * 50, (i + 1) * 50))


def test_workload_resumable():
    wl = make_workload("sift", n_base=100, n_steps=3, batch_size=20,
                       n_queries=10, dim=8)
    wl.cursor = 2
    state = wl.state_dict()
    wl2 = make_workload("sift", n_base=100, n_steps=3, batch_size=20,
                        n_queries=10, dim=8)
    wl2.load_state_dict(state)
    assert wl2.cursor == 2
    np.testing.assert_array_equal(wl.step_inserts[2], wl2.step_inserts[2])


def test_sampler_blocks():
    from repro.data.graph_sampler import NeighborSampler, random_graph
    g = random_graph(100, 5, 8, 4, seed=0)
    s = NeighborSampler(g, (4, 3), batch=10, seed=0)
    b = s.next_batch()
    feats = b["blocks"]["feats"]
    assert feats[0].shape == (10 * 4 * 3, 8)
    assert feats[1].shape == (10 * 4, 8)
    assert feats[2].shape == (10, 8)
    assert b["block_labels"].shape == (10,)
    # resumability
    st = s.state.state_dict()
    s2 = NeighborSampler(g, (4, 3), batch=10, seed=0)
    s2.state.load_state_dict(st)
    assert s2.state.cursor == s.state.cursor


def test_sampler_subgraph_form():
    from repro.data.graph_sampler import NeighborSampler, random_graph
    g = random_graph(80, 4, 6, 3, seed=1)
    s = NeighborSampler(g, (3, 2), batch=8, seed=0)
    sub = s.as_subgraph()
    N = sub["x"].shape[0]
    assert N == 8 + 8 * 3 + 8 * 3 * 2
    assert sub["senders"].max() < N and sub["receivers"].max() < N
    assert sub["label_mask"][:8].all() and not sub["label_mask"][8:].any()
