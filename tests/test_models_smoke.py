"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs — the deliverable (f) requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as reg
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.steps import (
    make_dlrm_train_step,
    make_gnn_train_step,
    make_lm_train_step,
)

LM_ARCHS = [
    "phi3.5-moe-42b-a6.6b", "llama4-scout-17b-a16e", "qwen3-1.7b",
    "mistral-nemo-12b", "gemma2-27b",
]
GNN_ARCHS = ["dimenet", "graphsage-reddit", "gatedgcn", "gat-cora"]

OPT = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)


def _finite(tree) -> bool:
    return all(
        bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models import transformer as tfm
    spec = reg.get_arch(arch)
    cfg = spec.smoke_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), bool),
    }
    step = jax.jit(make_lm_train_step(cfg, OPT))
    params2, opt2, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert _finite(params2), f"{arch}: non-finite params after update"
    # decode path shape check
    h, _, cache = tfm.forward(params, batch["tokens"], cfg, return_cache_pad=S + 4)
    logits, cache2 = tfm.decode_step(
        params, cache, batch["tokens"][:, :1], cfg
    )
    assert logits.shape == (B, cfg.vocab)
    assert _finite(logits)


def _random_graph_batch(arch, cfg, rng):
    from repro.models.gnn.common import make_graph
    from repro.models.gnn.dimenet import build_triplets
    N, E = 40, 120
    senders = rng.integers(0, N, E)
    receivers = (senders + 1 + rng.integers(0, N - 1, E)) % N
    g = make_graph(
        rng.normal(size=(N, cfg.d_in)).astype(np.float32),
        senders, receivers,
        labels=rng.integers(0, getattr(cfg, "n_classes", 2), N),
        positions=rng.normal(size=(N, 3)).astype(np.float32),
        targets=np.zeros(1, np.float32),
    )
    batch = {"graph": g}
    if arch == "dimenet":
        batch["triplets"] = {
            k: jnp.asarray(v)
            for k, v in build_triplets(senders, receivers, E, 256).items()
        }
    return batch


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_smoke(arch_id):
    spec = reg.get_arch(arch_id)
    cfg = spec.smoke_config()
    arch = {
        "graphsage-reddit": "graphsage", "gat-cora": "gat",
        "gatedgcn": "gatedgcn", "dimenet": "dimenet",
    }[arch_id]
    rng = np.random.default_rng(0)
    from repro.models.gnn import dimenet, gat, gatedgcn, graphsage
    init = {
        "graphsage": graphsage.init_params, "gat": gat.init_params,
        "gatedgcn": gatedgcn.init_params, "dimenet": dimenet.init_params,
    }[arch]
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _random_graph_batch(arch, cfg, rng)
    step = jax.jit(make_gnn_train_step(arch, cfg, OPT))
    params2, _, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2), f"{arch}: non-finite params"


def test_graphsage_sampled_smoke():
    """Minibatch path through the real neighbor sampler."""
    from repro.data.graph_sampler import NeighborSampler, random_graph
    from repro.models.gnn import graphsage
    spec = reg.get_arch("graphsage-reddit")
    cfg = spec.smoke_config()
    g = random_graph(200, 6, cfg.d_in, cfg.n_classes, seed=0)
    sampler = NeighborSampler(g, cfg.sample_sizes, batch=16, seed=0)
    batch_np = sampler.next_batch()
    batch = jax.tree.map(jnp.asarray, batch_np)
    params = graphsage.init_params(jax.random.PRNGKey(0), cfg)
    logits = graphsage.forward_sampled(params, batch["blocks"], cfg)
    assert logits.shape == (16, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    step = jax.jit(make_gnn_train_step("graphsage", cfg, OPT))
    _, _, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))


def test_dlrm_smoke():
    from repro.models import dlrm as dlrm_mod
    spec = reg.get_arch("dlrm-rm2")
    cfg = spec.smoke_config()
    rng = np.random.default_rng(0)
    B = 32
    params = dlrm_mod.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "dense": jnp.asarray(rng.normal(size=(B, cfg.n_dense)), jnp.float32),
        "sparse_ids": jnp.asarray(
            rng.integers(0, cfg.n_rows, (B, cfg.n_sparse, cfg.nnz)), jnp.int32
        ),
        "sparse_mask": jnp.asarray(
            rng.random((B, cfg.n_sparse, cfg.nnz)) > 0.3
        ),
        "labels": jnp.asarray(rng.integers(0, 2, B), jnp.int32),
    }
    step = jax.jit(make_dlrm_train_step(cfg, OPT))
    params2, _, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2)
    # retrieval path (pallas kernel, interpret mode)
    q = jnp.asarray(rng.normal(size=(2, cfg.bot_mlp[-1])), jnp.float32)
    cands = jnp.asarray(rng.normal(size=(500, cfg.bot_mlp[-1])), jnp.float32)
    s, i = dlrm_mod.retrieval_scores(q, cands, 10)
    assert s.shape == (2, 10) and bool(jnp.all(i >= 0))


def test_ipgm_smoke():
    """The paper's own arch: reduced config end-to-end."""
    from repro.core import IPGMIndex
    spec = reg.get_arch("ipgm-online")
    cfg = spec.smoke_config()
    rng = np.random.default_rng(0)
    idx = IPGMIndex(cfg, strategy="global")
    idx.insert(rng.normal(size=(60, cfg.dim)).astype(np.float32))
    idx.delete(np.arange(10))
    r = idx.recall(rng.normal(size=(16, cfg.dim)).astype(np.float32), k=5)
    assert 0.0 <= r <= 1.0 and r > 0.5
