"""Distributed sharded index — runs in a subprocess with 8 fake devices
(XLA device count is locked at first jax init, so the multi-device tests
must not share this process)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.distributed.ann import (DistParams, init_sharded_state,
                                   make_query_step, make_insert_step,
                                   make_delete_step)
from repro.core.params import IndexParams, SearchParams

out = {}
mesh = jax.make_mesh((4, 2), ('data', 'model'))
ip = IndexParams(capacity=64, dim=16, d_out=8,
                 search=SearchParams(pool_size=16, max_steps=32, num_starts=2))
dp = DistParams(index=ip)
state = init_sharded_state(dp, mesh)
rng = np.random.default_rng(0)
X = rng.normal(size=(200, 16)).astype(np.float32)
route = np.arange(200).astype(np.int32)
with compat.use_mesh(mesh):
    st, gids = make_insert_step(dp, mesh)(state, jnp.asarray(X),
                                          jnp.asarray(route),
                                          jax.random.PRNGKey(0))
    g = np.asarray(gids)
    out['n_inserted'] = int((g >= 0).sum())
    out['gids_unique'] = len(set(g.tolist())) == 200

    Q = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    ids, scores = make_query_step(dp, mesh)(st, Q, jax.random.PRNGKey(1))
    allv = np.asarray(jax.device_get(st.vectors)).reshape(-1, 16)
    alive = np.asarray(jax.device_get(st.alive)).reshape(-1)
    d2 = ((allv[None] - np.asarray(Q)[:, None])**2).sum(-1)
    d2[:, ~alive] = np.inf
    true10 = np.argsort(d2, 1)[:, :10]
    found = np.asarray(ids)[:, :10]
    out['recall'] = float(np.mean([
        len(set(found[i]) & set(true10[i])) / 10 for i in range(32)
    ]))

    dels = jnp.asarray(g[:50])
    st2 = make_delete_step(dp, mesh, 'global')(st, dels, jax.random.PRNGKey(2))
    out['alive_after_delete'] = int(np.asarray(jax.device_get(st2.alive)).sum())

    # per-shard consolidation (DESIGN.md section 8): mask-delete through a
    # ShardedSession with an armed threshold, then drain the tombstones
    from repro.distributed.ann import ShardedSession
    from repro.core.params import MaintenanceParams
    ipm = IndexParams(capacity=64, dim=16, d_out=8,
                      search=SearchParams(pool_size=16, max_steps=32,
                                          num_starts=2),
                      maintenance=MaintenanceParams(
                          strategy='mask', delete_chunk=16,
                          consolidate_threshold=0.25, consolidate_chunk=16))
    sess = ShardedSession(DistParams(index=ipm), mesh, strategy='mask')
    gids2 = np.asarray(sess.insert(X, jnp.asarray(route)))
    sess.delete(gids2[:40])
    sess.flush()  # trigger point: 40/200 = 0.2 < 0.25 → explicit drain below
    out['sharded_masked_mid'] = int(np.asarray(jnp.sum(sess.state.masked)))
    n_cons = sess.consolidate()
    sess.flush()
    out['sharded_consolidated'] = n_cons
    out['sharded_masked_after'] = int(np.asarray(jnp.sum(sess.state.masked)))
    out['sharded_present_after'] = int(np.asarray(jnp.sum(sess.state.present)))
    sess.delete(gids2[40:100])  # 60 more: crosses 0.25 → auto-trigger
    sess.flush()
    out['sharded_auto_masked'] = int(np.asarray(jnp.sum(sess.state.masked)))
    out['sharded_n_consolidations'] = sess.timers.n_consolidations

    # lockstep capacity growth (DESIGN.md section 9): armed max_capacity,
    # inserts past the per-shard tier grow every shard at once, and gids
    # handed out at the small tier stay decodable (stride = max_capacity)
    ipg = IndexParams(capacity=16, dim=16, d_out=8,
                      search=SearchParams(pool_size=16, max_steps=32,
                                          num_starts=2),
                      maintenance=MaintenanceParams(
                          strategy='pure', insert_chunk=32, delete_chunk=32,
                          max_capacity=128))
    gs = ShardedSession(DistParams(index=ipg), mesh, strategy='pure')
    g1 = np.asarray(gs.insert(X[:100], jnp.arange(100)))
    g2 = np.asarray(gs.insert(X[100:200], jnp.arange(100, 200)))
    gs.flush()
    out['growth_cap'] = gs.dp.index.capacity
    out['growth_n_grows'] = gs.timers.n_grows
    out['growth_refused'] = gs.timers.n_refused
    out['growth_gids_unique'] = (
        len(set(g1.tolist()) | set(g2.tolist())) == 200)
    out['growth_alive'] = int(np.asarray(jnp.sum(gs.state.alive)))
    gs.delete(jnp.asarray(g1[:20]))  # pre-growth gids must still decode
    gs.flush()
    out['growth_alive_after_delete'] = int(np.asarray(jnp.sum(gs.state.alive)))
    qi, _ = gs.query(Q[:8])
    out['growth_query_valid'] = bool((np.asarray(qi)[:, 0] >= 0).all())

    # fault-injection coverage (DESIGN.md section 11): a mixed sharded
    # stream with growth + consolidation armed reaches every registered
    # sharded crash point, and an armed plan kills at the exact site
    from repro.testing import faults
    ipf = IndexParams(capacity=16, dim=16, d_out=8,
                      search=SearchParams(pool_size=16, max_steps=32,
                                          num_starts=2),
                      maintenance=MaintenanceParams(
                          strategy='mask', insert_chunk=32, delete_chunk=32,
                          consolidate_threshold=0.25, consolidate_chunk=16,
                          max_capacity=128))
    probe = faults.FaultPlan()
    with faults.inject(probe):
        fs = ShardedSession(DistParams(index=ipf), mesh, strategy='mask')
        fg1 = np.asarray(fs.insert(X[:100], jnp.arange(100)))
        fs.insert(X[100:200], jnp.arange(100, 200))
        fs.delete(jnp.asarray(fg1[:60]))
        fs.consolidate()
        fs.flush()
    out['fault_hits'] = {p: probe.hits.get(p, 0)
                         for p in faults.SHARDED_CRASH_POINTS}
    crashed = False
    with faults.inject(faults.crash_once('sharded-pre-dispatch', hit=1)):
        try:
            fs.insert(X[:10], jnp.arange(10))
        except faults.SimulatedCrash:
            crashed = True
    out['fault_crash_fired'] = crashed

    # multi-pod replica mesh
    mesh3 = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
    dp3 = DistParams(index=ip, pod_axis='pod')
with compat.use_mesh(mesh3):
    st3 = init_sharded_state(dp3, mesh3)
    st3, gids3 = make_insert_step(dp3, mesh3)(st3, jnp.asarray(X[:80]),
                                              jnp.asarray(route[:80]),
                                              jax.random.PRNGKey(0))
    ids3, _ = make_query_step(dp3, mesh3)(st3, Q[:8], jax.random.PRNGKey(1))
    out['multipod_inserted'] = int((np.asarray(gids3) >= 0).sum())
    out['multipod_results_valid'] = bool((np.asarray(ids3)[:, 0] >= 0).all())

print('RESULT ' + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_index_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=540,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT ")]
    assert line, proc.stdout
    out = json.loads(line[-1][len("RESULT "):])
    assert out["n_inserted"] == 200
    assert out["gids_unique"]
    assert out["recall"] > 0.9
    assert out["alive_after_delete"] == 150
    assert out["sharded_masked_mid"] == 40
    assert out["sharded_consolidated"] == 40
    assert out["sharded_masked_after"] == 0
    assert out["sharded_present_after"] == 160
    assert out["sharded_auto_masked"] == 0, "threshold crossing must drain"
    assert out["sharded_n_consolidations"] >= 2
    assert out["growth_cap"] > 16, "shards must have grown in lockstep"
    assert out["growth_cap"] <= 128
    assert out["growth_n_grows"] <= 3  # ceil(log2(128/16)) recompiles max
    assert out["growth_refused"] == 0
    assert out["growth_gids_unique"]
    assert out["growth_alive"] == 200
    assert out["growth_alive_after_delete"] == 180
    assert out["growth_query_valid"]
    missing = [p for p, n in out["fault_hits"].items() if n == 0]
    assert not missing, f"sharded stream never reached crash points: {missing}"
    assert out["fault_crash_fired"], "armed sharded crash point must fire"
    assert out["multipod_inserted"] == 80
    assert out["multipod_results_valid"]
