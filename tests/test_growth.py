"""Capacity growth engine (DESIGN.md §9): gate, arbitration, refusal books.

Session-level coverage of the dynamic growth subsystem. Graph-level
``grow_state`` invariants live in test_graph_invariants.py, stream-level
differential coverage in test_stream_fuzz.py, and checkpoint capacity
compatibility in test_checkpoint.py.
"""
import numpy as np
import pytest

from helpers import check_invariants
from repro.core import (
    IndexParams,
    IPGMIndex,
    MaintenanceParams,
    SearchParams,
    Session,
    run_workload,
)
from repro.core.graph import NULL, next_capacity_tier

DIM = 8


def _params(capacity=32, **mkw):
    kw = dict(strategy="global", insert_chunk=16, delete_chunk=16)
    kw.update(mkw)
    return IndexParams(
        capacity=capacity, dim=DIM, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(**kw),
    )


def test_next_capacity_tier():
    assert next_capacity_tier(1024, 1024, 2.0, None) == 1024
    assert next_capacity_tier(1024, 1025, 2.0, None) == 2048
    assert next_capacity_tier(1024, 9000, 2.0, None) == 16384
    assert next_capacity_tier(1024, 9000, 2.0, 8192) == 8192  # clipped
    assert next_capacity_tier(10, 11, 1.5, None) == 15
    assert next_capacity_tier(16, 100, 2.0, 16) == 16  # capped out
    assert next_capacity_tier(16, 8, 2.0, None) == 16  # never shrinks


def test_full_index_reports_refusals():
    """Regression (ISSUE 5): a full fixed-capacity index must *count* the
    NULL ids it hands out — silently vanishing inserts are the bug."""
    rng = np.random.default_rng(0)
    sess = Session(_params(capacity=16), seed=0)
    ids = np.asarray(
        sess.insert(rng.normal(size=(20, DIM)).astype(np.float32)).result())
    assert (ids[:16] != NULL).all() and (ids[16:] == NULL).all()
    assert sess.timers.n_refused == 4
    assert sess.stats()["n_refused"] == 4
    ids2 = np.asarray(
        sess.insert(rng.normal(size=(3, DIM)).astype(np.float32)).result())
    assert (ids2 == NULL).all()
    assert sess.timers.n_refused == 7
    assert sess.timers.n_grows == 0 and sess.state.capacity == 16
    assert "n_refused" in sess.timers.to_dict()


def test_workload_summary_reports_refusals():
    rng = np.random.default_rng(1)
    sess = Session(_params(capacity=16), seed=0)
    recs = run_workload(
        sess, [("insert", rng.normal(size=(20, DIM)).astype(np.float32))])
    assert recs[-1]["op"] == "summary"
    assert recs[-1]["timers"]["n_refused"] == 4


def test_armed_session_grows_instead_of_refusing():
    rng = np.random.default_rng(2)
    sess = Session(_params(capacity=16, max_capacity=256), seed=0)
    ids = np.asarray(
        sess.insert(rng.normal(size=(100, DIM)).astype(np.float32)).result())
    assert (ids != NULL).all()
    assert sess.timers.n_refused == 0
    assert 100 <= sess.state.capacity <= 256
    assert 1 <= sess.timers.n_grows <= 4  # ceil(log2(256/16))
    assert not check_invariants(sess.state)
    Q = rng.normal(size=(16, DIM)).astype(np.float32)
    assert sess.recall(Q, 10) > 0.8
    st = sess.stats()
    assert st["capacity"] == sess.state.capacity and st["n_grows"] >= 1


def test_growth_caps_at_max_capacity_then_refuses():
    rng = np.random.default_rng(3)
    sess = Session(_params(capacity=16, max_capacity=24), seed=0)
    ids = np.asarray(
        sess.insert(rng.normal(size=(30, DIM)).astype(np.float32)).result())
    assert (ids[:24] != NULL).all() and (ids[24:] == NULL).all()
    assert sess.state.capacity == 24
    assert sess.timers.n_refused == 6


def test_arbitration_prefers_consolidate_over_grow():
    """Tombstones that cover the shortfall are compacted inside the current
    shape family — the session must not pay a growth recompile for slots
    consolidation can reclaim."""
    rng = np.random.default_rng(4)
    sess = Session(_params(capacity=32, strategy="mask", max_capacity=256),
                   seed=0)
    ids = sess.insert(rng.normal(size=(32, DIM)).astype(np.float32)).result()
    sess.delete(np.asarray(ids[:16]))
    new = np.asarray(
        sess.insert(rng.normal(size=(10, DIM)).astype(np.float32)).result())
    assert (new != NULL).all()
    assert sess.timers.n_grows == 0, "tombstones covered the shortfall"
    assert sess.timers.n_consolidations == 1
    assert sess.timers.n_refused == 0
    assert sess.state.capacity == 32
    # the compacted slots were genuinely reused, lowest-first
    assert np.array_equal(new, np.arange(10))


def test_arbitration_grows_when_tombstones_insufficient():
    rng = np.random.default_rng(5)
    sess = Session(_params(capacity=32, strategy="mask", max_capacity=256),
                   seed=0)
    ids = sess.insert(rng.normal(size=(32, DIM)).astype(np.float32)).result()
    sess.delete(np.asarray(ids[:4]))
    new = np.asarray(
        sess.insert(rng.normal(size=(10, DIM)).astype(np.float32)).result())
    assert (new != NULL).all()
    assert sess.timers.n_consolidations == 1  # compacted first ...
    assert sess.timers.n_grows == 1           # ... then grew for the rest
    assert sess.state.capacity > 32
    assert sess.timers.n_refused == 0
    assert not check_invariants(sess.state)


def test_explicit_grow_and_allocator_handoff():
    """Session.grow is callable directly (maintenance scripts); the new
    slots join the allocator immediately and old results stay valid."""
    rng = np.random.default_rng(6)
    sess = Session(_params(capacity=16), seed=0)
    ids = sess.insert(rng.normal(size=(16, DIM)).astype(np.float32)).result()
    sess.grow(48)
    assert sess.state.capacity == 48
    new = np.asarray(
        sess.insert(rng.normal(size=(20, DIM)).astype(np.float32)).result())
    assert (new != NULL).all()
    assert np.array_equal(new, np.arange(16, 36))  # appended free slots
    assert (np.asarray(ids) < 16).all()
    assert sess.timers.n_refused == 0
    with pytest.raises(ValueError, match="shrink"):
        sess.grow(16)
    # an *armed* session refuses explicit grows past its ceiling — every
    # tier it can save is one its own config restores
    armed = Session(_params(capacity=16, max_capacity=64), seed=0)
    with pytest.raises(ValueError, match="max_capacity"):
        armed.grow(128)


def test_max_capacity_below_initial_capacity_rejected():
    with pytest.raises(AssertionError, match="max_capacity"):
        _params(capacity=64, max_capacity=32)


def test_rebuild_from_alive_uses_live_capacity():
    """Regression (ISSUE 5): ``rebuild_from_alive`` padded to the *initial*
    ``params.capacity`` — after a growth that both desyncs the tier and
    cannot even hold the alive set. It must rebuild at ``state.capacity``."""
    rng = np.random.default_rng(7)
    sess = Session(_params(capacity=16, max_capacity=256), seed=0)
    sess.insert(rng.normal(size=(60, DIM)).astype(np.float32)).result()
    cap = sess.state.capacity
    assert cap >= 60 > 16
    sess.rebuild_from_alive()
    assert sess.state.capacity == cap, "rebuild must keep the live tier"
    assert sess.stats()["n_alive"] == 60
    assert not check_invariants(sess.state)
    ids = np.asarray(sess.insert(
        rng.normal(size=(cap - 60, DIM)).astype(np.float32)).result())
    assert (ids != NULL).all()
    assert sess.timers.n_refused == 0


def test_growth_timing_does_not_shift_op_keys():
    """A session that grew mid-stream and one born at the final tier run the
    same op-key chain: insert slot assignment is bit-identical (allocation
    is lowest-free-first; growth only appends free slots)."""
    rng = np.random.default_rng(8)
    batches = [rng.normal(size=(n, DIM)).astype(np.float32)
               for n in (30, 40, 50)]
    grown = Session(_params(capacity=32, max_capacity=512), seed=5)
    static = Session(_params(capacity=256, max_capacity=512), seed=5)
    for b in batches:
        g = np.asarray(grown.insert(b).result())
        s = np.asarray(static.insert(b).result())
        np.testing.assert_array_equal(g, s)
    assert grown.timers.n_grows >= 1 and static.timers.n_grows == 0
    assert grown._op_counter == static._op_counter
    n = min(grown.state.capacity, static.state.capacity)
    np.testing.assert_array_equal(np.asarray(grown.state.alive)[:n],
                                  np.asarray(static.state.alive)[:n])


def test_facade_growth_passthrough():
    rng = np.random.default_rng(9)
    idx = IPGMIndex(_params(capacity=16, max_capacity=128), seed=0)
    ids = np.asarray(idx.insert(rng.normal(size=(50, DIM))
                                .astype(np.float32)))
    assert (ids != NULL).all()
    st = idx.stats()
    assert st["capacity"] >= 50 and st["n_refused"] == 0
    assert not check_invariants(idx.state)
