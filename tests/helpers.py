"""Test helpers: invariant checkers + tiny index builders."""
from __future__ import annotations

import numpy as np

from repro.core import IPGMIndex, IndexParams, SearchParams
from repro.core.graph import NULL


def small_params(capacity=256, dim=8, d_out=6, pool=16) -> IndexParams:
    return IndexParams(
        capacity=capacity, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2),
    )


def build_index(X, strategy="global", capacity=None, **kw) -> IPGMIndex:
    cap = capacity if capacity is not None else X.shape[0] + 64
    p = small_params(capacity=cap, dim=X.shape[1], **kw)
    idx = IPGMIndex(p, strategy=strategy, seed=0)
    idx.insert(X)
    return idx


def check_invariants(state) -> list[str]:
    """Returns a list of violated invariants (empty = healthy)."""
    adj = np.asarray(state.adj)
    radj = np.asarray(state.radj)
    alive = np.asarray(state.alive)
    present = np.asarray(state.present)
    errors = []

    # I3: alive ⇒ present
    if (alive & ~present).any():
        errors.append("alive slot not present")

    cap = adj.shape[0]
    for u in range(cap):
        row = adj[u]
        vals = row[row != NULL]
        # I4: no dups / self-edges
        if len(set(vals.tolist())) != len(vals):
            errors.append(f"dup out-edges at {u}")
        if (vals == u).any():
            errors.append(f"self-edge at {u}")
        if not present[u] and len(vals):
            errors.append(f"edges from non-present {u}")
        for v in vals:
            # I2: edges point at present slots
            if not present[v]:
                errors.append(f"dangling edge {u}->{v}")
            # I1: reverse entry exists
            if u not in radj[v]:
                errors.append(f"missing reverse {u}->{v}")
    for v in range(cap):
        row = radj[v]
        vals = row[row != NULL]
        if len(set(vals.tolist())) != len(vals):
            errors.append(f"dup in-edges at {v}")
        for u in vals:
            if v not in adj[u]:
                errors.append(f"stale reverse {u}->{v}")
    return errors
