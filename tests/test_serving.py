"""Serving front-end: batching, pad stability, quorum degradation,
MASK consolidation."""
import numpy as np
import pytest

from helpers import build_index, check_invariants
from repro.core.consolidate import (
    consolidate,
    consolidate_reference,
    masked_fraction,
    maybe_consolidate,
)
from repro.core.graph import NULL
from repro.serving.batcher import BatchedServer, ServeConfig, quorum_merge


def test_batched_server_roundtrip():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(200, 8)).astype(np.float32)
    idx = build_index(X, capacity=256)
    srv = BatchedServer(idx, ServeConfig(max_batch=16, k=5))
    rids = [srv.submit(X[i] + 0.01) for i in range(5)]
    out = srv.step()
    assert set(out) == set(rids)
    for i, rid in enumerate(rids):
        ids, scores = out[rid]
        assert ids.shape == (5,)
        assert i in ids.tolist(), "query ≈ a stored vector must find it"
    assert srv.stats["requests"] == 5 and srv.stats["batches"] == 1


class _FakeTime:
    """Deterministic clock that only advances when sleep() is called."""

    def __init__(self):
        self.now = 0.0
        self.sleeps = []
        self.on_sleep = None

    def clock(self):
        return self.now

    def sleep(self, dt):
        assert dt > 0, "sleep(<=0) would busy-spin"
        self.sleeps.append(dt)
        self.now += dt
        if self.on_sleep is not None:
            self.on_sleep(self.now)


def _server(cfg, ft):
    rng = np.random.default_rng(9)
    X = rng.normal(size=(60, 8)).astype(np.float32)
    idx = build_index(X, capacity=96)
    return BatchedServer(idx, cfg, clock=ft.clock, sleep=ft.sleep)


def test_drain_waits_for_late_arrivals():
    """Regression: the max_wait_s branch used to be dead code (an empty
    queue hit `break` immediately), so adaptive batching never waited."""
    ft = _FakeTime()
    srv = _server(ServeConfig(max_batch=4, max_wait_s=0.005, k=3), ft)
    srv.submit(np.zeros(8, np.float32))

    def late_arrival(now):
        if now >= 0.002 and srv.stats.get("_arrived") is None:
            srv.stats["_arrived"] = True
            srv.submit(np.ones(8, np.float32))

    ft.on_sleep = late_arrival
    batch = srv._drain()
    assert len(batch) == 2, "mid-window arrival must join the batch"
    assert ft.now <= 0.005 + srv._POLL_S, "deadline overshot"


def test_drain_deadline_bounded_and_not_spinning():
    ft = _FakeTime()
    srv = _server(ServeConfig(max_batch=4, max_wait_s=0.005, k=3), ft)
    srv.submit(np.zeros(8, np.float32))
    batch = srv._drain()
    assert len(batch) == 1
    # waited the full window (clock advanced to the deadline)...
    assert abs(ft.now - 0.005) < 1e-9
    # ...in bounded slices, not a hot spin
    assert 0 < len(ft.sleeps) <= int(0.005 / srv._POLL_S) + 2
    assert all(dt > 0 for dt in ft.sleeps)


def test_drain_idle_and_full_batch_skip_the_wait():
    ft = _FakeTime()
    srv = _server(ServeConfig(max_batch=2, max_wait_s=0.005, k=3), ft)
    assert srv._drain() == [] and not ft.sleeps, "idle queue must not block"
    srv.submit(np.zeros(8, np.float32))
    srv.submit(np.ones(8, np.float32))
    srv.submit(np.zeros(8, np.float32))
    assert len(srv._drain()) == 2 and not ft.sleeps, "full batch is immediate"
    assert len(srv._queue) == 1


def test_quorum_merge_degrades_gracefully():
    rng = np.random.default_rng(1)
    P, B, k = 8, 4, 10
    scores = rng.normal(size=(P, B, k)).astype(np.float32)
    scores.sort(axis=-1)
    scores = scores[..., ::-1]
    ids = rng.integers(0, 10_000, size=(P, B, k)).astype(np.int32)

    full_i, full_s = quorum_merge(ids, scores, np.ones(P, bool), k)
    # drop 2 shards
    arrived = np.ones(P, bool)
    arrived[[2, 5]] = False
    part_i, part_s = quorum_merge(ids, scores, arrived, k)
    assert (part_s <= full_s + 1e-6).all(), "partial merge can't beat full"
    # every returned id comes from an arrived shard
    alive_ids = set(ids[arrived].reshape(-1).tolist())
    got = part_i[part_i != NULL]
    assert set(got.tolist()) <= alive_ids
    # overlap stays high: ≥ k - 2·k/P expected per row on average
    overlap = np.mean([
        len(set(full_i[b]) & set(part_i[b])) / k for b in range(B)
    ])
    assert overlap >= 0.6


def test_consolidate_removes_tombstones():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(150, 8)).astype(np.float32)
    idx = build_index(X, strategy="mask", capacity=256)
    idx.delete(np.arange(40))
    assert masked_fraction(idx.state) > 0.25
    n = consolidate(idx, strategy="global")
    assert n == 40
    assert masked_fraction(idx.state) == 0.0
    st = idx.stats()
    assert st["n_alive"] == 110 and st["n_masked"] == 0
    assert not check_invariants(idx.state)
    # recall survives consolidation
    Q = rng.normal(size=(32, 8)).astype(np.float32)
    assert idx.recall(Q, k=5) > 0.6


def test_maybe_consolidate_threshold():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(100, 8)).astype(np.float32)
    idx = build_index(X, strategy="mask", capacity=160)
    idx.delete(np.arange(10))           # 10% masked < 20% threshold
    assert maybe_consolidate(idx, threshold=0.2) == 0
    idx.delete(np.arange(10, 25))       # now 25% masked
    assert maybe_consolidate(idx, threshold=0.2) == 25


def _masked_index(seed=8, n=120, n_del=35):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 8)).astype(np.float32)
    idx = build_index(X, strategy="mask", capacity=192)
    idx.delete(rng.choice(n, size=n_del, replace=False))
    return idx, rng


def test_consolidate_reference_parity_pins_jitted_pass():
    """The exception-safe revive-then-delete oracle and the jitted chunked
    compaction agree semantically at small N: identical alive/present sets,
    masked fraction 0, invariant-clean graphs, equivalent recall. (Edge
    layouts differ by construction — the repair searches draw from
    different key chains — so the pin is set-level, not byte-level.)"""
    idx_ref, rng = _masked_index()
    idx_jit, _ = _masked_index()
    n_ref = consolidate_reference(idx_ref, strategy="global")
    n_jit = consolidate(idx_jit, strategy="global")
    assert n_ref == n_jit == 35
    for idx in (idx_ref, idx_jit):
        assert masked_fraction(idx.state) == 0.0
        assert not check_invariants(idx.state)
    np.testing.assert_array_equal(
        np.asarray(idx_ref.state.alive), np.asarray(idx_jit.state.alive))
    np.testing.assert_array_equal(
        np.asarray(idx_ref.state.present), np.asarray(idx_jit.state.present))
    Q = rng.normal(size=(48, 8)).astype(np.float32)
    r_ref = idx_ref.recall(Q, k=10)
    r_jit = idx_jit.recall(Q, k=10)
    assert abs(r_ref - r_jit) < 0.1, (r_ref, r_jit)
    assert min(r_ref, r_jit) > 0.6


def test_consolidate_reference_is_exception_safe():
    """Regression for the revive-then-delete hack: a repair failure used to
    leave tombstones revived and a foreign strategy installed. Now the
    state and strategy roll back, and a later pass still succeeds."""
    idx, _ = _masked_index()
    alive_before = np.asarray(idx.state.alive).copy()
    present_before = np.asarray(idx.state.present).copy()

    real_delete = idx.session.delete

    def boom(*a, **k):
        raise RuntimeError("injected repair failure")

    idx.session.delete = boom
    with pytest.raises(RuntimeError, match="injected"):
        consolidate_reference(idx, strategy="global")
    idx.session.delete = real_delete

    assert idx.strategy == "mask", "strategy must roll back"
    np.testing.assert_array_equal(np.asarray(idx.state.alive), alive_before)
    np.testing.assert_array_equal(
        np.asarray(idx.state.present), present_before)
    assert not check_invariants(idx.state)
    # the rolled-back index is fully functional: the real pass still drains
    assert consolidate(idx, strategy="global") == 35
    assert masked_fraction(idx.state) == 0.0


# ---------------------------------------------------------------------------
# graceful degradation (DESIGN.md §11): shedding, deadlines, readiness
# ---------------------------------------------------------------------------

def test_bounded_queue_sheds_overload():
    from repro.serving.batcher import ServerOverloadError

    ft = _FakeTime()
    srv = _server(ServeConfig(max_batch=4, k=3, max_queue=3), ft)
    for _ in range(3):
        srv.submit(np.zeros(8, np.float32))
    with pytest.raises(ServerOverloadError):
        srv.submit(np.zeros(8, np.float32))
    assert srv.stats["shed_overload"] == 1
    assert len(srv._queue) == 3, "the shed request must not occupy a slot"
    # draining frees capacity: admission recovers
    out = srv.step()
    assert len(out) == 3
    srv.submit(np.zeros(8, np.float32))
    assert srv.stats["shed_overload"] == 1


def test_per_request_deadline_expires_stale_entries():
    ft = _FakeTime()
    srv = _server(
        ServeConfig(max_batch=4, max_wait_s=0.0, k=3, deadline_s=0.01), ft)
    stale = srv.submit(np.zeros(8, np.float32))
    ft.now += 0.02  # the request ages past its deadline while queued
    fresh = srv.submit(np.ones(8, np.float32))
    out = srv.step()
    assert fresh in out and stale not in out
    assert srv.failed[stale] == "deadline"
    assert srv.stats["shed_deadline"] == 1
    ids, _ = out[fresh]
    assert ids.shape == (3,)


def test_readiness_gate_rejects_until_recovered():
    from repro.serving.batcher import ServerNotReadyError

    ft = _FakeTime()
    srv = _server(ServeConfig(max_batch=4, k=3), ft)
    assert srv.ready
    srv.set_ready(False)
    with pytest.raises(ServerNotReadyError):
        srv.submit(np.zeros(8, np.float32))
    srv.set_ready(True)
    srv.submit(np.zeros(8, np.float32))
    assert len(srv.step()) == 1


def test_readiness_tracks_session_recovery_flag():
    """A server wrapping a recovering session reports not-ready without any
    explicit wiring: `ready` consults session.recovering."""
    from repro.serving.batcher import ServerNotReadyError

    ft = _FakeTime()
    srv = _server(ServeConfig(max_batch=4, k=3), ft)
    srv.session.recovering = True
    assert not srv.ready
    with pytest.raises(ServerNotReadyError):
        srv.submit(np.zeros(8, np.float32))
    srv.session.recovering = False
    assert srv.ready
