"""SELECT-NEIGHBORS vs a literal brute-force transcription of Alg 2."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis;
# skip (not error) where it is not baked into the image
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graph import NULL
from repro.core.select import select_neighbors


def reference_select(x, cands, valid, d):
    """Direct Alg 2: scan by distance to x; keep y iff
    ||x-y|| <= min_{z selected} ||z-y||."""
    order = sorted(
        [i for i in range(len(cands)) if valid[i]],
        key=lambda i: np.sum((x - cands[i]) ** 2),
    )
    sel = []
    for i in order:
        if len(sel) >= d:
            break
        dx = np.sum((x - cands[i]) ** 2)
        if all(np.sum((cands[j] - cands[i]) ** 2) >= dx for j in sel):
            sel.append(i)
    return sel


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 24),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(6,)).astype(np.float32)
    cands = rng.normal(size=(n, 6)).astype(np.float32)
    valid = rng.random(n) > 0.2
    ids = np.arange(n, dtype=np.int32)

    got = select_neighbors(
        jnp.asarray(x), jnp.asarray(ids), jnp.asarray(cands),
        jnp.asarray(valid), d, "l2",
    )
    got = [int(i) for i in np.asarray(got) if i != NULL]
    want = reference_select(x, cands, valid, d)
    assert got == want


def test_diversity_prunes_collinear():
    """Two near-duplicate candidates: only the closer one survives."""
    x = np.zeros(2, np.float32)
    cands = np.asarray([[1, 0], [1.1, 0], [0, 1]], np.float32)
    got = select_neighbors(
        jnp.asarray(x), jnp.arange(3, dtype=jnp.int32), jnp.asarray(cands),
        jnp.ones(3, bool), 3, "l2",
    )
    got = [int(i) for i in np.asarray(got) if i != NULL]
    assert got == [0, 2]  # candidate 1 dominated by 0


def test_respects_degree_threshold():
    rng = np.random.default_rng(0)
    cands = rng.normal(size=(20, 4)).astype(np.float32) * 10
    got = select_neighbors(
        jnp.zeros(4), jnp.arange(20, dtype=jnp.int32), jnp.asarray(cands),
        jnp.ones(20, bool), 3, "l2",
    )
    assert (np.asarray(got) != NULL).sum() <= 3


def test_dedup_and_exclusion():
    from repro.core import init_graph
    import dataclasses
    import jax

    state = init_graph(8, 4, d_out=4)
    vecs = jnp.asarray(np.eye(8, 4), jnp.float32)
    state = dataclasses.replace(
        state, vectors=vecs, alive=jnp.ones(8, bool), present=jnp.ones(8, bool),
        sqnorms=jnp.sum(vecs * vecs, axis=1),
    )
    from repro.core.select import select_from_pool
    cands = jnp.asarray([1, 1, 2, 3, NULL], jnp.int32)  # dup id 1
    got = select_from_pool(state, jnp.ones(4), cands, 4,
                           exclude=jnp.asarray([2], jnp.int32))
    vals = [int(i) for i in np.asarray(got) if i != NULL]
    assert 2 not in vals
    assert vals.count(1) <= 1
