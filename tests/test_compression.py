"""int8 gradient compression: quantization error bounds + exact reduction."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    dequantize,
    quantize_int8,
    wire_bytes_saved,
)


def test_quantize_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = quantize_int8(x, jax.random.PRNGKey(0))
    err = np.asarray(jnp.abs(dequantize(q, s) - x))
    assert err.max() <= float(s) * 1.01  # ≤ one quantization bin


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 0.3)
    q, s = quantize_int8(x, jax.random.PRNGKey(1))
    mean = float(jnp.mean(dequantize(q, s)))
    assert abs(mean - 0.3) < 2e-3


def test_wire_bytes():
    g = {"a": jnp.zeros((100,)), "b": jnp.zeros((50,))}
    fp32, int8 = wire_bytes_saved(g)
    assert fp32 == 600 and int8 < fp32 / 3


def test_compressed_psum_multi_device():
    """Single-device psum (axis of size 1) must be ≈ identity."""
    from repro.distributed.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("d",))
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(size=(64,)).astype(np.float32))}

    def f(grads):
        return compressed_psum(grads, jax.random.PRNGKey(0), "d")

    from repro import compat
    out = jax.jit(compat.shard_map(
        f, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(),),
        out_specs=jax.sharding.PartitionSpec(),
        check_vma=False,
    ))(g)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    np.testing.assert_allclose(
        np.asarray(out["w"]), np.asarray(g["w"]), atol=1.01 * scale
    )
