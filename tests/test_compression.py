"""int8 gradient compression: quantization error bounds + exact reduction.

Also covers the *deterministic* per-row vector-code quantizer (DESIGN.md
§10) re-exported here next to the stochastic gradient quantizer.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    dequantize,
    dequantize_rows,
    quantize_int8,
    quantize_rows,
    wire_bytes_saved,
)


def test_quantize_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = quantize_int8(x, jax.random.PRNGKey(0))
    err = np.asarray(jnp.abs(dequantize(q, s) - x))
    assert err.max() <= float(s) * 1.01  # ≤ one quantization bin


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 0.3)
    q, s = quantize_int8(x, jax.random.PRNGKey(1))
    mean = float(jnp.mean(dequantize(q, s)))
    assert abs(mean - 0.3) < 2e-3


def test_stochastic_rounding_unbiased_over_keys():
    """E_key[dequantize(quantize(x, key))] == x elementwise: the mean over
    many independent keys of a FIXED vector must converge to the vector
    (the per-key test above only checks the mean over elements)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(-1.0, 1.0, size=(64,)).astype(np.float32))
    n_keys = 4000
    keys = jax.random.split(jax.random.PRNGKey(7), n_keys)
    deq = jax.vmap(lambda k: dequantize(*quantize_int8(x, k)))(keys)
    mean = np.asarray(jnp.mean(deq, axis=0))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    # CLT: per-element sd ≤ scale/2, so 5·scale/(2·√n) is a ~5σ band
    tol = 5.0 * scale / (2.0 * np.sqrt(n_keys))
    np.testing.assert_allclose(mean, np.asarray(x), atol=tol)


def test_compressed_psum_vs_fp32_psum_small_trees():
    """compressed_psum == fp32 psum-mean up to the local quantization error
    (the int8 reduction itself is exact), on a small multi-leaf tree."""
    from repro.distributed.compression import compressed_psum

    from repro import compat

    mesh = jax.make_mesh((1,), ("d",))
    rng = np.random.default_rng(3)
    tree = {
        "w": jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(16,)).astype(np.float32) * 1e-3),
    }

    def f(grads):
        comp = compressed_psum(grads, jax.random.PRNGKey(0), "d")
        exact = jax.tree.map(
            lambda g: jax.lax.psum(g, "d") / jax.lax.psum(1, "d"), grads)
        return comp, exact

    P = jax.sharding.PartitionSpec
    comp, exact = jax.jit(compat.shard_map(
        f, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        check_vma=False,
    ))(tree)
    for name in tree:
        scale = float(jnp.max(jnp.abs(tree[name]))) / 127.0
        np.testing.assert_allclose(
            np.asarray(comp[name]), np.asarray(exact[name]),
            atol=1.01 * scale,
        )


def test_quantize_rows_roundtrip_and_determinism():
    """Row quantizer: error ≤ scale/2 per element, deterministic (no key),
    zero rows → (zero codes, positive sentinel scale) — distinct from the
    freed-slot (0, 0.0) scrub (DESIGN.md §10, scheme v2)."""
    from repro.core.quantize import ZERO_ROW_SCALE

    x = np.random.default_rng(4).normal(size=(50, 24)).astype(np.float32)
    x[7] = 0.0
    xj = jnp.asarray(x)
    c1, s1 = quantize_rows(xj)
    c2, s2 = quantize_rows(xj)
    assert np.array_equal(np.asarray(c1), np.asarray(c2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    err = np.abs(np.asarray(dequantize_rows(c1, s1)) - x)
    assert (err <= np.asarray(s1)[:, None] * 0.5 + 1e-7).all()
    assert (np.asarray(c1)[7] == 0).all()
    assert float(s1[7]) == float(ZERO_ROW_SCALE) > 0.0
    # stacked leading dims (the ShardedSession layout) quantize identically
    cs, ss = quantize_rows(jnp.asarray(x.reshape(2, 25, 24)))
    assert np.array_equal(np.asarray(cs).reshape(50, 24), np.asarray(c1))
    assert np.array_equal(np.asarray(ss).reshape(50), np.asarray(s1))


def test_wire_bytes():
    g = {"a": jnp.zeros((100,)), "b": jnp.zeros((50,))}
    fp32, int8 = wire_bytes_saved(g)
    assert fp32 == 600 and int8 < fp32 / 3


def test_compressed_psum_multi_device():
    """Single-device psum (axis of size 1) must be ≈ identity."""
    from repro.distributed.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("d",))
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(size=(64,)).astype(np.float32))}

    def f(grads):
        return compressed_psum(grads, jax.random.PRNGKey(0), "d")

    from repro import compat
    out = jax.jit(compat.shard_map(
        f, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(),),
        out_specs=jax.sharding.PartitionSpec(),
        check_vma=False,
    ))(g)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    np.testing.assert_allclose(
        np.asarray(out["w"]), np.asarray(g["w"]), atol=1.01 * scale
    )
