"""Sharding-rule divisibility for every assigned arch on both meshes —
pure shape math (eval_shape), no 512-device init needed."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry as reg
from repro.launch import sharding as shr

MESH_SHAPES = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}

LM_ARCHS = [
    "phi3.5-moe-42b-a6.6b", "llama4-scout-17b-a16e", "qwen3-1.7b",
    "mistral-nemo-12b", "gemma2-27b",
]


def _check_divisible(sds_tree, spec_tree, axes: dict, where: str):
    leaves = jax.tree.leaves(sds_tree)
    specs = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: x is None or
        type(x).__name__ == "PartitionSpec")
    assert len(leaves) == len(specs)
    for leaf, spec in zip(leaves, specs):
        if spec is None:
            continue
        for dim, part in enumerate(tuple(spec)):
            if part is None:
                continue
            names = (part,) if isinstance(part, str) else tuple(part)
            k = int(np.prod([axes[n] for n in names]))
            assert leaf.shape[dim] % k == 0, (
                f"{where}: dim {dim} of {leaf.shape} not divisible by {k} "
                f"({names})"
            )


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_lm_param_specs_divisible(arch, mesh_kind):
    from repro.models import transformer as tfm
    spec = reg.get_arch(arch)
    cfg = spec.config_for_shape("train_4k")
    params = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    p_spec = shr.lm_param_specs(params)
    _check_divisible(params, p_spec, MESH_SHAPES[mesh_kind], arch)


@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_dlrm_table_specs_divisible(mesh_kind):
    from repro.models import dlrm as dlrm_mod
    spec = reg.get_arch("dlrm-rm2")
    cfg = spec.config_for_shape("train_batch")
    params = jax.eval_shape(
        lambda: dlrm_mod.init_params(jax.random.PRNGKey(0), cfg))
    p_spec = shr.dlrm_param_specs(params)
    _check_divisible(params, p_spec, MESH_SHAPES[mesh_kind], "dlrm")


def test_lm_batch_shapes_divisible():
    """Every LM shape cell's batch dims divide the mesh batch axes."""
    for arch in LM_ARCHS:
        spec = reg.get_arch(arch)
        for name, cell in spec.shapes.items():
            if cell.skip:
                continue
            B = cell.sizes["batch"]
            assert B == 1 or B % 16 == 0, (arch, name, B)


def test_gnn_padded_dims_divisible():
    from repro.configs.gnn_common import GNN_SIZES, graph_specs
    for shape, sizes in GNN_SIZES.items():
        g = graph_specs(sizes)
        assert g.x.shape[0] % 512 == 0, shape
        assert g.senders.shape[0] % 512 == 0, shape
