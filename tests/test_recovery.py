"""Durability suite (DESIGN.md §11): journal, crash matrix, fault harness.

Three layers:

  · **journal unit tests** — record framing round-trips, torn/corrupt tails
    are dropped (prefix semantics), truncate/reset behave, fsync policies
    are accepted;
  · **the crash-point matrix** — ONE deterministic mixed stream (inserts,
    deletes, queries, periodic flushes and checkpoint saves, auto-
    consolidation and auto-growth armed) is first run uninterrupted to (a)
    produce the control state and (b) count how often each registered crash
    point fires. Then, for every session-tier crash point, the stream is
    killed at that point's *middle* occurrence, recovered via
    ``Session.recover``, resumed from the recovered op counter, and the
    final state must be **bit-identical** to the control — arrays, op
    counters, capacity tier, and a probe query;
  · **harness/degradation details** — transient-flush retry with bounded
    backoff, explicit consolidate/grow journaling, NaN/Inf dispatch
    rejection, recovery with no checkpoint, fingerprint guards.

The matrix stream is a pure function of the op index (vectors, delete
targets and query payloads are all seeded per-``t``), so the resumed run
regenerates the exact suffix the crashed run never acknowledged — no
result-dependent state crosses the kill.
"""
import numpy as np
import pytest

from repro.checkpoint import journal as journal_mod
from repro.checkpoint.manager import CheckpointCorruptError
from repro.core import (
    IndexParams,
    MaintenanceParams,
    SearchParams,
    Session,
)
from repro.core import ops as ops_mod
from repro.core.graph import NULL
from repro.testing import faults

CAP = 96
DIM = 8
CHUNK = 16


def _params(**maintenance_kw):
    # every session-tier maintenance op is armed so the matrix stream
    # reaches every registered crash point: auto-consolidate, auto-grow,
    # and auto-refine (refine-begin/refine-step joined the registry with
    # OP_REFINE — the stream accrues ~18 update rows per schedule cycle,
    # so threshold 30 fires passes at several flush boundaries)
    mkw = dict(strategy="mask", insert_chunk=CHUNK, delete_chunk=CHUNK,
               consolidate_threshold=0.3, max_capacity=4 * CAP,
               growth_factor=2.0, refine_threshold=30, refine_chunk=8)
    mkw.update(maintenance_kw)
    return IndexParams(
        capacity=CAP, dim=DIM, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(**mkw),
    )


# ---------------------------------------------------------------------------
# journal unit tests
# ---------------------------------------------------------------------------

def test_journal_roundtrip(tmp_path):
    path = tmp_path / "j.bin"
    j = journal_mod.OpJournal(path, fsync="always")
    pay = np.arange(12, dtype=np.float32).reshape(3, 4)
    ids = np.asarray([7, 9], np.int32)
    j.append(ops_mod.OP_INSERT, seq=0, payload=pay, aux={"chunk": 8})
    j.append(ops_mod.OP_DELETE, seq=1, cseq=2, ids=ids, aux={"chunk": 4})
    j.append(ops_mod.JR_FLUSH, seq=2)
    j.close()

    recs, valid, dropped = journal_mod.scan_file(path)
    assert dropped == 0 and valid == path.stat().st_size
    assert [r.code for r in recs] == [
        ops_mod.OP_INSERT, ops_mod.OP_DELETE, ops_mod.JR_FLUSH]
    np.testing.assert_array_equal(recs[0].payload, pay)
    assert recs[0].aux == {"chunk": 8} and recs[0].seq == 0
    np.testing.assert_array_equal(recs[1].ids, ids)
    assert recs[1].cseq == 2
    assert recs[2].payload is None and recs[2].ids is None


def test_journal_torn_tail_dropped(tmp_path):
    path = tmp_path / "j.bin"
    j = journal_mod.OpJournal(path, fsync="never")
    for s in range(5):
        j.append(ops_mod.OP_QUERY, seq=s, aux={"n": 3})
    j.sync()
    whole = path.stat().st_size
    j.close()
    # tear the final record mid-body (a kill during append)
    with open(path, "r+b") as f:
        f.truncate(whole - 5)
    recs, valid, dropped = journal_mod.scan_file(path)
    assert [r.seq for r in recs] == [0, 1, 2, 3]
    assert dropped > 0
    # repair() physically drops the tail so appends extend a clean prefix
    j2 = journal_mod.OpJournal(path)
    recs2, dropped2 = j2.repair()
    assert dropped2 == dropped and len(recs2) == 4
    assert path.stat().st_size == valid
    j2.append(ops_mod.OP_QUERY, seq=4, aux={"n": 1})
    j2.sync()
    recs3, _, d3 = journal_mod.scan_file(path)
    assert d3 == 0 and [r.seq for r in recs3] == [0, 1, 2, 3, 4]


def test_journal_corrupt_record_ends_prefix(tmp_path):
    path = tmp_path / "j.bin"
    j = journal_mod.OpJournal(path, fsync="never")
    offsets = [0]
    for s in range(4):
        j.append(ops_mod.OP_QUERY, seq=s, aux={"n": 1})
        j.sync()
        offsets.append(path.stat().st_size)
    j.close()
    # flip one byte inside record 2's body: CRC must end the prefix there,
    # dropping record 3 as well (framing after rot is untrusted)
    data = bytearray(path.read_bytes())
    data[offsets[2] + 14] ^= 0xFF
    path.write_bytes(bytes(data))
    recs, valid, dropped = journal_mod.scan_file(path)
    assert [r.seq for r in recs] == [0, 1]
    assert valid == offsets[2] and dropped == len(data) - offsets[2]


def test_journal_truncate_and_policies(tmp_path):
    with pytest.raises(ValueError):
        journal_mod.OpJournal(tmp_path / "x.bin", fsync="sometimes")
    j = journal_mod.OpJournal(tmp_path / "j.bin", fsync="flush")
    j.append(ops_mod.OP_QUERY, seq=0, aux={"n": 1})
    j.truncate()
    assert (tmp_path / "j.bin").stat().st_size == 0
    j.reset(meta={"fingerprint": "fp"})
    recs, _, _ = journal_mod.scan_file(tmp_path / "j.bin")
    assert [r.code for r in recs] == [ops_mod.JR_META]
    assert recs[0].aux == {"fingerprint": "fp"}


def test_scan_missing_file_is_empty(tmp_path):
    recs, valid, dropped = journal_mod.scan_file(tmp_path / "nope.bin")
    assert recs == [] and valid == 0 and dropped == 0


# ---------------------------------------------------------------------------
# the deterministic matrix stream — a pure function of the op index
# ---------------------------------------------------------------------------

N_OPS = 60
FLUSH_EVERY = 7
SAVE_EVERY = 20
SCHEDULE = "iidiq"  # per-op kind, cycled


def _vec(t):
    return np.random.default_rng(1000 + t).normal(size=(5, DIM)).astype(
        np.float32)


def _del_ids(t):
    return np.random.default_rng(2000 + t).integers(
        0, CAP, size=3).astype(np.int32)


def _probe_q(seed=5):
    return np.random.default_rng(seed).normal(size=(4, DIM)).astype(
        np.float32)


def _events(sess, t):
    """Flush/save events attached to op ``t`` (run after it)."""
    if (t + 1) % FLUSH_EVERY == 0:
        sess.flush()
    if (t + 1) % SAVE_EVERY == 0:
        sess.save(t + 1)


def _run_stream(sess, start=0):
    """Drive ops ``start..N_OPS-1``; on resume, first re-run the *events*
    of op ``start-1`` — a kill inside them may have lost the flush/save
    (both are idempotent when replayed against the recovered state)."""
    if start > 0:
        _events(sess, start - 1)
    for t in range(start, N_OPS):
        kind = SCHEDULE[t % len(SCHEDULE)]
        if kind == "i":
            sess.insert(_vec(t))
        elif kind == "d":
            sess.delete(_del_ids(t))
        else:
            sess.query(_vec(t)[:2])
        _events(sess, t)
    sess.flush()
    return sess


def _state_summary(sess, probe=True):
    """Snapshot for bit-exactness asserts.

    ``probe=False`` for want/got pairs that straddle a recovery: a probe
    query on a journaled session is itself journaled (it advances the op
    key chain), so issuing one on the *want* side would shift every later
    key on the recovered side. The matrix tests keep the probe — both
    sides run it at the same op counter, so it compares like-for-like.
    """
    st = sess.state
    out = {
        "arrays": {f: np.asarray(getattr(st, f)) for f in
                   ("adj", "vectors", "codes", "scales",
                    "alive", "present", "masked")},
        "capacity": st.capacity,
        "op_counter": sess._op_counter,
        "consolidate_counter": sess._consolidate_counter,
        "refine_counter": sess._refine_counter,
        "refine_wear": sess._refine_wear,
    }
    if probe:
        ids, scores = sess.query(_probe_q(), k=10).result()
        out["probe"] = (np.asarray(ids), np.asarray(scores))
    return out


def _assert_bit_identical(a, b, label):
    assert a["capacity"] == b["capacity"], label
    assert a["op_counter"] == b["op_counter"], label
    assert a["consolidate_counter"] == b["consolidate_counter"], label
    assert a["refine_counter"] == b["refine_counter"], label
    assert a["refine_wear"] == b["refine_wear"], label
    for f, arr in a["arrays"].items():
        np.testing.assert_array_equal(
            arr, b["arrays"][f], err_msg=f"{label}: state.{f} diverged")
    if "probe" in a and "probe" in b:
        np.testing.assert_array_equal(a["probe"][0], b["probe"][0],
                                      err_msg=f"{label}: probe ids")
        np.testing.assert_array_equal(a["probe"][1], b["probe"][1],
                                      err_msg=f"{label}: probe scores")


@pytest.fixture(scope="module")
def control(tmp_path_factory):
    """Uninterrupted run: (final summary, per-crash-point hit counts)."""
    d = tmp_path_factory.mktemp("ctrl")
    probe_plan = faults.FaultPlan()  # crashes nothing, counts everything
    with faults.inject(probe_plan):
        sess = _run_stream(Session(_params(), seed=3, checkpoint_dir=d))
    return _state_summary(sess), dict(probe_plan.hits)


def test_stream_covers_every_session_crash_point(control):
    """The matrix is only meaningful if the stream actually reaches every
    registered kill site — growth, consolidation, saves and flushes all
    have to fire."""
    _, hits = control
    missing = [p for p in faults.SESSION_CRASH_POINTS if not hits.get(p)]
    assert not missing, f"stream never reached crash points: {missing}"


@pytest.mark.parametrize("point", faults.SESSION_CRASH_POINTS)
def test_kill_and_recover_bit_exact(point, control, tmp_path):
    """Acceptance: kill at the middle occurrence of every registered crash
    point, recover, resume — final state bit-identical to the control."""
    ctrl_summary, hits = control
    hit = (hits[point] + 1) // 2
    plan = faults.crash_once(point, hit=hit)
    sess = Session(_params(), seed=3, checkpoint_dir=tmp_path)
    with faults.inject(plan):
        with pytest.raises(faults.SimulatedCrash):
            _run_stream(sess)
    assert plan.log, "the armed crash never fired"
    del sess  # device state dies with the process; disk is all that's left

    rec = Session.recover(tmp_path, _params(), seed=3)
    assert rec.recovery_info is not None and not rec.recovering
    start = rec._op_counter
    assert 0 <= start <= N_OPS
    _run_stream(rec, start=start)
    _assert_bit_identical(_state_summary(rec), ctrl_summary,
                          f"crash at {point}#{hit}")


def test_double_crash_recover(control, tmp_path):
    """A second kill before the next checkpoint recovers from the SAME disk
    state: replayed records stay in the journal until a save truncates."""
    ctrl_summary, hits = control
    plan = faults.crash_once("post-journal-append",
                             hit=(hits["post-journal-append"] + 1) // 2)
    sess = Session(_params(), seed=3, checkpoint_dir=tmp_path)
    with faults.inject(plan):
        with pytest.raises(faults.SimulatedCrash):
            _run_stream(sess)
    rec1 = Session.recover(tmp_path, _params(), seed=3)
    start1 = rec1._op_counter
    # run a handful of ops, then "crash" again (just drop the session)
    plan2 = faults.crash_once("post-journal-append", hit=4)
    with faults.inject(plan2):
        with pytest.raises(faults.SimulatedCrash):
            _run_stream(rec1, start=start1)
    del rec1
    rec2 = Session.recover(tmp_path, _params(), seed=3)
    _run_stream(rec2, start=rec2._op_counter)
    _assert_bit_identical(_state_summary(rec2), ctrl_summary, "double crash")


# ---------------------------------------------------------------------------
# harness + degradation details
# ---------------------------------------------------------------------------

def test_explicit_consolidate_and_grow_are_journaled(tmp_path):
    """Explicit maintenance is part of the timeline: a crash right after an
    explicit consolidate()/grow() must replay both."""
    p = _params(consolidate_threshold=None)  # no auto passes
    sess = Session(p, seed=1, checkpoint_dir=tmp_path)
    ids = sess.insert(_vec(0)).result()
    sess.delete(ids[:3])
    sess.consolidate()
    sess.grow(2 * CAP)
    sess.insert(_vec(1))
    sess.flush()
    want = _state_summary(sess, probe=False)
    del sess

    rec = Session.recover(tmp_path, p, seed=1)
    info = rec.recovery_info
    assert info["step"] is None and info["n_replayed"] >= 5
    _assert_bit_identical(_state_summary(rec, probe=False), want,
                          "explicit maintenance")


def test_explicit_refine_is_journaled(tmp_path):
    """Explicit refine() journals JR_REFINE with its n/chunk aux, and a
    crash afterwards replays the pass — the rewired edges and the refine
    key-chain counter are bit-identical to the original timeline."""
    p = _params(consolidate_threshold=None, refine_threshold=None)
    sess = Session(p, seed=1, checkpoint_dir=tmp_path)
    sess.insert(_vec(0))
    sess.insert(_vec(1))
    sess.delete(sess.insert(_vec(2)).result()[:3])
    n = sess.refine(n=10, chunk=4)
    assert n == 10
    sess.insert(_vec(3))
    sess.flush()
    want = _state_summary(sess, probe=False)
    assert want["refine_counter"] == 3  # ceil(10/4) key draws
    del sess

    rec = Session.recover(tmp_path, p, seed=1)
    assert rec.recovery_info["step"] is None
    _assert_bit_identical(_state_summary(rec, probe=False), want,
                          "explicit refine replay")


def test_pre_refactor_journal_replays_through_registry(tmp_path):
    """Back-compat acceptance: a journal written with the *pre-registry*
    literal record codes (JR_META=16, JR_FLUSH=17, JR_CONSOLIDATE=18,
    JR_GROW=19) and the legacy cseq discipline must replay bit-exactly
    through the registry dispatch path."""
    from repro.core.session import params_fingerprint

    p = _params(consolidate_threshold=None, refine_threshold=None)

    # the control timeline, executed live (its own journal discarded)
    sess = Session(p, seed=9)
    sess.insert(_vec(30))
    sess.delete(np.asarray([0, 2, 4], np.int32))
    sess.flush()
    sess.consolidate()
    sess.grow(2 * CAP)
    sess.insert(_vec(31))
    sess.flush()
    want = _state_summary(sess, probe=False)
    del sess

    # the same timeline as raw journal bytes, appended exactly as the
    # pre-refactor writer did: literal numeric codes, seq = op counter,
    # cseq = consolidate counter at append time
    j = journal_mod.OpJournal(tmp_path / "journal.bin", fsync="always")
    fp = params_fingerprint(p, p.maintenance.strategy)
    j.append(16, seq=0, cseq=0, aux={"fingerprint": fp})           # META
    j.append(ops_mod.OP_INSERT, seq=0, cseq=0, payload=_vec(30),
             aux={"chunk": None})
    j.append(ops_mod.OP_DELETE, seq=1, cseq=0,
             ids=np.asarray([0, 2, 4], np.int32), aux={"chunk": None})
    j.append(17, seq=2, cseq=0)                                    # FLUSH
    j.append(18, seq=2, cseq=0, aux={"strategy": None, "chunk": None})
    j.append(19, seq=2, cseq=1, aux={"new_capacity": 2 * CAP})     # GROW
    j.append(ops_mod.OP_INSERT, seq=2, cseq=1, payload=_vec(31),
             aux={"chunk": None})
    j.append(17, seq=3, cseq=1)                                    # FLUSH
    j.close()

    rec = Session.recover(tmp_path, p, seed=9)
    assert rec.recovery_info["step"] is None
    assert rec.recovery_info["n_replayed"] == 7
    _assert_bit_identical(_state_summary(rec, probe=False), want,
                          "pre-refactor journal")


def test_recover_without_checkpoint_replays_from_empty(tmp_path):
    sess = Session(_params(), seed=2, checkpoint_dir=tmp_path)
    sess.insert(_vec(3))
    sess.query(_vec(4)[:2])
    sess.flush()
    want = _state_summary(sess, probe=False)
    del sess
    rec = Session.recover(tmp_path, _params(), seed=2)
    assert rec.recovery_info["step"] is None
    _assert_bit_identical(_state_summary(rec, probe=False), want,
                          "no-checkpoint recover")


def test_recover_falls_back_past_corrupt_checkpoint(tmp_path):
    """A garbled newest checkpoint degrades recovery (older step + longer
    replay), it does not end it."""
    sess = Session(_params(), seed=4, checkpoint_dir=tmp_path)
    sess.insert(_vec(10))
    sess.save(1)
    sess.insert(_vec(11))
    sess.save(2)
    sess.insert(_vec(12))
    sess.flush()
    del sess
    # rot the newest step's shard: CRC validation must reject it
    shard = tmp_path / "step_000000000002" / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:100])
    rec = Session.recover(tmp_path, _params(), seed=4)
    assert rec.recovery_info["step"] == 1
    # the journal was truncated at save(2), so the ops between save(1) and
    # save(2) are genuinely lost with the corrupt step — the recovered
    # timeline is the save(1) prefix, and the journaled post-save(2)
    # suffix (insert seq=2, flush seq=3) is a dead timeline: counted as
    # unreplayable, not applied. What must still hold: recovery succeeds,
    # the loss is surfaced, and the session accepts new ops.
    assert rec._op_counter == 1  # one insert before save(1)
    assert rec.recovery_info["n_unreplayable"] == 2
    rec.insert(_vec(13))
    rec.flush()
    # the gapped suffix was discarded for a fresh timeline: a second
    # recovery must replay cleanly, not trip over stale records
    del rec
    rec2 = Session.recover(tmp_path, _params(), seed=4)
    assert rec2.recovery_info["n_unreplayable"] == 0
    assert rec2._op_counter == 2


def test_journal_fingerprint_guard(tmp_path):
    sess = Session(_params(), seed=0, checkpoint_dir=tmp_path)
    sess.insert(_vec(0))
    sess.flush()
    del sess
    other = _params(consolidate_threshold=0.5)
    with pytest.raises(ValueError, match="fingerprint"):
        Session.recover(tmp_path, other, seed=0)


def test_transient_flush_failures_retry_with_backoff(tmp_path):
    sess = Session(_params(), seed=0, flush_retries=3,
                   flush_backoff_s=1e-4)
    sess.insert(_vec(0))
    with faults.inject(faults.transient("flush", count=2)):
        sess.flush()
    assert sess.timers.n_retries == 2
    # exhaustion re-raises: more consecutive failures than retries
    sess.insert(_vec(1))
    with faults.inject(faults.transient("flush", count=10)):
        with pytest.raises(faults.TransientDispatchError):
            sess.flush()


def test_crash_point_registry_is_closed():
    with pytest.raises(ValueError):
        faults.crash_point("not-a-registered-point")
    with pytest.raises(ValueError):
        faults.crash_once("also-not-registered")
    # plans don't nest
    with faults.inject(faults.FaultPlan()):
        with pytest.raises(RuntimeError):
            with faults.inject(faults.FaultPlan()):
                pass


def test_random_plan_is_seed_deterministic():
    a = faults.random_plan(123)
    b = faults.random_plan(123)
    c = faults.random_plan(124)
    assert a.crashes == b.crashes
    assert (a.crashes != c.crashes) or True  # different seed may collide
    (pt, hit), = a.crashes.items()
    assert pt in faults.SESSION_CRASH_POINTS and hit >= 1


def test_nonfinite_insert_rows_rejected_and_counted():
    sess = Session(_params(), seed=0)
    v = _vec(20)
    v[1, 3] = np.nan
    v[3, 0] = np.inf
    ids = sess.insert(v).result()
    assert ids.shape == (5,)
    assert ids[1] == NULL and ids[3] == NULL
    assert (ids[[0, 2, 4]] >= 0).all()
    assert sess.timers.n_rejected == 2
    assert sess.timers.n_inserts == 3
    # an all-rejected batch still consumes exactly one op key
    before = sess._op_counter
    ids2 = sess.insert(np.full((2, DIM), np.nan, np.float32)).result()
    assert (ids2 == NULL).all() and sess.timers.n_rejected == 4
    assert sess._op_counter == before + 1
    sess.flush()


def test_rejection_replays_identically(tmp_path):
    """NaN rows are journaled raw and re-rejected on replay — the recovered
    key chain and state match the original."""
    sess = Session(_params(), seed=6, checkpoint_dir=tmp_path)
    v = _vec(21)
    v[0, 0] = np.nan
    sess.insert(v)
    sess.insert(_vec(22))
    sess.flush()
    want = _state_summary(sess, probe=False)
    del sess
    rec = Session.recover(tmp_path, _params(), seed=6)
    assert rec.timers.n_rejected == 1
    _assert_bit_identical(_state_summary(rec, probe=False), want,
                          "rejection replay")


# ---------------------------------------------------------------------------
# the tiered crash matrix (DESIGN.md §12): kill mid-merge, recover bit-exact
# ---------------------------------------------------------------------------
#
# Same recipe as the session matrix, over a TieredSession with both
# auto-merge trigger arms live: the deterministic stream fills the fresh
# tier (fresh-fraction arm) and tombstones main-resident points (tombstone
# arm), so merges start, compact, drain and swap *interleaved with the op
# stream* via the one-pump-per-op rule. Merge progress is a pure function
# of the acknowledged op stream, so recovery must land bit-exactly even
# when the kill happens in the middle of a drain — both tiers' arrays, the
# ext→location table and every counter are compared. Explicit merges are
# deliberately absent from the matrix stream (they are journaled as
# JR_MERGE and replayed; re-running one on resume would double it) — the
# dedicated test below covers JR_MERGE replay.

from repro.core import TieredSession  # noqa: E402

T_N_OPS = 48
T_SCHEDULE = "iidiqdiq"   # per-op kind, cycled — half inserts
T_FLUSH_EVERY = 7
T_SAVE_EVERY = 19
T_FRESH = 32


def _t_params():
    return _params(merge_fresh_threshold=0.5,
                   merge_tombstone_threshold=0.25,
                   merge_chunk=8)


def _t_n_ext(t):
    """External ids assigned before op ``t`` (pure function of the index)."""
    return 5 * sum(1 for s in range(t)
                   if T_SCHEDULE[s % len(T_SCHEDULE)] == "i")


def _t_del(t):
    hi = max(_t_n_ext(t), 1)
    return np.random.default_rng(3000 + t).integers(
        0, hi, size=3).astype(np.int32)


def _t_events(ts, t):
    if (t + 1) % T_FLUSH_EVERY == 0:
        ts.flush()
    if (t + 1) % T_SAVE_EVERY == 0:
        ts.save(t + 1)


def _run_tiered_stream(ts, start=0):
    if start > 0:
        _t_events(ts, start - 1)
    for t in range(start, T_N_OPS):
        kind = T_SCHEDULE[t % len(T_SCHEDULE)]
        if kind == "i":
            ts.insert(_vec(t))
        elif kind == "d":
            ts.delete(_t_del(t))
        else:
            ts.query(_vec(t)[:2], k=8)
        _t_events(ts, t)
    ts.flush()
    return ts


_T_FIELDS = ("adj", "vectors", "codes", "scales",
             "alive", "present", "masked", "stamps")


def _tiered_summary(ts, probe=True):
    out = {"tiers": {}}
    for name, sess in (("fresh", ts._fresh), ("main", ts._main)):
        st = sess.state
        out["tiers"][name] = (
            {f: np.asarray(getattr(st, f)) for f in _T_FIELDS},
            st.capacity, sess._op_counter)
    out["loc"] = dict(ts._loc)
    out["counters"] = (ts._op_counter, ts._merge_counter,
                       ts._merges_done, ts._next_ext)
    out["ext"] = (ts._fm.ext.copy(), ts._mm.ext.copy())
    if probe:
        ids, sc = ts.query(_probe_q(), k=10).result()
        out["probe"] = (np.asarray(ids), np.asarray(sc))
    return out


def _assert_tiered_identical(a, b, label):
    assert a["counters"] == b["counters"], label
    assert a["loc"] == b["loc"], label
    for side in ("tiers",):
        for name in ("fresh", "main"):
            arrs_a, cap_a, opc_a = a[side][name]
            arrs_b, cap_b, opc_b = b[side][name]
            assert cap_a == cap_b, f"{label}: {name} capacity"
            assert opc_a == opc_b, f"{label}: {name} op counter"
            for f, arr in arrs_a.items():
                np.testing.assert_array_equal(
                    arr, arrs_b[f], err_msg=f"{label}: {name}.{f} diverged")
    for got, want in zip(a["ext"], b["ext"]):
        np.testing.assert_array_equal(got, want, err_msg=f"{label}: ext map")
    if "probe" in a and "probe" in b:
        np.testing.assert_array_equal(a["probe"][0], b["probe"][0],
                                      err_msg=f"{label}: probe ids")
        np.testing.assert_array_equal(a["probe"][1], b["probe"][1],
                                      err_msg=f"{label}: probe scores")


@pytest.fixture(scope="module")
def tiered_control(tmp_path_factory):
    d = tmp_path_factory.mktemp("tctrl")
    probe_plan = faults.FaultPlan()
    with faults.inject(probe_plan):
        ts = _run_tiered_stream(TieredSession(
            _t_params(), fresh_capacity=T_FRESH, seed=3, checkpoint_dir=d))
    return _tiered_summary(ts), dict(probe_plan.hits)


def test_tiered_stream_covers_every_merge_crash_point(tiered_control):
    _, hits = tiered_control
    missing = [p for p in faults.TIERED_CRASH_POINTS if not hits.get(p)]
    assert not missing, f"stream never reached crash points: {missing}"


@pytest.mark.parametrize(
    "point",
    list(faults.TIERED_CRASH_POINTS)
    + ["post-journal-append", "post-checkpoint-save"],
)
def test_tiered_kill_and_recover_bit_exact(point, tiered_control, tmp_path):
    """Acceptance (§12): kill at the middle occurrence of every merge-phase
    crash point (plus the durability points the tiered layer fires),
    recover, resume — both tiers bit-identical to the control."""
    ctrl_summary, hits = tiered_control
    hit = (hits[point] + 1) // 2
    plan = faults.crash_once(point, hit=hit)
    ts = TieredSession(_t_params(), fresh_capacity=T_FRESH, seed=3,
                       checkpoint_dir=tmp_path)
    with faults.inject(plan):
        with pytest.raises(faults.SimulatedCrash):
            _run_tiered_stream(ts)
    assert plan.log, "the armed crash never fired"
    del ts

    rec = TieredSession.recover(tmp_path, _t_params(),
                                fresh_capacity=T_FRESH, seed=3)
    assert rec.recovery_info is not None and not rec.recovering
    start = rec._op_counter
    assert 0 <= start <= T_N_OPS
    _run_tiered_stream(rec, start=start)
    _assert_tiered_identical(_tiered_summary(rec), ctrl_summary,
                             f"tiered crash at {point}#{hit}")


def test_tiered_explicit_merge_is_journaled(tmp_path):
    """An explicit ``merge()`` is part of the timeline (JR_MERGE): a crash
    after it must replay the merge, landing on the same post-drain state."""
    p = _t_params()
    ts = TieredSession(p, fresh_capacity=T_FRESH, seed=7,
                       checkpoint_dir=tmp_path)
    ids = ts.insert(_vec(0)).result()
    ts.insert(_vec(1))
    ts.merge()                       # drain everything to main
    ts.delete(ids[:2])               # tombstones the merged copies
    ts.merge()                       # compacts them
    ts.insert(_vec(2))
    ts.flush()
    want = _tiered_summary(ts, probe=False)
    del ts

    rec = TieredSession.recover(tmp_path, p, fresh_capacity=T_FRESH, seed=7)
    assert rec.recovery_info["step"] is None
    assert rec.recovery_info["n_replayed"] >= 6
    _assert_tiered_identical(_tiered_summary(rec, probe=False), want,
                             "explicit merge replay")


def test_tiered_fingerprint_guard(tmp_path):
    ts = TieredSession(_t_params(), fresh_capacity=T_FRESH, seed=0,
                       checkpoint_dir=tmp_path)
    ts.insert(_vec(0))
    ts.flush()
    del ts
    with pytest.raises(ValueError, match="fingerprint"):
        TieredSession.recover(tmp_path, _t_params(),
                              fresh_capacity=2 * T_FRESH, seed=0)
