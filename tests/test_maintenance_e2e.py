"""End-to-end GRAPH-MAINTENANCE runs — the paper's workload at test scale."""
import numpy as np
import pytest

from helpers import check_invariants
from repro.core import IPGMIndex, IndexParams, SearchParams, run_workload
from repro.data.workload import make_workload


def _params(dim, cap):
    return IndexParams(
        capacity=cap, dim=dim, d_out=8,
        search=SearchParams(pool_size=24, max_steps=64, num_starts=2),
    )


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["pure", "mask", "local", "global"])
def test_workload_two_steps(strategy):
    wl = make_workload("sift", n_base=250, n_steps=2, batch_size=40,
                       n_queries=40, pattern="random", dim=16)
    idx = IPGMIndex(_params(16, 450), strategy=strategy, delete_chunk=32)
    ids = idx.insert(wl.base)
    id_map = list(np.asarray(ids))

    # drive step by step — pool positions resolve to ids as inserts land
    recalls = []
    for i in range(2):
        idx.delete(np.asarray([id_map[p] for p in wl.step_deletes[i]]))
        new = idx.insert(wl.step_inserts[i])
        id_map.extend(np.asarray(new))
        recalls.append(idx.recall(wl.queries, k=10))
    assert all(r > 0.5 for r in recalls), (strategy, recalls)
    if strategy != "mask":
        assert not check_invariants(idx.state)
    assert idx.stats()["n_alive"] == 250


def test_run_workload_driver():
    rng = np.random.default_rng(0)
    idx = IPGMIndex(_params(8, 120), strategy="global", delete_chunk=16)
    X = rng.normal(size=(80, 8)).astype(np.float32)
    idx.insert(X)
    recs = run_workload(idx, [
        ("delete", np.arange(10)),
        ("insert", rng.normal(size=(10, 8)).astype(np.float32)),
        ("query", rng.normal(size=(20, 8)).astype(np.float32)),
    ], k=5)
    assert [r["op"] for r in recs] == ["delete", "insert", "query"]
    assert recs[-1]["recall"] > 0.5
    assert idx.timers.n_deletes == 10
    assert idx.timers.n_inserts == 90  # 80 base + 10 streamed


@pytest.mark.slow
def test_rebuild_matches_incremental_quality():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(300, 16)).astype(np.float32)
    Q = rng.normal(size=(48, 16)).astype(np.float32)
    idx = IPGMIndex(_params(16, 400), strategy="global")
    idx.insert(X)
    r_inc = idx.recall(Q, k=10)
    idx.rebuild_from_alive()
    r_reb = idx.recall(Q, k=10)
    assert not check_invariants(idx.state)
    assert r_reb > r_inc - 0.1, (r_inc, r_reb)
