"""Batched beam engine vs the per-query reference path (parity suite).

Pins the tentpole contract: ``beam_width=1`` reproduces the pre-refactor
engine exactly (ids, scores, hop counts — filtered and raw, all three
metrics, with MASK tombstones in the graph), wider beams stay recall-equal
or better at scale, and the Pallas gather path scores identically to the
jnp path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IPGMIndex, IndexParams, SearchParams, metrics
from repro.core import search as search_mod
from repro.core.graph import NULL

METRICS = ["l2", "ip", "cos"]


def _index(metric, n=260, dim=12, d_out=6, pool=16, capacity=320, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, dim)).astype(np.float32)
    if metric == "ip":
        X *= rng.uniform(0.5, 2.0, size=(n, 1)).astype(np.float32)  # hubs
    p = IndexParams(
        capacity=capacity, dim=dim, d_out=d_out, metric=metric,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2),
    )
    idx = IPGMIndex(p, strategy="mask", seed=seed)
    idx.insert(X)
    return idx, rng


def _assert_result_parity(got, want):
    assert (np.asarray(got.ids) == np.asarray(want.ids)).all()
    np.testing.assert_allclose(
        np.asarray(got.scores), np.asarray(want.scores),
        rtol=1e-6, atol=1e-6,
    )
    assert (np.asarray(got.n_expanded) == np.asarray(want.n_expanded)).all()


@pytest.mark.parametrize("metric", METRICS)
def test_beam1_matches_reference(metric):
    idx, rng = _index(metric)
    Q = jnp.asarray(rng.normal(size=(24, 12)).astype(np.float32))
    key = jax.random.PRNGKey(42)
    sp = idx.params.search
    _assert_result_parity(
        search_mod.search_batch(idx.state, Q, key, sp),
        search_mod.search_batch_reference(idx.state, Q, key, sp),
    )


@pytest.mark.parametrize("metric", METRICS)
def test_beam1_matches_reference_with_mask_tombstones(metric):
    idx, rng = _index(metric)
    idx.delete(np.arange(60))  # MASK: traversable, not reportable
    assert int(np.asarray(idx.state.masked).sum()) == 60
    Q = jnp.asarray(rng.normal(size=(24, 12)).astype(np.float32))
    key = jax.random.PRNGKey(7)
    sp = idx.params.search
    filt = search_mod.search_batch(idx.state, Q, key, sp)
    _assert_result_parity(
        filt, search_mod.search_batch_reference(idx.state, Q, key, sp)
    )
    # raw traversal pools (insert/repair internals) must agree too
    _assert_result_parity(
        search_mod.search_batch_raw(idx.state, Q, key, sp),
        search_mod.search_batch_reference_raw(idx.state, Q, key, sp),
    )
    # and tombstones never leak into filtered results
    ids = np.asarray(filt.ids)
    assert not np.isin(ids[ids != NULL], np.arange(60)).any()


def test_search_one_matches_batched_row():
    idx, rng = _index("l2")
    q = jnp.asarray(rng.normal(size=(12,)).astype(np.float32))
    starts = jnp.asarray([3, 17], jnp.int32)
    sp = idx.params.search
    one = search_mod.search_one(idx.state, q, starts, sp)
    batched = search_mod.beam_search(idx.state, q[None], starts[None], sp)
    assert (np.asarray(one.ids) == np.asarray(batched.ids[0])).all()
    assert int(one.n_expanded) == int(batched.n_expanded[0])


@pytest.mark.parametrize("metric", METRICS)
def test_wider_beam_recall_equal_or_better_at_scale(metric):
    idx, rng = _index(metric, n=420, capacity=512)
    Q = rng.normal(size=(64, 12)).astype(np.float32)
    _, true_ids = idx.ground_truth(Q, 10)
    key = jax.random.PRNGKey(0)

    def recall(beam):
        sp = SearchParams(pool_size=16, max_steps=48, num_starts=2,
                          beam_width=beam)
        res = search_mod.search_batch(idx.state, jnp.asarray(Q), key, sp)
        return float(metrics.recall_at_k(res.ids[:, :10], true_ids, 10))

    r1, r4, r8 = recall(1), recall(4), recall(8)
    # wider beams explore strictly more of the frontier per step; allow a
    # small tolerance for tie-order noise near the pool boundary
    assert r4 >= r1 - 0.03, (r1, r4)
    assert r8 >= r1 - 0.03, (r1, r8)


@pytest.mark.parametrize("beam", [1, 2])
def test_pallas_gather_path_matches_jnp(beam):
    idx, rng = _index("l2", n=120, dim=8, d_out=4, pool=12, capacity=160)
    Q = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    key = jax.random.PRNGKey(3)
    mk = lambda up: SearchParams(pool_size=12, max_steps=36, num_starts=2,
                                 beam_width=beam, use_pallas=up)
    rj = search_mod.search_batch(idx.state, Q, key, mk(False))
    rp = search_mod.search_batch(idx.state, Q, key, mk(True))
    assert (np.asarray(rj.ids) == np.asarray(rp.ids)).all()
    np.testing.assert_allclose(
        np.asarray(rj.scores), np.asarray(rp.scores), rtol=1e-5, atol=1e-5
    )


def test_pallas_engine_end_to_end_insert_query_delete():
    """gather_scores runs under insert's ef-search, IPGMIndex.query, and the
    GLOBAL delete repair when SearchParams.use_pallas is set."""
    rng = np.random.default_rng(5)
    sp = SearchParams(pool_size=12, max_steps=24, num_starts=2,
                      beam_width=2, use_pallas=True)
    p = IndexParams(capacity=96, dim=8, d_out=4, search=sp, query_chunk=32)
    idx = IPGMIndex(p, strategy="global", seed=0, delete_chunk=16)
    X = rng.normal(size=(64, 8)).astype(np.float32)
    idx.insert(X)                      # ef-search through the Pallas path
    idx.delete(np.arange(8))           # GLOBAL repair through the Pallas path
    Q = rng.normal(size=(16, 8)).astype(np.float32)
    ids, scores = idx.query(Q, k=5)    # query path
    assert ids.shape == (16, 5)
    assert not np.isin(np.asarray(ids), np.arange(8)).any()
    assert idx.recall(Q, k=5) > 0.5
