"""Stream-fuzzing differential suite (DESIGN.md §8 certification).

Seeded random mixed op streams (query/insert/delete, ragged chunks,
interleaved consolidations) run through the streaming :class:`Session` and
are checked against a brute-force numpy oracle that mirrors the engine's
book-keeping exactly:

  · slot allocation — the i-th valid insert row takes the i-th lowest free
    (non-present) slot, so freed-slot reuse after consolidation is pinned
    bit-exactly through the returned insert ids;
  · alive/present flags — MASK tombstones stay present, consolidation frees
    them; engine flags must equal the oracle's after every consolidation;
  · recall@10 vs the oracle's exact ground truth over alive vectors — never
    below the pinned floor after any consolidation;
  · consolidation *timing* invariance — the same logical stream with
    compaction fired at different positions keeps the same logical alive
    set, the same recall floor, and an invariant-clean graph, because
    consolidation draws its PRNG keys from a separate chain and never
    changes which vertices are reportable.

All tests share ONE IndexParams value so the jitted switch program compiles
once for the whole module.
"""
import numpy as np
import pytest

from helpers import check_invariants
from repro.core import (
    IndexParams,
    IPGMIndex,
    MaintenanceParams,
    SearchParams,
    Session,
    run_workload,
)
from repro.core.consolidate import masked_fraction
from repro.core.graph import NULL

CAP = 160
DIM = 8
CHUNK = 16
RECALL_FLOOR = 0.8  # measured min over seeds 0–5 is 0.93; pinned with margin


def _params(**maintenance_kw):
    mkw = dict(strategy="mask", insert_chunk=CHUNK, delete_chunk=CHUNK)
    mkw.update(maintenance_kw)
    return IndexParams(
        capacity=CAP, dim=DIM, d_out=8,
        search=SearchParams(pool_size=24, max_steps=72, num_starts=2),
        maintenance=MaintenanceParams(**mkw),
    )


class Oracle:
    """Numpy mirror of the session's semantics (allocator + flags + exact
    top-k over alive vectors)."""

    def __init__(self, capacity=CAP, dim=DIM):
        self.vectors = np.zeros((capacity, dim), np.float32)
        self.alive = np.zeros(capacity, bool)
        self.present = np.zeros(capacity, bool)

    def insert(self, vecs):
        ids = []
        for v in np.asarray(vecs, np.float32):
            free = np.flatnonzero(~self.present)
            if free.size == 0:
                ids.append(NULL)
                continue
            s = int(free[0])
            self.vectors[s] = v
            self.alive[s] = self.present[s] = True
            ids.append(s)
        return np.asarray(ids, np.int32)

    def delete_mask(self, ids):
        for i in np.asarray(ids, np.int64).ravel():
            if i >= 0 and self.alive[i]:
                self.alive[i] = False  # stays present: tombstone

    def delete_hard(self, ids):
        """PURE-style delete: the slot frees immediately (no tombstone)."""
        for i in np.asarray(ids, np.int64).ravel():
            if i >= 0 and self.alive[i]:
                self.alive[i] = self.present[i] = False

    def consolidate(self):
        freed = self.present & ~self.alive
        self.present[freed] = False
        return int(freed.sum())

    def topk(self, queries, k):
        q = np.asarray(queries, np.float32)
        d2 = ((self.vectors[None] - q[:, None]) ** 2).sum(-1)
        d2[:, ~self.alive] = np.inf
        order = np.argsort(d2, axis=1)[:, :k]
        valid = np.take_along_axis(d2, order, axis=1) < np.inf
        return np.where(valid, order, NULL).astype(np.int32)

    def recall(self, found_ids, queries, k):
        true = self.topk(queries, k)
        hits = 0.0
        for f, t in zip(np.asarray(found_ids)[:, :k], true):
            tset = set(t[t != NULL].tolist())
            if not tset:
                continue
            hits += len(set(f[f != NULL].tolist()) & tset) / len(tset)
        return hits / max(len(true), 1)


class UnboundedOracle(Oracle):
    """Numpy mirror with an *unbounded* allocator (DESIGN.md §9): capacity
    is virtual — insert never refuses, the arrays double on demand. Slot
    assignment stays bit-comparable with a growing session regardless of
    when (or in what tiers) the engine grows, because allocation is
    lowest-free-first and growth only ever appends free slots."""

    def insert(self, vecs):
        ids = []
        for v in np.asarray(vecs, np.float32):
            free = np.flatnonzero(~self.present)
            if free.size == 0:
                cap = self.present.shape[0]
                self.vectors = np.concatenate(
                    [self.vectors, np.zeros_like(self.vectors)])
                self.alive = np.concatenate(
                    [self.alive, np.zeros(cap, bool)])
                self.present = np.concatenate(
                    [self.present, np.zeros(cap, bool)])
                free = np.flatnonzero(~self.present)
            s = int(free[0])
            self.vectors[s] = v
            self.alive[s] = self.present[s] = True
            ids.append(s)
        return np.asarray(ids, np.int32)


def _assert_flag_parity(sess, oracle):
    np.testing.assert_array_equal(np.asarray(sess.state.alive), oracle.alive)
    np.testing.assert_array_equal(
        np.asarray(sess.state.present), oracle.present
    )


def _assert_flag_parity_prefix(sess, oracle):
    """Flag parity when the engine tier and the oracle's doubling diverge:
    equal on the common prefix, empty beyond it on both sides."""
    for eng, orc in ((np.asarray(sess.state.alive), oracle.alive),
                     (np.asarray(sess.state.present), oracle.present)):
        n = min(eng.shape[0], orc.shape[0])
        np.testing.assert_array_equal(eng[:n], orc[:n])
        assert not eng[n:].any() and not orc[n:].any()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stream_fuzz_differential(seed):
    """Random mixed streams: engine vs oracle, interleaved consolidations."""
    rng = np.random.default_rng(seed)
    sess = Session(_params(), seed=seed)
    oracle = Oracle()
    base = rng.normal(size=(90, DIM)).astype(np.float32)
    np.testing.assert_array_equal(sess.insert(base).result(),
                                  oracle.insert(base))

    n_consolidations = 0
    for step in range(24):
        op = rng.choice(["query", "insert", "delete", "consolidate"],
                        p=[0.35, 0.25, 0.3, 0.1])
        if op == "insert":
            n = int(rng.integers(1, 20))  # ragged: pads the final micro-batch
            V = rng.normal(size=(n, DIM)).astype(np.float32)
            np.testing.assert_array_equal(
                sess.insert(V).result(), oracle.insert(V),
                err_msg="allocator parity (incl. freed-slot reuse) broke",
            )
        elif op == "delete":
            alive_ids = np.flatnonzero(oracle.alive)
            if alive_ids.size < 20:
                continue
            n = int(rng.integers(1, 13))
            victims = rng.choice(alive_ids, size=n, replace=False)
            sess.delete(victims.astype(np.int32))
            oracle.delete_mask(victims)
        elif op == "query":
            Q = rng.normal(size=(int(rng.integers(1, 10)), DIM)).astype(
                np.float32)
            ids, _ = sess.query(Q, k=10).result()
            assert oracle.recall(ids, Q, 10) >= RECALL_FLOOR, step
        else:
            assert sess.consolidate() == oracle.consolidate()
            sess.flush()
            n_consolidations += 1
            _assert_flag_parity(sess, oracle)
            errs = check_invariants(sess.state)
            assert not errs, errs[:5]

    # drain the stream: final consolidation + recall floor on a probe set
    assert sess.consolidate() == oracle.consolidate()
    sess.flush()
    _assert_flag_parity(sess, oracle)
    assert masked_fraction(sess.state) == 0.0
    errs = check_invariants(sess.state)
    assert not errs, errs[:5]
    Q = rng.normal(size=(32, DIM)).astype(np.float32)
    ids, _ = sess.query(Q, k=10).result()
    assert oracle.recall(ids, Q, 10) >= RECALL_FLOOR


def _logical_stream(seed, rounds=6):
    """Schedule-independent stream: deletes address *logical* item ranks
    (position in the sorted logical-alive set), so every run — whatever its
    physical slot assignment — performs the same logical mutation."""
    rng = np.random.default_rng(seed)
    events, alive, next_id = [], [], 0
    base = rng.normal(size=(70, DIM)).astype(np.float32)
    alive.extend(range(70))
    next_id = 70
    for _ in range(rounds):
        n_ins = int(rng.integers(4, 14))
        events.append(("insert", rng.normal(size=(n_ins, DIM)).astype(
            np.float32)))
        new = list(range(next_id, next_id + n_ins))
        alive.extend(new)
        next_id += n_ins
        n_del = int(rng.integers(3, 10))
        ranks = rng.choice(len(alive), size=n_del, replace=False)
        victims = [sorted(alive)[r] for r in sorted(ranks)]
        events.append(("delete", victims))
        for v in victims:
            alive.remove(v)
        events.append(("query", rng.normal(size=(8, DIM)).astype(np.float32)))
    return base, events


def _run_schedule(base, events, consolidate_after):
    """Run the logical stream, consolidating after the given event indices.
    Returns (per-query recalls, sorted alive vectors, session)."""
    sess = Session(_params(), seed=7)
    oracle = Oracle()
    logical_to_slot = {}
    ids = sess.insert(base).result()
    np.testing.assert_array_equal(ids, oracle.insert(base))
    for lg, s in enumerate(ids):
        logical_to_slot[lg] = int(s)
    next_logical = len(base)
    recalls = []
    for ei, (op, payload) in enumerate(events):
        if op == "insert":
            got = sess.insert(payload).result()
            np.testing.assert_array_equal(got, oracle.insert(payload))
            for v in got:
                logical_to_slot[next_logical] = int(v)
                next_logical += 1
        elif op == "delete":
            slots = np.asarray([logical_to_slot[lg] for lg in payload],
                               np.int32)
            sess.delete(slots)
            oracle.delete_mask(slots)
        else:
            found, _ = sess.query(payload, k=10).result()
            recalls.append(oracle.recall(found, payload, 10))
        if ei in consolidate_after:
            assert sess.consolidate() == oracle.consolidate()
            sess.flush()
            _assert_flag_parity(sess, oracle)
            errs = check_invariants(sess.state)
            assert not errs, errs[:5]
    sess.flush()
    alive = np.asarray(sess.state.alive)
    vecs = np.asarray(sess.state.vectors)[alive]
    order = np.lexsort(vecs.T)
    return recalls, vecs[order], sess


def test_consolidation_timing_invariance():
    """The same logical stream with compaction fired at different positions:
    identical logical alive set, recall floor everywhere, clean graph."""
    base, events = _logical_stream(seed=5)
    last = len(events) - 1
    schedules = [set(), {last // 2}, {2, last - 1}, set(range(len(events)))]
    outs = [_run_schedule(base, events, sched) for sched in schedules]
    ref_recalls, ref_vecs, _ = outs[0]
    for recalls, vecs, sess in outs:
        assert all(r >= RECALL_FLOOR for r in recalls), recalls
        np.testing.assert_array_equal(
            vecs, ref_vecs,
            err_msg="consolidation timing must not change the alive set",
        )
        errs = check_invariants(sess.state)
        assert not errs, errs[:5]
    # the never-consolidated and the always-consolidated runs bracket the
    # recall trajectory; both must clear the floor (asserted above), and
    # each query answers over the identical logical ground truth
    assert len(ref_recalls) == len(outs[-1][0])


def test_refine_timing_invariance():
    """Background refinement must be invisible to the logical stream
    (DESIGN.md §15): the same op sequence run with refinement disabled,
    auto-triggered, or fired explicitly at different positions keeps the
    identical acked insert ids, alive/present flags, size and op counter —
    refine rewires edges only, and draws its keys from the registered
    REFINE stream, never the op-key chain. Recall clears the floor and the
    graph stays invariant-clean in every schedule."""
    rng = np.random.default_rng(11)
    base = rng.normal(size=(80, DIM)).astype(np.float32)
    extra = [rng.normal(size=(10, DIM)).astype(np.float32) for _ in range(3)]
    dele = [np.arange(12 * i, 12 * i + 12, dtype=np.int32) for i in range(3)]
    Q = rng.normal(size=(24, DIM)).astype(np.float32)

    def drive(maint_kw, explicit_at=()):
        sess = Session(_params(**maint_kw), seed=4)
        acked = [np.asarray(sess.insert(base).result())]
        for i, (vs, ds) in enumerate(zip(extra, dele)):
            sess.delete(ds)
            acked.append(np.asarray(sess.insert(vs).result()))
            sess.flush()
            if i in explicit_at:
                sess.refine(n=32)
        sess.flush()
        return acked, sess

    runs = [
        drive({}),                                            # never
        drive({"refine_threshold": 25, "refine_chunk": 8}),   # auto
        drive({}, explicit_at=(0, 2)),                        # explicit
    ]
    assert runs[1][1].timers.n_refines >= 1, "auto trigger never fired"
    assert runs[2][1].timers.n_refines == 2
    ref_acked, ref_sess = runs[0]
    ref_alive = np.asarray(ref_sess.state.alive).copy()
    ref_present = np.asarray(ref_sess.state.present).copy()
    ref_size = int(np.asarray(ref_sess.state.size))
    ref_ops = ref_sess._op_counter  # snapshot: recall() below issues queries
    for acked, sess in runs:
        for got, want in zip(acked, ref_acked):
            np.testing.assert_array_equal(
                got, want, err_msg="refine timing shifted assigned ids")
        np.testing.assert_array_equal(np.asarray(sess.state.alive), ref_alive)
        np.testing.assert_array_equal(
            np.asarray(sess.state.present), ref_present)
        assert int(np.asarray(sess.state.size)) == ref_size
        assert sess._op_counter == ref_ops
        errs = check_invariants(sess.state)
        assert not errs, errs[:5]
        assert sess.recall(Q, k=10) >= RECALL_FLOOR


def test_auto_trigger_bounds_masked_fraction():
    """With consolidate_threshold set, the session auto-fires at delete and
    flush boundaries: the tombstone share stays bounded and freed slots are
    genuinely reusable by subsequent inserts."""
    thr = 0.2
    rng = np.random.default_rng(9)
    sess = Session(_params(consolidate_threshold=thr), seed=0)
    X = rng.normal(size=(100, DIM)).astype(np.float32)
    ids = list(sess.insert(X).result())
    for _ in range(10):
        victims = [ids.pop(int(rng.integers(len(ids)))) for _ in range(6)]
        sess.delete(np.asarray(victims, np.int32))
        new = sess.insert(
            rng.normal(size=(6, DIM)).astype(np.float32)).result()
        assert (np.asarray(new) != NULL).all(), "slots must keep recycling"
        ids.extend(int(v) for v in new)
        sess.flush()
        # flush is a trigger point: the settled share is under the threshold
        # (+ one delete-op of slack for tombstones younger than the check)
        assert masked_fraction(sess.state) <= thr + 6 / 100 + 1e-6
    assert sess.timers.n_consolidations >= 1
    assert sess.timers.n_consolidated > 0
    errs = check_invariants(sess.state)
    assert not errs, errs[:5]
    d = sess.timers.to_dict()
    assert d["n_consolidations"] == sess.timers.n_consolidations
    assert d["consolidate_s"] >= 0.0


def test_consolidation_chunk_shape_invariance():
    """Chunked compaction must drain the whole tombstone set for any chunk
    width, leaving identical alive/present flags (edge-level layout may
    differ — each chunk repairs against a different intermediate graph)."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(80, DIM)).astype(np.float32)
    flags = {}
    for chunk in (4, CHUNK, 64):
        sess = Session(_params(), seed=2)
        ids = sess.insert(X).result()
        sess.delete(ids[10:40])
        assert sess.consolidate(chunk=chunk) == 30
        sess.flush()
        assert masked_fraction(sess.state) == 0.0
        errs = check_invariants(sess.state)
        assert not errs, (chunk, errs[:5])
        flags[chunk] = (np.asarray(sess.state.alive),
                        np.asarray(sess.state.present))
    for chunk in (CHUNK, 64):
        np.testing.assert_array_equal(flags[4][0], flags[chunk][0])
        np.testing.assert_array_equal(flags[4][1], flags[chunk][1])


def test_run_workload_consolidate_op():
    """("consolidate", None) is a first-class stream op on both drivers."""
    rng = np.random.default_rng(6)
    X = rng.normal(size=(60, DIM)).astype(np.float32)
    stream = [
        ("delete", np.arange(15)),
        ("consolidate", None),
        ("insert", rng.normal(size=(10, DIM)).astype(np.float32)),
        ("query", rng.normal(size=(12, DIM)).astype(np.float32)),
    ]
    sess = Session(_params(), seed=0)
    sess.insert(X)
    recs = run_workload(sess, list(stream), k=5)
    assert [r["op"] for r in recs] == [
        "delete", "consolidate", "insert", "query", "summary"]
    assert recs[1]["n"] == 15
    assert recs[-1]["n"] == 15 + 10 + 12  # consolidations aren't stream items
    assert recs[-1]["timers"]["n_consolidated"] == 15
    assert masked_fraction(sess.state) == 0.0

    idx = IPGMIndex(_params(), seed=0)
    idx.insert(X)
    recs_f = run_workload(idx, list(stream), k=5)
    assert [r["op"] for r in recs_f] == [
        "delete", "consolidate", "insert", "query"]
    assert recs_f[1]["n"] == 15
    assert recs_f[-1]["recall"] == pytest.approx(recs[-2]["recall"], abs=1e-9)


# ---------------------------------------------------------------------------
# growth engine (DESIGN.md §9): net-growing streams vs the unbounded oracle
# ---------------------------------------------------------------------------

GROW_CAP = 64
GROW_MAX = 1024


def _growth_params(**maintenance_kw):
    mkw = dict(strategy="mask", insert_chunk=CHUNK, delete_chunk=CHUNK,
               max_capacity=GROW_MAX)
    mkw.update(maintenance_kw)
    p = _params(**mkw)
    import dataclasses
    return dataclasses.replace(p, capacity=GROW_CAP)


@pytest.mark.parametrize("seed", [0, 1])
def test_growth_stream_fuzz_differential(seed):
    """Net-growing random mixed streams through an armed session vs the
    unbounded-allocator oracle: insert-id parity across every tier move,
    zero refusals, flag parity, recall floor, clean invariants."""
    rng = np.random.default_rng(seed)
    sess = Session(_growth_params(), seed=seed)
    oracle = UnboundedOracle()
    base = rng.normal(size=(50, DIM)).astype(np.float32)
    np.testing.assert_array_equal(sess.insert(base).result(),
                                  oracle.insert(base))

    for step in range(20):
        op = rng.choice(["insert", "delete", "query", "consolidate"],
                        p=[0.45, 0.2, 0.25, 0.1])
        if op == "insert":
            n = int(rng.integers(5, 25))  # insert-heavy: the net-growth bias
            V = rng.normal(size=(n, DIM)).astype(np.float32)
            # the gate's arbitration may compact tombstones *before* this
            # insert dispatches (grow-vs-consolidate, DESIGN.md §9) — the
            # timer delta tells the oracle to mirror the compaction first
            n_cons = sess.timers.n_consolidations
            got = sess.insert(V).result()
            if sess.timers.n_consolidations > n_cons:
                oracle.consolidate()
            np.testing.assert_array_equal(
                got, oracle.insert(V),
                err_msg=f"allocator parity broke across a tier move @ {step}",
            )
        elif op == "delete":
            alive_ids = np.flatnonzero(oracle.alive)
            if alive_ids.size < 20:
                continue
            victims = rng.choice(alive_ids, size=int(rng.integers(1, 8)),
                                 replace=False)
            sess.delete(victims.astype(np.int32))
            oracle.delete_mask(victims)
        elif op == "query":
            Q = rng.normal(size=(int(rng.integers(1, 10)), DIM)).astype(
                np.float32)
            ids, _ = sess.query(Q, k=10).result()
            assert oracle.recall(ids, Q, 10) >= RECALL_FLOOR, step
        else:
            assert sess.consolidate() == oracle.consolidate()
            sess.flush()
            _assert_flag_parity_prefix(sess, oracle)

    sess.flush()
    assert sess.timers.n_refused == 0, "armed sessions must never refuse"
    assert sess.state.capacity > GROW_CAP, "the stream must have grown"
    import math
    bound = math.ceil(math.log2(sess.state.capacity / GROW_CAP))
    assert sess.timers.n_grows <= bound, (sess.timers.n_grows, bound)
    _assert_flag_parity_prefix(sess, oracle)
    errs = check_invariants(sess.state)
    assert not errs, errs[:5]
    Q = rng.normal(size=(32, DIM)).astype(np.float32)
    ids, _ = sess.query(Q, k=10).result()
    assert oracle.recall(ids, Q, 10) >= RECALL_FLOOR


def test_growth_timing_invariance():
    """The same logical stream from different initial tiers (growth firing
    at different stream positions, or never): identical slot assignment and
    alive flags — allocation is lowest-free-first and the op-key chain
    never sees a grow — plus the recall floor everywhere. PURE deletes keep
    the physical layout schedule-independent (MASK's tombstone *compaction*
    timing is a separate, already-pinned invariance — §8)."""
    import dataclasses

    base, events = _logical_stream(seed=5)
    outs = []
    for cap0 in (80, 160, 320):
        params = dataclasses.replace(_growth_params(strategy="pure"),
                                     capacity=cap0)
        sess = Session(params, seed=7)
        oracle = UnboundedOracle()
        logical_to_slot = {}
        ids = sess.insert(base).result()
        np.testing.assert_array_equal(ids, oracle.insert(base))
        for lg, s in enumerate(ids):
            logical_to_slot[lg] = int(s)
        next_logical = len(base)
        recalls = []
        for op, payload in events:
            if op == "insert":
                got = sess.insert(payload).result()
                np.testing.assert_array_equal(got, oracle.insert(payload))
                for v in got:
                    logical_to_slot[next_logical] = int(v)
                    next_logical += 1
            elif op == "delete":
                slots = np.asarray(
                    [logical_to_slot[lg] for lg in payload], np.int32)
                sess.delete(slots)
                oracle.delete_hard(slots)
            else:
                found, _ = sess.query(payload, k=10).result()
                recalls.append(oracle.recall(found, payload, 10))
        sess.flush()
        errs = check_invariants(sess.state)
        assert not errs, (cap0, errs[:5])
        outs.append((recalls, np.asarray(sess.state.alive), sess))

    assert outs[0][2].timers.n_grows >= 1       # the small tier had to grow
    assert outs[-1][2].timers.n_grows == 0      # the big tier never did
    for sess in (o[2] for o in outs):
        assert sess.timers.n_refused == 0
    _, ref_alive, _ = outs[0]
    for recalls, alive, _ in outs:
        assert all(r >= RECALL_FLOOR for r in recalls), recalls
        n = min(ref_alive.shape[0], alive.shape[0])
        np.testing.assert_array_equal(
            alive[:n], ref_alive[:n],
            err_msg="growth timing must not change the alive slot set",
        )
        assert not alive[n:].any() and not ref_alive[n:].any()


def test_save_grow_restore_bit_exact():
    """save at tier C → restore → the *next growth* and everything after it
    replay bit-exactly (tier sequence included)."""
    import tempfile

    def run(ckpt_dir=None, restore_from=None):
        rng = np.random.default_rng(13)
        sess = Session(_growth_params(), seed=1, checkpoint_dir=ckpt_dir)
        X = rng.normal(size=(60, DIM)).astype(np.float32)
        sess.insert(X).result()
        if ckpt_dir is not None and restore_from is None:
            sess.save(step=1)
        if restore_from is not None:
            rng = np.random.default_rng(13)
            rng.normal(size=(60, DIM))
            sess = Session(_growth_params(), seed=1,
                           checkpoint_dir=restore_from)
            sess.restore(1)
        ids = sess.insert(
            rng.normal(size=(40, DIM)).astype(np.float32)).result()
        sess.flush()
        return (np.asarray(ids), sess.state.capacity,
                np.asarray(sess.state.adj), np.asarray(sess.state.alive))

    with tempfile.TemporaryDirectory() as d:
        out_a = run(ckpt_dir=d)            # save mid-stream, then grow more
        out_b = run()                      # never checkpointed
        out_c = run(restore_from=d)        # restore, then replay the tail
    for a, b in zip(out_a, out_b):
        np.testing.assert_array_equal(a, b)
    for a, c in zip(out_a, out_c):
        np.testing.assert_array_equal(a, c)


def test_consolidate_handle_reports_compacted_slots():
    """run_workload's consolidate op + the session's op surface agree on
    which tombstones were compacted."""
    rng = np.random.default_rng(4)
    sess = Session(_params(), seed=0)
    X = rng.normal(size=(50, DIM)).astype(np.float32)
    ids = sess.insert(X).result()
    victims = np.sort(rng.choice(ids, size=20, replace=False))
    sess.delete(victims.astype(np.int32))
    n = sess.consolidate()
    assert n == 20
    # the consolidate handle resolves to the compacted slot ids
    handle = sess.last_consolidate_handle
    assert handle is not None and handle.op == "consolidate"
    got = np.sort(np.asarray(handle.result()))
    np.testing.assert_array_equal(got, victims)
    sess.flush()


# ---------------------------------------------------------------------------
# adversarial deletion patterns (ROADMAP item 1 / DESIGN.md §13): rolling-
# window eviction and delete-then-reinsert, pinned against the numpy oracle
# for the random-walk repair strategy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_rwalk_rolling_window_eviction_stream(seed):
    """FIFO rolling window under the RWALK strategy (hard delete): the
    oldest slice is evicted every round and replaced with fresh arrivals,
    so the index fully turns over. Pinned vs the oracle at every round:
    allocator parity (evicted slots must recycle immediately), recall
    floor, and flag parity + clean invariants at the end."""
    import collections

    rng = np.random.default_rng(100 + seed)
    sess = Session(_params(strategy="rwalk"), seed=seed)
    oracle = Oracle()
    base = rng.normal(size=(100, DIM)).astype(np.float32)
    ids = sess.insert(base).result()
    np.testing.assert_array_equal(ids, oracle.insert(base))
    fifo = collections.deque(int(s) for s in ids)
    for rnd in range(12):
        evict = np.asarray([fifo.popleft() for _ in range(8)], np.int32)
        sess.delete(evict)
        oracle.delete_hard(evict)
        V = rng.normal(size=(8, DIM)).astype(np.float32)
        got = sess.insert(V).result()
        np.testing.assert_array_equal(
            got, oracle.insert(V),
            err_msg=f"freed-slot reuse parity broke at round {rnd}",
        )
        fifo.extend(int(s) for s in got)
        Q = rng.normal(size=(8, DIM)).astype(np.float32)
        found, _ = sess.query(Q, k=10).result()
        assert oracle.recall(found, Q, 10) >= RECALL_FLOOR, rnd
    sess.flush()
    _assert_flag_parity(sess, oracle)
    errs = check_invariants(sess.state)
    assert not errs, errs[:5]


def test_tiered_delete_then_reinsert_same_ext_one_flush_window():
    """Delete an external id and reinsert it (same id, fresh vector) with NO
    flush between the two ops, through a TieredSession whose fresh tier runs
    the RWALK hard-delete strategy. The reinserted copy must be the only one
    served, the host mirrors stay exact, and a plain live-id upsert (no
    explicit delete) behaves identically."""
    from repro.core import TieredSession

    rng = np.random.default_rng(21)
    ts = TieredSession(_params(), fresh_capacity=64, fresh_strategy="rwalk",
                       seed=3)
    X = rng.normal(size=(40, DIM)).astype(np.float32)
    ext = np.arange(40)
    got = ts.insert(X, ids=ext).result()
    np.testing.assert_array_equal(got, ext)

    # one flush window: delete then reinsert the same external ids
    victims = np.asarray([3, 7, 11], np.int64)
    X2 = rng.normal(size=(3, DIM)).astype(np.float32)
    ts.delete(victims)
    got = ts.insert(X2, ids=victims).result()
    np.testing.assert_array_equal(got, victims)
    ts.flush()
    ts.check_mirrors()
    assert ts.n_alive == 40

    # the new vectors are served under the old ids (exact-match queries),
    # and the old copies are never reported
    ids, scores = ts.query(X2, k=1).result()
    np.testing.assert_array_equal(ids[:, 0], victims)
    ids_old, scores_old = ts.query(X[victims], k=1).result()
    for j, e in enumerate(victims):
        if int(ids_old[j, 0]) == int(e):
            # the id may still win on proximity, but only via the NEW vector
            d_new = float(((X2[j] - X[int(e)]) ** 2).sum())
            assert scores_old[j, 0] != pytest.approx(0.0, abs=1e-5) or \
                d_new == pytest.approx(0.0, abs=1e-5)

    # live-id upsert path (no explicit delete): same contract
    X3 = rng.normal(size=(3, DIM)).astype(np.float32)
    got = ts.insert(X3, ids=victims).result()
    np.testing.assert_array_equal(got, victims)
    ts.flush()
    ts.check_mirrors()
    assert ts.n_alive == 40
    ids, _ = ts.query(X3, k=1).result()
    np.testing.assert_array_equal(ids[:, 0], victims)


# ---------------------------------------------------------------------------
# kill-and-recover fuzz (DESIGN.md §11): seeded random crash schedules over
# a deterministic mixed stream — whatever fires, the resumed run must land
# bit-identical to the uninterrupted control
# ---------------------------------------------------------------------------

from repro.testing import faults  # noqa: E402

F_OPS = 40
F_FLUSH = 6
F_SAVE = 15


def _f_vec(t):
    return np.random.default_rng(7000 + t).normal(size=(4, DIM)).astype(
        np.float32)


def _f_dels(t):
    return np.random.default_rng(8000 + t).integers(
        0, CAP, size=4).astype(np.int32)


def _f_events(sess, t):
    if (t + 1) % F_FLUSH == 0:
        sess.flush()
    if (t + 1) % F_SAVE == 0:
        sess.save(t + 1)


def _f_run(sess, start=0):
    # on resume, re-run the (idempotent) events of the last replayed op —
    # a kill inside them may have lost the flush/save
    if start > 0:
        _f_events(sess, start - 1)
    for t in range(start, F_OPS):
        kind = "iidq"[t % 4]
        if kind == "i":
            sess.insert(_f_vec(t))
        elif kind == "d":
            sess.delete(_f_dels(t))
        else:
            sess.query(_f_vec(t)[:2])
        _f_events(sess, t)
    sess.flush()


def _f_summary(sess):
    st = sess.state
    return (np.asarray(st.adj), np.asarray(st.vectors),
            np.asarray(st.alive), np.asarray(st.present),
            st.capacity, sess._op_counter)


@pytest.mark.parametrize("seed", [11, 12, 13, 14])
def test_kill_recover_fuzz(seed, tmp_path):
    """random_plan arms one session crash point at a random occurrence; if
    it fires mid-stream, recover + resume and demand the control state.
    (Plans whose armed occurrence the stream never reaches simply complete
    — that run degenerates to a journal-overhead-only differential.)"""
    params = _params(consolidate_threshold=0.25)
    ctrl = Session(params, seed=5, checkpoint_dir=tmp_path / "ctrl")
    _f_run(ctrl)
    want = _f_summary(ctrl)

    plan = faults.random_plan(seed)
    sess = Session(params, seed=5, checkpoint_dir=tmp_path / "kill")
    crashed = False
    with faults.inject(plan):
        try:
            _f_run(sess)
        except faults.SimulatedCrash:
            crashed = True
    if crashed:
        del sess  # the device state dies with the "process"
        sess = Session.recover(tmp_path / "kill", params, seed=5)
        _f_run(sess, start=sess._op_counter)
    got = _f_summary(sess)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w, err_msg=str(plan.crashes))
    errs = check_invariants(sess.state)
    assert not errs, errs[:5]
