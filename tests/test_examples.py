"""Subprocess smoke over the runnable examples (CI's examples job).

Each example runs as the user would run it — a fresh interpreter with
``PYTHONPATH=src`` — so import breakage, API drift, and top-level crashes
in examples/ fail CI even when no unit test imports the touched module.
Marked ``examples`` so CI can run the set standalone (``-m examples``).
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

_ROOT = Path(__file__).resolve().parent.parent


def _run_example(script: str, *args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src")
    return subprocess.run(
        [sys.executable, str(_ROOT / "examples" / script), *args],
        capture_output=True, text=True, timeout=900, env=env, check=False)


@pytest.mark.examples
def test_quickstart_runs():
    proc = _run_example("quickstart.py")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # the two-tier quickstart must demonstrate merges and refuse nothing
    assert "recall@10" in proc.stdout
    assert "n_refused=0" in proc.stdout


@pytest.mark.examples
def test_online_ann_serving_runs():
    proc = _run_example("online_ann_serving.py", "--scale", "300",
                        "--steps", "2")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # both strategies must complete their streams
    assert "strategy: global" in proc.stdout
    assert "strategy: mask" in proc.stdout
