"""GREEDY-SEARCH behaviour: recall, termination, determinism, metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import build_index, small_params
from repro.core import IPGMIndex, IndexParams, SearchParams, metrics
from repro.core.graph import NULL
from repro.core import search as search_mod


def test_recall_beats_random_walk():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(400, 16)).astype(np.float32)
    idx = build_index(X, capacity=512, d_out=8, pool=24)
    Q = rng.normal(size=(64, 16)).astype(np.float32)
    assert idx.recall(Q, k=10) > 0.75


def test_results_sorted_and_alive():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(200, 8)).astype(np.float32)
    idx = build_index(X, capacity=256)
    idx.delete(np.arange(50))
    ids, scores = idx.query(rng.normal(size=(16, 8)).astype(np.float32), k=16)
    s = np.asarray(scores)
    i = np.asarray(ids)
    alive = np.asarray(idx.state.alive)
    for b in range(16):
        row = s[b][np.isfinite(s[b])]
        assert (np.diff(row) <= 1e-6).all(), "scores must be descending"
        valid = i[b][i[b] != NULL]
        assert alive[valid].all(), "results must be alive"
        assert (~np.isin(valid, np.arange(50))).all()


def test_search_exact_on_tiny_graph():
    """With pool ≥ n and enough steps, greedy search is exhaustive."""
    rng = np.random.default_rng(2)
    X = rng.normal(size=(30, 4)).astype(np.float32)
    p = IndexParams(capacity=40, dim=4, d_out=8,
                    search=SearchParams(pool_size=32, max_steps=64,
                                        num_starts=4))
    idx = IPGMIndex(p, strategy="pure")
    idx.insert(X)
    Q = rng.normal(size=(8, 4)).astype(np.float32)
    assert idx.recall(Q, k=5) == 1.0


def test_hop_count_bounded():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(300, 8)).astype(np.float32)
    idx = build_index(X, capacity=384, pool=16)
    res = search_mod.search_batch(
        idx.state, jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32)),
        jax.random.PRNGKey(0), idx.params.search,
    )
    hops = np.asarray(res.n_expanded)
    assert (hops <= idx.params.search.max_steps).all()
    assert (hops > 0).all()


def test_recall_metric():
    found = jnp.asarray([[1, 2, 3], [4, 5, NULL]])
    true = jnp.asarray([[1, 2, 9], [4, 5, 6]])
    r = float(metrics.recall_at_k(found, true, 3))
    assert abs(r - (2 / 3 + 2 / 3) / 2) < 1e-6


def test_empty_graph_query():
    p = small_params(capacity=32, dim=4)
    idx = IPGMIndex(p)
    ids, scores = idx.query(np.zeros((4, 4), np.float32), k=5)
    assert (np.asarray(ids) == NULL).all()
