"""Two-tier index certification (DESIGN.md §12).

Differential + invariance suite for :class:`TieredSession`:

  · host-mirror parity — the tiered layer's present/masked/ext mirrors must
    match the device bitmaps bit-exactly after any churn (they are what
    routes ops and gates the merge, so drift would be silent corruption);
  · external-id semantics vs a numpy oracle — upsert, cross-tier delete,
    fan-out dedup: a live external id is reported at most once, with its
    *newest* vector, no matter which tier(s) hold copies mid-merge;
  · merge-timing invariance — the same logical stream under different merge
    chunk sizes / trigger thresholds / explicit merge placement keeps the
    identical acked-id sequence and alive set, and recall never drops below
    the pinned floor after merges (the §8 consolidation guarantee class,
    extended to the merge PRNG stream);
  · per-tier key-chain uniformity — every public op consumes a fixed number
    of per-tier op keys regardless of where its targets live, which is the
    mechanism behind the invariance above.

Configs stay small: every TieredSession here shares one geometry so the
jitted op-switch compiles once per tier shape family for the module.
"""
import numpy as np
import pytest

from repro.core import (
    NULL,
    IndexParams,
    MaintenanceParams,
    SearchParams,
    TieredSession,
)

DIM = 8
CHUNK = 16
CAP = 96
FRESH = 32
RECALL_FLOOR = 0.75  # measured min over the seeds below is 0.92; wide margin


def _params(**maintenance_kw):
    mkw = dict(strategy="mask", insert_chunk=CHUNK, delete_chunk=CHUNK,
               max_capacity=4 * CAP)
    mkw.update(maintenance_kw)
    return IndexParams(
        capacity=CAP, dim=DIM, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(**mkw),
    )


def _session(seed=0, **maintenance_kw):
    return TieredSession(_params(**maintenance_kw), fresh_capacity=FRESH,
                         seed=seed)


class ExtOracle:
    """Ground truth keyed by *external* id: a dict of live vectors."""

    def __init__(self):
        self.vec: dict[int, np.ndarray] = {}

    def upsert(self, ids, vecs):
        for e, v in zip(np.asarray(ids).ravel(), np.asarray(vecs, np.float32)):
            if e != NULL:
                self.vec[int(e)] = v.copy()

    def delete(self, ids):
        for e in np.asarray(ids).ravel():
            self.vec.pop(int(e), None)

    def topk(self, queries, k):
        if not self.vec:
            return np.full((len(queries), k), NULL, np.int32)
        ids = np.fromiter(self.vec.keys(), np.int32)
        mat = np.stack([self.vec[int(e)] for e in ids])
        d2 = ((mat[None] - np.asarray(queries, np.float32)[:, None]) ** 2
              ).sum(-1)
        order = np.argsort(d2, axis=1)[:, :k]
        out = np.full((len(queries), k), NULL, np.int32)
        out[:, :order.shape[1]] = ids[order]
        return out

    def recall(self, found, queries, k):
        true = self.topk(queries, k)
        hits = 0.0
        for f, t in zip(np.asarray(found)[:, :k], true):
            tset = set(int(x) for x in t if x != NULL)
            if not tset:
                continue
            hits += len(set(int(x) for x in f if x != NULL) & tset) / len(tset)
        return hits / max(len(queries), 1)


def _vecs(seed, n):
    return np.random.default_rng(seed).normal(size=(n, DIM)).astype(np.float32)


def _drive(ts, oracle, seed, n_ops=30, explicit_merge_at=()):
    """One seeded mixed stream; returns the acked-id transcript."""
    rng = np.random.default_rng(seed)
    acks = []
    for t in range(n_ops):
        r = rng.random()
        if r < 0.45:
            v = _vecs(seed * 1000 + t, int(rng.integers(1, 12)))
            ids = ts.insert(v).result()
            if oracle is not None:
                oracle.upsert(ids, v)
            acks.append(("i", ids.tolist()))
        elif r < 0.65 and ts.n_alive > 4:
            live = np.fromiter(sorted(ts._loc), np.int64)
            pick = live[rng.integers(0, len(live),
                                     size=int(rng.integers(1, 4)))]
            ts.delete(pick).result()
            if oracle is not None:
                oracle.delete(pick)
            acks.append(("d", sorted(set(pick.tolist()))))
        else:
            q = _vecs(seed * 7777 + t, 4)
            ids, _ = ts.query(q, k=8).result()
            acks.append(("q", None))
        if t in explicit_merge_at:
            ts.merge()
        if t % 9 == 8:
            ts.flush()
    ts.flush()
    return acks


# ---------------------------------------------------------------------------
# mirrors + basic semantics
# ---------------------------------------------------------------------------

def test_mirror_parity_after_churn():
    ts = _session(seed=1, merge_fresh_threshold=0.6,
                  merge_tombstone_threshold=0.3)
    _drive(ts, None, seed=1, n_ops=40)
    ts.check_mirrors()  # raises on any divergence
    # alive ext set == location table == union of ext maps
    exts = set(ts._fm.ext[ts._fm.ext != NULL].tolist()) | set(
        ts._mm.ext[ts._mm.ext != NULL].tolist())
    assert exts == set(ts._loc)


def test_external_ids_are_monotone_and_stable():
    ts = _session(seed=0, merge_fresh_threshold=0.6)
    a = ts.insert(_vecs(0, 5)).result()
    b = ts.insert(_vecs(1, 5)).result()
    assert a.tolist() == [0, 1, 2, 3, 4]
    assert b.tolist() == [5, 6, 7, 8, 9]
    ts.merge()  # ids survive the tier move untouched
    ids, _ = ts.query(_vecs(0, 5), k=4).result()
    assert set(ids[:, 0].tolist()) <= set(range(10))


def test_delete_routes_to_both_tiers():
    ts = _session(seed=2, merge_fresh_threshold=None)
    ids = ts.insert(_vecs(3, 20)).result()
    ts.merge()                       # all 20 now main-resident
    ids2 = ts.insert(_vecs(4, 6)).result()   # fresh-resident
    ts.delete(np.concatenate([ids[:3], ids2[:2]])).result()
    ts.flush()
    st = ts.stats()
    assert st["n_main_masked"] == 3   # main deletes tombstone
    assert st["n_fresh"] == 4         # fresh deletes free immediately
    assert ts.n_alive == 21
    ts.check_mirrors()
    # next merge's compaction reclaims the tombstones
    ts.merge()
    assert ts.stats()["n_main_masked"] == 0


# ---------------------------------------------------------------------------
# satellite 2: cross-tier duplicate-id / upsert semantics
# ---------------------------------------------------------------------------

def test_reinserted_id_never_surfaces_twice_nor_stale():
    """Delete a main-resident id, re-insert the same external id with a new
    vector: queries must report the id at most once and score the NEW
    vector (the tombstoned main ghost must stay invisible)."""
    ts = _session(seed=5, merge_fresh_threshold=None)
    v_old = _vecs(50, 12)
    ids = ts.insert(v_old).result()
    ts.merge()                                   # main-resident now
    target = int(ids[0])
    ts.delete([target]).result()                 # tombstone in main
    v_new = -v_old[0:1] * 3.0                    # far from the old vector
    got = ts.insert(v_new, ids=[target]).result()
    assert got.tolist() == [target]
    q_ids, q_sc = ts.query(v_new, k=8).result()
    row = q_ids[0].tolist()
    assert row.count(target) == 1
    # scored against the NEW vector (l2 score 2<x,q>-|x|^2; x=q → |q|^2),
    # not the tombstoned old one (which would score 2<v_old,q>-|v_old|^2)
    pos = row.index(target)
    expect = float(np.sum(v_new[0] ** 2))
    assert q_sc[0][pos] == pytest.approx(expect, rel=1e-4)
    # the ghost's slot must also never resurface after compaction reuse
    ts.merge()
    q_ids, _ = ts.query(v_new, k=8).result()
    assert q_ids[0].tolist().count(target) == 1
    ts.check_mirrors()


def test_upsert_same_tier_and_within_batch():
    ts = _session(seed=6, merge_fresh_threshold=None)
    ids = ts.insert(_vecs(60, 4)).result()
    # upsert while still fresh-resident: same ext id, one live copy
    got = ts.insert(_vecs(61, 1), ids=[int(ids[1])]).result()
    assert got.tolist() == [int(ids[1])]
    assert ts.n_alive == 4
    # duplicate ids within one batch: last row wins, earlier superseded
    v = _vecs(62, 3)
    got = ts.insert(v, ids=[100, 100, 101]).result()
    assert got.tolist() == [NULL, 100, 101]
    ts.flush()
    q_ids, q_sc = ts.query(v[1:2], k=8).result()
    row = q_ids[0].tolist()
    assert row.count(100) == 1
    # the surviving copy is the LAST duplicate row, i.e. exactly v[1]
    expect = float(np.sum(v[1] ** 2))
    assert q_sc[0][row.index(100)] == pytest.approx(expect, rel=1e-4)
    ts.check_mirrors()


def test_mid_drain_duplicate_is_deduped():
    """While an item is resident in BOTH tiers (drained, not yet swapped),
    the fan-out union must still report it exactly once."""
    ts = _session(seed=7, merge_fresh_threshold=None, merge_chunk=4)
    v = _vecs(70, 10)
    ts.insert(v).result()
    # drive the merge by hand (not via _active_merge: query's pump must not
    # advance it) and park it mid-drain
    from repro.core.merge import DRAIN, StreamingMerge
    m = StreamingMerge(ts)
    while m.phase != DRAIN:
        m.step()
    m.step()  # drain one chunk → those items are now in both tiers
    both = [e for e, loc in ts._loc.items() if loc[0] == "both"]
    assert both, "expected mid-drain duplicates"
    q_ids, _ = ts.query(v, k=10).result()
    for row in q_ids:
        live = [x for x in row.tolist() if x != NULL]
        assert len(live) == len(set(live)), row
    m.run()
    ts.flush()
    ts.check_mirrors()


# ---------------------------------------------------------------------------
# satellite 4: merge-timing invariance + recall floor (stream fuzz)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stream_fuzz_differential_vs_oracle(seed):
    ts = _session(seed=seed, merge_fresh_threshold=0.6,
                  merge_tombstone_threshold=0.3)
    oracle = ExtOracle()
    _drive(ts, oracle, seed=seed, n_ops=36)
    assert set(ts._loc) == set(oracle.vec)
    q = _vecs(seed + 31337, 16)
    ids, _ = ts.query(q, k=10).result()
    rec = oracle.recall(ids, q, 10)
    assert rec >= RECALL_FLOOR, rec
    ts.check_mirrors()


@pytest.mark.parametrize("seed", [0, 3])
def test_merge_timing_invariance(seed):
    """Identical logical stream, three merge policies: acked ids, the alive
    ext set and the per-tier op-key counters must match exactly; recall
    stays above the floor under every policy."""
    configs = [
        dict(merge_fresh_threshold=0.5, merge_tombstone_threshold=0.25),
        dict(merge_fresh_threshold=0.9, merge_chunk=4),
        dict(merge_fresh_threshold=None, merge_tombstone_threshold=None),
    ]
    merge_at = [(), (), (7, 19)]   # policy 3 merges explicitly instead
    transcripts, alive_sets, counters, recalls = [], [], [], []
    for kw, m_at in zip(configs, merge_at):
        ts = _session(seed=seed, **kw)
        oracle = ExtOracle()
        acks = _drive(ts, oracle, seed=seed, n_ops=30,
                      explicit_merge_at=m_at)
        transcripts.append(acks)
        alive_sets.append(set(ts._loc))
        counters.append((ts._op_counter, ts._fresh._op_counter,
                         ts._main._op_counter))
        q = _vecs(seed + 999, 12)
        ids, _ = ts.query(q, k=10).result()
        recalls.append(oracle.recall(ids, q, 10))
    assert transcripts[0] == transcripts[1] == transcripts[2]
    assert alive_sets[0] == alive_sets[1] == alive_sets[2]
    # per-tier key chains advance identically — merge work never touches
    # them (MERGE_KEY_STREAM isolation, the mechanism behind the above)
    assert counters[0] == counters[1] == counters[2]
    assert min(recalls) >= RECALL_FLOOR, recalls


# ---------------------------------------------------------------------------
# growth + refusal accounting
# ---------------------------------------------------------------------------

def test_main_tier_grows_during_drain():
    ts = _session(seed=8, merge_fresh_threshold=None)
    for i in range(5):
        ts.insert(_vecs(800 + i, FRESH)).result()
        ts.merge()
    assert ts.n_alive == 5 * FRESH
    assert ts._main.state.capacity > CAP   # drain outgrew the initial tier
    ts.check_mirrors()
    q = _vecs(888, 8)
    assert ts.recall(q, 10) >= RECALL_FLOOR


def test_capped_merge_leaves_suffix_fresh_and_refuses_exactly():
    p = _params(merge_fresh_threshold=None, max_capacity=CAP)
    ts = TieredSession(p, fresh_capacity=FRESH, seed=9)
    total = 0
    for i in range(6):
        ids = ts.insert(_vecs(900 + i, FRESH)).result()
        total += int(np.sum(ids != NULL))
        ts.merge()
    ts.flush()
    # every acked id is live; everything past main+fresh capacity refused
    assert ts.n_alive == total
    assert total <= CAP + FRESH
    assert ts.timers.n_refused == 6 * FRESH - total
    assert ts.stats()["main_capacity"] == CAP
    ts.check_mirrors()


def test_nan_rows_rejected_and_acked_null():
    ts = _session(seed=10)
    v = _vecs(1000, 4)
    v[2, 0] = np.nan
    ids = ts.insert(v).result()
    assert ids[2] == NULL
    assert sorted(x for x in ids.tolist() if x != NULL) == [0, 1, 3]
    assert ts.timers.n_rejected == 1
    assert ts.n_alive == 3
