"""Session API (DESIGN.md §7): op IR, donation, flush, padding, checkpoints.

The module-scoped fixtures share one compiled switch program across tests —
the full lax.switch traces every branch, so re-tracing per test would
dominate the suite's wall clock.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    IndexParams,
    IPGMIndex,
    MaintenanceParams,
    SearchParams,
    Session,
    run_workload,
)
from repro.core.graph import NULL
from repro.core import ops as ops_mod

CHUNK = 16
DIM = 8


def _params():
    return IndexParams(
        capacity=192, dim=DIM, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(
            strategy="global", insert_chunk=CHUNK, delete_chunk=CHUNK
        ),
    )


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return (
        rng.normal(size=(100, DIM)).astype(np.float32),   # base
        rng.normal(size=(20, DIM)).astype(np.float32),    # queries
        rng,
    )


def _fresh_session(**kw):
    return Session(_params(), seed=0, **kw)


# ---------------------------------------------------------------------------
# tentpole: mixed stream through the unified op IR == per-op facade
# ---------------------------------------------------------------------------

def test_mixed_stream_matches_facade(data):
    """One async session stream must reproduce the per-op facade bit-exactly:
    same query ids/scores (despite different micro-batch shapes — the facade
    pads queries to ``query_chunk``), same insert ids, same final graph."""
    X, Q, rng = data
    idx = IPGMIndex(_params(), seed=0)
    f_ins = np.asarray(idx.insert(X))
    f_ids, f_scores = idx.query(Q, k=7)
    idx.delete(f_ins[:12])
    f_ids2, f_scores2 = idx.query(Q, k=7)

    sess = _fresh_session()
    h_ins = sess.insert(X)
    h_q1 = sess.query(Q, k=7)
    s_ins = h_ins.result()
    h_del = sess.delete(s_ins[:12])
    h_q2 = sess.query(Q, k=7)
    sess.flush()

    assert np.array_equal(f_ins, s_ins)
    s_ids, s_scores = h_q1.result()
    assert np.array_equal(f_ids, s_ids)
    assert np.array_equal(f_scores, s_scores)
    s_ids2, s_scores2 = h_q2.result()
    assert np.array_equal(f_ids2, s_ids2)
    assert np.array_equal(f_scores2, s_scores2)
    assert h_del.result() is None
    for fld in ("adj", "radj", "alive", "present", "vectors"):
        assert np.array_equal(
            np.asarray(getattr(idx.state, fld)),
            np.asarray(getattr(sess.state, fld)),
        ), fld


def test_unified_and_static_dispatch_agree(data):
    """The traced-op_code switch program and the trace-time branch selection
    are the same code — results must match exactly."""
    X, Q, _ = data
    outs = []
    for unified in (True, False):
        sess = Session(_params(), seed=0, unified_dispatch=unified)
        ins = sess.insert(X).result()
        sess.delete(ins[:10])
        ids, scores = sess.query(Q, k=5).result()
        sess.flush()
        outs.append((ins, ids, scores, np.asarray(sess.state.adj)))
    for a, b in zip(outs[0], outs[1]):
        assert np.array_equal(a, b)


def test_query_results_invariant_to_chunk_shape(data):
    """Per-item PRNG folds make query results independent of how the stream
    is chopped into micro-batches (DESIGN.md §7)."""
    X, Q, _ = data
    r = {}
    for chunk in (4, CHUNK, 64):
        sess = _fresh_session()
        sess.insert(X)
        r[chunk] = sess.query(Q, k=9, chunk=chunk).result()
    for chunk in (CHUNK, 64):
        assert np.array_equal(r[4][0], r[chunk][0])
        assert np.array_equal(r[4][1], r[chunk][1])


# ---------------------------------------------------------------------------
# ragged final-chunk padding (satellite): padded == unpadded reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("length", [1, CHUNK - 1, CHUNK, CHUNK + 1])
def test_ragged_query_padding(data, length):
    X, Q_all, rng = data
    Q = rng.normal(size=(length, DIM)).astype(np.float32)
    padded = _fresh_session()
    padded.insert(X)
    ids_p, scores_p = padded.query(Q, k=5).result()
    # unchunked reference: the identical op sequence with micro-batches
    # sized exactly to the stream (no padding lanes at all)
    exact = _fresh_session()
    exact.insert(X)
    ids_e, scores_e = exact.query(Q, k=5, chunk=length).result()
    assert ids_p.shape == (length, 5)
    assert np.array_equal(ids_p, ids_e)
    # per-item keys are shape-invariant, so the walks visit the same
    # vertices; scores may differ in ulps across differently-shaped
    # compiled programs (XLA picks a different reduction vectorization)
    np.testing.assert_allclose(scores_p, scores_e, rtol=1e-5, atol=1e-6)


def _replay_exact(sess: Session, op_code: int, arr, fold_chunk_key: bool):
    """Reference path: the session's op, but every micro-batch dispatched at
    its exact (unpadded) size — what the padded stream must reproduce."""
    key = sess._op_key()
    state = sess.state
    outs = []
    for ci, lo in enumerate(range(0, arr.shape[0], CHUNK)):
        part = arr[lo:lo + CHUNK]
        batch = ops_mod.make_op(
            op_code, part.shape[0], DIM,
            payload=None if op_code == ops_mod.OP_DELETE else part,
            ids=part if op_code == ops_mod.OP_DELETE else None,
            offset=lo,
        )
        ckey = jax.random.fold_in(key, ci) if fold_chunk_key else key
        state, ids, _ = ops_mod.apply_ops_step(
            state, batch, ckey, sess.params, sess.strategy,
            static_op=op_code,
        )
        outs.append(np.asarray(ids))
    sess._state = state  # the old reference was donated away above
    return outs


@pytest.mark.parametrize("length", [1, CHUNK - 1, CHUNK, CHUNK + 1])
def test_ragged_insert_padding(data, length):
    X, Q, rng = data
    V = rng.normal(size=(length, DIM)).astype(np.float32)
    padded = _fresh_session()
    padded.insert(X)
    ids_p = padded.insert(V).result()

    exact = _fresh_session()
    exact.insert(X)
    outs = _replay_exact(exact, ops_mod.OP_INSERT, V, fold_chunk_key=False)
    ids_e = np.concatenate([o[:, 0] for o in outs])

    assert ids_p.shape == (length,)
    assert np.array_equal(ids_p, ids_e)
    assert (ids_p != NULL).all()
    for fld in ("adj", "radj", "alive", "vectors"):
        assert np.array_equal(
            np.asarray(getattr(padded.state, fld)),
            np.asarray(getattr(exact.state, fld)),
        ), fld
    alive = np.asarray(padded.state.alive)
    assert alive[ids_p].all() and alive.sum() == 100 + length


@pytest.mark.parametrize("length", [1, CHUNK - 1, CHUNK, CHUNK + 1])
def test_ragged_delete_padding(data, length):
    X, Q, rng = data
    padded = _fresh_session()
    base_ids = padded.insert(X).result()
    victims = base_ids[:length]
    padded.delete(victims)
    padded.flush()

    exact = _fresh_session()
    exact.insert(X)
    _replay_exact(exact, ops_mod.OP_DELETE, victims, fold_chunk_key=True)

    for fld in ("adj", "radj", "alive", "present"):
        assert np.array_equal(
            np.asarray(getattr(padded.state, fld)),
            np.asarray(getattr(exact.state, fld)),
        ), fld
    alive = np.asarray(padded.state.alive)
    assert not alive[victims].any()
    assert alive.sum() == 100 - length


# ---------------------------------------------------------------------------
# donation (acceptance): the jitted step consumes the state buffers
# ---------------------------------------------------------------------------

def test_update_step_donates_state(data):
    X, Q, rng = data
    sess = _fresh_session()
    sess.insert(X)
    sess.flush()
    st0 = sess.state
    sess.insert(rng.normal(size=(4, DIM)).astype(np.float32))
    sess.flush()
    # the pre-dispatch state buffers were donated to the step...
    assert st0.vectors.is_deleted()
    assert st0.adj.is_deleted()
    # ...and the session holds only the returned (live) state
    assert not sess.state.vectors.is_deleted()
    assert not sess.state.adj.is_deleted()
    # queries run through the same donating step: state is re-aliased, and
    # no call-site retains the stale pre-donation reference
    st1 = sess.state
    sess.query(Q, k=3)
    sess.flush()
    assert st1.vectors.is_deleted()
    assert not sess.state.vectors.is_deleted()


def test_apply_ops_lowering_marks_donation():
    """The compiled step itself declares the GraphState input donated
    (input→output aliasing), independent of runtime buffer bookkeeping."""
    p = _params()
    sess = Session(p, seed=0)
    batch = ops_mod.make_op(ops_mod.OP_INSERT, CHUNK, DIM,
                            payload=np.zeros((4, DIM), np.float32))
    lowered = ops_mod.apply_ops_step.lower(
        sess.state, batch, jax.random.PRNGKey(0), p, "global",
        static_op=None,
    )
    txt = lowered.as_text()
    assert "tf.aliasing_output" in txt, "GraphState args must be donated"


# ---------------------------------------------------------------------------
# checkpoint integration (satellite)
# ---------------------------------------------------------------------------

def test_checkpoint_save_mutate_restore_roundtrip(tmp_path, data):
    X, Q, rng = data
    sess = Session(_params(), seed=0, checkpoint_dir=tmp_path)
    ids = sess.insert(X).result()
    sess.save(step=1)
    ref_ids, ref_scores = sess.query(Q, k=8).result()

    # mutate: churn the graph past the checkpoint
    sess.delete(ids[:30])
    sess.insert(rng.normal(size=(25, DIM)).astype(np.float32))
    mut_ids, _ = sess.query(Q, k=8).result()
    assert not np.array_equal(ref_ids, mut_ids)

    # restore rolls back state AND the PRNG chain: the next query replays
    # the op index the reference query ran at → bit-exact results
    step = sess.restore()
    assert step == 1
    got_ids, got_scores = sess.query(Q, k=8).result()
    assert np.array_equal(ref_ids, got_ids)
    assert np.array_equal(ref_scores, got_scores)
    assert sess.stats()["n_alive"] == 100


def test_checkpoint_rejects_params_mismatch(tmp_path, data):
    X, _, _ = data
    sess = Session(_params(), seed=0, checkpoint_dir=tmp_path)
    sess.insert(X)
    sess.save(step=3)
    other = Session(
        dataclasses.replace(_params(), d_out=8), seed=0,
        checkpoint_dir=tmp_path,
    )
    with pytest.raises(ValueError, match="fingerprint"):
        other.restore()
    mism = Session(_params(), strategy="mask", checkpoint_dir=tmp_path)
    with pytest.raises(ValueError, match="fingerprint"):
        mism.restore()


def test_session_without_checkpoint_dir_raises(data):
    sess = _fresh_session()
    with pytest.raises(ValueError, match="checkpoint_dir"):
        sess.save(0)


# ---------------------------------------------------------------------------
# params satellites + timers + workload driver
# ---------------------------------------------------------------------------

def test_params_defaults_not_shared():
    """Mutable-default hazard: each IndexParams must own fresh sub-configs
    (dataclasses.field(default_factory=...), not a shared class instance)."""
    a = IndexParams(capacity=8, dim=2)
    b = IndexParams(capacity=8, dim=2)
    assert a.search is not b.search
    assert a.maintenance is not b.maintenance
    assert a == b  # still value-equal (jit static-arg hashing intact)
    assert hash(a) == hash(b)


def test_facade_ctor_overrides_maintenance_params():
    p = _params()
    idx = IPGMIndex(p, strategy="mask", insert_chunk=8, delete_chunk=4)
    assert idx.strategy == "mask"
    assert idx.params.maintenance.insert_chunk == 8
    assert idx.params.maintenance.delete_chunk == 4
    # the caller's params object is untouched (frozen, replaced not mutated)
    assert p.maintenance.strategy == "global"
    with pytest.raises(ValueError, match="strategy"):
        IPGMIndex(p, strategy="nope")


def test_facade_chunk_setters_stay_assignable(data):
    """The property suite drives `idx.insert_chunk = batch` — the facade's
    chunk knobs must stay writable even though they now live on the typed
    MaintenanceParams (regression: getter-only property broke assignment)."""
    X, _, _ = data
    idx = IPGMIndex(_params(), seed=0)
    idx.insert(X[:20])
    idx.insert_chunk = 7
    idx.delete_chunk = 5
    assert idx.insert_chunk == 7 and idx.delete_chunk == 5
    ids = np.asarray(idx.insert(X[20:40]))
    assert (ids != NULL).all()
    idx.delete(ids[:6])
    assert idx.stats()["n_alive"] == 34


def test_stream_workload_recall_uses_stream_position_state(data):
    """A query's ground truth must be evaluated against the graph at the
    query's stream position, not the post-stream final state (regression:
    the consume loop used to flush and brute-force the final graph)."""
    X, Q, _ = data
    stream_ops = [("query", Q), ("delete", np.arange(50))]
    sess = _fresh_session()
    sess.insert(X)
    recs = run_workload(sess, list(stream_ops), k=5)
    idx = IPGMIndex(_params(), seed=0)
    idx.insert(X)
    legacy = run_workload(idx, list(stream_ops), k=5)
    # query results are parity-exact and GT now snapshots pre-churn state,
    # so the two drivers must report the same recall
    assert recs[0]["recall"] == pytest.approx(legacy[0]["recall"], abs=1e-9)


def test_consumed_handles_retire_from_pending(data):
    """Serving loops resolve every handle but may never flush(): consumed
    handles must leave the session's pending set (regression: they
    accumulated unboundedly) and the timer window must still close."""
    X, Q, _ = data
    sess = _fresh_session()
    sess.insert(X).result()
    for _ in range(5):
        sess.query(Q[:4], k=3).result()
    assert sess._pending == []
    assert sess.timers.wall_s > 0.0
    assert sess.timers.to_dict()["ops_per_s"] > 0.0
    # an unconsumed handle stays pending until flush retires it
    h = sess.query(Q[:4], k=3)
    assert sess._pending == [h]
    sess.flush()
    assert sess._pending == []


def test_timers_summary_and_stream_workload(data):
    X, Q, rng = data
    sess = _fresh_session()
    sess.insert(X).result()
    recs = run_workload(sess, [
        ("delete", np.arange(5)),
        ("insert", rng.normal(size=(5, DIM)).astype(np.float32)),
        ("query", Q),
    ], k=5)
    assert [r["op"] for r in recs] == ["delete", "insert", "query", "summary"]
    assert all("ops_per_s" in r for r in recs)
    assert recs[2]["recall"] > 0.5
    summary = recs[-1]
    assert summary["n"] == 5 + 5 + len(Q)
    t = summary["timers"]
    for key in ("query_s", "insert_s", "delete_s", "flush_s", "wall_s",
                "n_queries", "n_inserts", "n_deletes", "n_ops", "total_s",
                "ops_per_s"):
        assert key in t, key
    assert t["n_queries"] == len(Q) and t["n_deletes"] == 5
    assert t["ops_per_s"] > 0
