"""Property tests: graph invariants survive arbitrary op sequences.

The full-strength health check (:func:`assert_graph_healthy`) covers I1–I4
via ``helpers.check_invariants``, degree bounds, the ``rebuild_radj_rows``
reverse-adjacency oracle, codes↔vectors sync (I5), and the touch-stamp
contract (I7) — and the maintenance-op harness at the bottom runs EVERY op
registered in ``repro.core.maint`` through it, so a new maintenance op is
invariant-tested by registering one scenario instead of copying the checks.
"""
import numpy as np
import pytest

try:  # hypothesis-driven tests skip individually where it is not baked in;
    # the seeded/parametrized tests below run everywhere
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised on slim images only
    def given(*a, **k):
        def deco(f):
            return pytest.mark.skip(reason="hypothesis not installed")(f)
        return deco

    def settings(*a, **k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from helpers import build_index, check_invariants, small_params
from repro.core import (
    IPGMIndex,
    IndexParams,
    MaintenanceParams,
    SearchParams,
    Session,
    TieredSession,
    maint,
)
from repro.core.graph import NULL

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def assert_graph_healthy(state):
    """The shared full-strength health check (module docstring).

    One copy, used by every per-op test and the registry harness below —
    this is what each maintenance op must leave behind.
    """
    import jax.numpy as jnp

    from repro.core.graph import rebuild_radj_rows
    from repro.core.quantize import quantize_rows

    errs = check_invariants(state)  # I1–I4 incl. freed-slot edges
    assert not errs, errs[:5]
    adj = np.asarray(state.adj)
    radj = np.asarray(state.radj)
    # degree bounds
    assert (np.sum(adj != NULL, axis=1) <= state.d_out).all()
    assert (np.sum(radj != NULL, axis=1) <= state.d_in).all()
    # radj oracle: a full recompute from adj must agree row-for-row as sets
    # (incremental patches preserve hole positions, not entry order) and
    # must not need to drop any forward edge
    rebuilt = rebuild_radj_rows(state, jnp.ones((state.capacity,), bool))
    assert np.array_equal(np.asarray(rebuilt.adj), adj), \
        "recompute dropped forward edges — in-degree bound was violated"
    reb = np.asarray(rebuilt.radj)
    for v in range(state.capacity):
        got = set(radj[v][radj[v] != NULL].tolist())
        want = set(reb[v][reb[v] != NULL].tolist())
        assert got == want, v
    # I5: codes/scales re-check bit-exactly; freed slots are scrubbed
    present = np.asarray(state.present)
    codes, scales = quantize_rows(state.vectors)
    np.testing.assert_array_equal(np.asarray(state.codes)[present],
                                  np.asarray(codes)[present])
    np.testing.assert_array_equal(np.asarray(state.scales)[present],
                                  np.asarray(scales)[present])
    assert (np.asarray(state.codes)[~present] == 0).all()
    assert (np.asarray(state.scales)[~present] == 0.0).all()
    # I7: freed slots carry no stamp; no stamp is from the future
    touch = np.asarray(state.touch)
    assert (touch[~present] == -1).all(), "freed slot kept a touch stamp"
    assert (touch < int(state.tclock)).all(), "touch stamp >= tclock"


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(20, 60),
    strategy=st.sampled_from(["pure", "mask", "local", "global"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_insert_then_delete_invariants(n, strategy, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 8)).astype(np.float32)
    idx = build_index(X, strategy=strategy, capacity=n + 16)
    dele = rng.choice(n, size=n // 3, replace=False)
    idx.delete(dele)
    errs = check_invariants(idx.state)
    assert not errs, errs[:5]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(["pure", "local", "global"]))
def test_interleaved_ops_invariants(seed, strategy):
    """delete → insert reusing freed slots → delete again."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(40, 8)).astype(np.float32)
    idx = build_index(X, strategy=strategy, capacity=64)
    idx.delete(rng.choice(40, size=12, replace=False))
    ids2 = idx.insert(rng.normal(size=(10, 8)).astype(np.float32))
    assert (np.asarray(ids2) != NULL).all(), "freed slots must be reusable"
    alive_ids = np.flatnonzero(np.asarray(idx.state.alive))
    idx.delete(rng.choice(alive_ids, size=8, replace=False))
    errs = check_invariants(idx.state)
    assert not errs, errs[:5]


def test_mask_keeps_tombstones_traversable():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(50, 8)).astype(np.float32)
    idx = build_index(X, strategy="mask", capacity=80)
    idx.delete(np.arange(10))
    st_ = idx.state
    assert int(np.asarray(st_.masked).sum()) == 10
    assert int(np.asarray(st_.present).sum()) == 50  # still traversable
    # masked never reported
    ids, _ = idx.query(rng.normal(size=(16, 8)).astype(np.float32), k=10)
    found = np.asarray(ids)
    found = found[found != NULL]
    assert not set(found.tolist()) & set(range(10))


def test_capacity_full_insert_refuses():
    rng = np.random.default_rng(4)
    p = small_params(capacity=16, dim=4)
    idx = IPGMIndex(p, strategy="pure")
    ids = idx.insert(rng.normal(size=(20, 4)).astype(np.float32))
    arr = np.asarray(ids)
    assert (arr[:16] != NULL).all()
    assert (arr[16:] == NULL).all(), "inserts beyond capacity must refuse"
    assert not check_invariants(idx.state)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    strategy=st.sampled_from(["pure", "local", "global"]),
    batch=st.sampled_from([1, 7, 16]),
)
def test_batched_update_sequences_invariants(seed, strategy, batch):
    """Random batched insert→delete→insert sequences through the vectorized
    update engine (bulk edge primitives): I1 (adj/radj mirror), I4 (no
    dup/self edges), and degree bounds must hold after every step."""
    rng = np.random.default_rng(seed)
    idx = build_index(
        rng.normal(size=(40, 8)).astype(np.float32),
        strategy=strategy, capacity=96,
    )
    idx.insert_chunk = batch  # drive the pipeline at this micro-batch size

    def assert_healthy():
        errs = check_invariants(idx.state)
        assert not errs, errs[:5]
        adj = np.asarray(idx.state.adj)
        radj = np.asarray(idx.state.radj)
        assert (np.sum(adj != NULL, axis=1) <= idx.state.d_out).all()
        assert (np.sum(radj != NULL, axis=1) <= idx.state.d_in).all()

    for step in range(3):
        alive_ids = np.flatnonzero(np.asarray(idx.state.alive))
        n_del = min(len(alive_ids), int(rng.integers(1, 12)))
        idx.delete(rng.choice(alive_ids, size=n_del, replace=False))
        assert_healthy()
        n_ins = int(rng.integers(1, 14))
        ids = idx.insert(rng.normal(size=(n_ins, 8)).astype(np.float32))
        assert (np.asarray(ids) != NULL).all()
        assert_healthy()


# ---------------------------------------------------------------------------
# post-consolidation states (DESIGN.md §8): freed slots, radj oracle, bounds
# ---------------------------------------------------------------------------

def _consolidated_index(seed, consolidate_strategy, n_del):
    """Mask-delete a random subset, then run the jitted compaction pass."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(48, 8)).astype(np.float32)
    idx = build_index(X, strategy="mask", capacity=96)
    victims = rng.choice(48, size=n_del, replace=False)
    idx.delete(victims)
    n = idx.consolidate(strategy=consolidate_strategy)
    assert n == n_del
    return idx, victims, rng


@pytest.mark.parametrize("consolidate_strategy", ["pure", "local", "global"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_post_consolidation_invariants(seed, consolidate_strategy):
    """After compaction: full health (shared check), no edges into the freed
    slots in either direction, and the freed slots genuinely reusable."""
    n_del = int(np.random.default_rng(seed).integers(5, 21))
    idx, victims, rng = _consolidated_index(seed, consolidate_strategy, n_del)
    state = idx.state
    assert_graph_healthy(state)
    adj = np.asarray(state.adj)
    radj = np.asarray(state.radj)
    # no edges touch the freed slots, in either direction
    assert not np.isin(adj, victims).any()
    assert not np.isin(radj, victims).any()
    assert (adj[victims] == NULL).all() and (radj[victims] == NULL).all()
    # freed slots reusable: the allocator hands them out lowest-first
    n_new = len(victims)
    new_ids = np.asarray(
        idx.insert(rng.normal(size=(n_new, 8)).astype(np.float32)))
    assert (new_ids != NULL).all()
    assert set(new_ids.tolist()) == set(np.sort(victims).tolist()), \
        "consolidated slots must be the first ones re-allocated"
    assert not check_invariants(idx.state)


# ---------------------------------------------------------------------------
# post-growth states (DESIGN.md §9): byte-stable prefix, empty new slots
# ---------------------------------------------------------------------------

def test_grow_state_preserves_graph_and_adds_empty_slots():
    """After ``grow_state``: old slots byte-identical, new slots edge-free
    and invisible (not present, zero vectors), full health (shared check) at
    the new tier."""
    from repro.core.graph import grow_state

    rng = np.random.default_rng(7)
    X = rng.normal(size=(40, 8)).astype(np.float32)
    idx = build_index(X, strategy="global", capacity=48)
    st = idx.state
    grown = grow_state(st, 100)
    assert grown.capacity == 100
    for fld in ("vectors", "sqnorms", "adj", "radj", "alive", "present"):
        np.testing.assert_array_equal(
            np.asarray(getattr(grown, fld))[:48],
            np.asarray(getattr(st, fld)), err_msg=fld)
    assert (np.asarray(grown.adj)[48:] == NULL).all()
    assert (np.asarray(grown.radj)[48:] == NULL).all()
    assert not np.asarray(grown.present)[48:].any()
    assert not np.asarray(grown.alive)[48:].any()
    assert (np.asarray(grown.vectors)[48:] == 0).all()
    assert (np.asarray(grown.touch)[48:] == -1).all()
    assert int(np.asarray(grown.size)) == int(np.asarray(st.size))
    assert_graph_healthy(grown)
    # no-op and shrink edges of the contract
    assert grow_state(st, 48) is st
    with pytest.raises(ValueError, match="shrink"):
        grow_state(st, 16)


def test_grown_index_keeps_invariants_under_updates():
    """Updates running at the grown tier (insert into the padded slots,
    delete across the old/new boundary) keep full health."""
    from repro.core.graph import grow_state

    rng = np.random.default_rng(11)
    X = rng.normal(size=(40, 8)).astype(np.float32)
    idx = build_index(X, strategy="global", capacity=48)
    idx.state = grow_state(idx.state, 96)
    ids = idx.insert(rng.normal(size=(40, 8)).astype(np.float32))
    assert (np.asarray(ids) != NULL).all()
    alive_ids = np.flatnonzero(np.asarray(idx.state.alive))
    idx.delete(rng.choice(alive_ids, size=20, replace=False))
    assert_graph_healthy(idx.state)


def test_delete_then_reinsert_no_stale_edges():
    """Reused slots must not inherit stale in-edges (the ABA hazard)."""
    rng = np.random.default_rng(5)
    X = rng.normal(size=(30, 8)).astype(np.float32)
    idx = build_index(X, strategy="pure", capacity=40)
    idx.delete(np.arange(15))
    idx.insert(rng.normal(size=(15, 8)).astype(np.float32) + 100.0)
    errs = check_invariants(idx.state)
    assert not errs, errs[:5]


# ---------------------------------------------------------------------------
# the maintenance-op harness (DESIGN.md §14): every op registered in
# repro.core.maint runs an end-to-end scenario and must leave every touched
# GraphState passing the shared full-strength health check. Adding an op =
# adding one scenario function here; forgetting one fails the completeness
# assertion at the bottom.
# ---------------------------------------------------------------------------

def _stream_params(**maintenance_kw):
    mkw = dict(strategy="mask", insert_chunk=16, delete_chunk=16)
    mkw.update(maintenance_kw)
    return IndexParams(
        capacity=96, dim=8, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(**mkw),
    )


def _churned_session(seed, **maintenance_kw):
    """A Session with churn on it: insert, delete a third, insert again."""
    rng = np.random.default_rng(seed)
    sess = Session(_stream_params(**maintenance_kw), seed=0)
    sess.insert(rng.normal(size=(48, 8)).astype(np.float32))
    sess.delete(rng.choice(48, size=16, replace=False))
    sess.insert(rng.normal(size=(8, 8)).astype(np.float32))
    sess.flush()
    return sess, rng


def _scenario_consolidate(seed):
    sess, _ = _churned_session(seed)
    n = sess.consolidate()
    assert n == 16
    sess.flush()
    return [sess.state]


def _scenario_grow(seed):
    sess, rng = _churned_session(seed, max_capacity=256)
    sess.grow(192)
    sess.insert(rng.normal(size=(20, 8)).astype(np.float32))
    sess.flush()
    assert sess.state.capacity == 192
    return [sess.state]


def _scenario_refine(seed):
    sess, _ = _churned_session(seed, refine_chunk=8)
    before = {f: np.asarray(getattr(sess.state, f)).copy()
              for f in ("alive", "present", "size", "vectors", "stamps")}
    n = sess.refine(n=24)
    assert n == 24
    sess.flush()
    # refinement rewires edges ONLY (its §15 contract)
    for f, want in before.items():
        np.testing.assert_array_equal(
            np.asarray(getattr(sess.state, f)), want, err_msg=f)
    return [sess.state]


def _scenario_merge(seed):
    rng = np.random.default_rng(seed)
    sess = TieredSession(_stream_params(max_capacity=384), fresh_capacity=32,
                         seed=0)
    sess.insert(rng.normal(size=(40, 8)).astype(np.float32))
    sess.delete(np.arange(10))
    sess.merge()
    sess.insert(rng.normal(size=(12, 8)).astype(np.float32))
    sess.flush()
    return [sess._fresh.state, sess._main.state]


_SCENARIOS = {
    "consolidate": _scenario_consolidate,
    "grow": _scenario_grow,
    "refine": _scenario_refine,
    "merge": _scenario_merge,
}


def test_every_registered_op_has_a_scenario():
    assert set(_SCENARIOS) == {op.name for op in maint.REGISTRY}


@pytest.mark.parametrize("op_name", sorted(_SCENARIOS))
@pytest.mark.parametrize("seed", [0, 1])
def test_maintenance_op_leaves_graph_healthy(op_name, seed):
    for state in _SCENARIOS[op_name](seed):
        assert_graph_healthy(state)
