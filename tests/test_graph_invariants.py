"""Property tests: graph invariants survive arbitrary op sequences (I1–I4)."""
import numpy as np
import pytest

try:  # hypothesis-driven tests skip individually where it is not baked in;
    # the seeded/parametrized tests below run everywhere
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised on slim images only
    def given(*a, **k):
        def deco(f):
            return pytest.mark.skip(reason="hypothesis not installed")(f)
        return deco

    def settings(*a, **k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from helpers import build_index, check_invariants, small_params
from repro.core import IPGMIndex
from repro.core.graph import NULL

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(20, 60),
    strategy=st.sampled_from(["pure", "mask", "local", "global"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_insert_then_delete_invariants(n, strategy, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 8)).astype(np.float32)
    idx = build_index(X, strategy=strategy, capacity=n + 16)
    dele = rng.choice(n, size=n // 3, replace=False)
    idx.delete(dele)
    errs = check_invariants(idx.state)
    assert not errs, errs[:5]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(["pure", "local", "global"]))
def test_interleaved_ops_invariants(seed, strategy):
    """delete → insert reusing freed slots → delete again."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(40, 8)).astype(np.float32)
    idx = build_index(X, strategy=strategy, capacity=64)
    idx.delete(rng.choice(40, size=12, replace=False))
    ids2 = idx.insert(rng.normal(size=(10, 8)).astype(np.float32))
    assert (np.asarray(ids2) != NULL).all(), "freed slots must be reusable"
    alive_ids = np.flatnonzero(np.asarray(idx.state.alive))
    idx.delete(rng.choice(alive_ids, size=8, replace=False))
    errs = check_invariants(idx.state)
    assert not errs, errs[:5]


def test_mask_keeps_tombstones_traversable():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(50, 8)).astype(np.float32)
    idx = build_index(X, strategy="mask", capacity=80)
    idx.delete(np.arange(10))
    st_ = idx.state
    assert int(np.asarray(st_.masked).sum()) == 10
    assert int(np.asarray(st_.present).sum()) == 50  # still traversable
    # masked never reported
    ids, _ = idx.query(rng.normal(size=(16, 8)).astype(np.float32), k=10)
    found = np.asarray(ids)
    found = found[found != NULL]
    assert not set(found.tolist()) & set(range(10))


def test_capacity_full_insert_refuses():
    rng = np.random.default_rng(4)
    p = small_params(capacity=16, dim=4)
    idx = IPGMIndex(p, strategy="pure")
    ids = idx.insert(rng.normal(size=(20, 4)).astype(np.float32))
    arr = np.asarray(ids)
    assert (arr[:16] != NULL).all()
    assert (arr[16:] == NULL).all(), "inserts beyond capacity must refuse"
    assert not check_invariants(idx.state)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    strategy=st.sampled_from(["pure", "local", "global"]),
    batch=st.sampled_from([1, 7, 16]),
)
def test_batched_update_sequences_invariants(seed, strategy, batch):
    """Random batched insert→delete→insert sequences through the vectorized
    update engine (bulk edge primitives): I1 (adj/radj mirror), I4 (no
    dup/self edges), and degree bounds must hold after every step."""
    rng = np.random.default_rng(seed)
    idx = build_index(
        rng.normal(size=(40, 8)).astype(np.float32),
        strategy=strategy, capacity=96,
    )
    idx.insert_chunk = batch  # drive the pipeline at this micro-batch size

    def assert_healthy():
        errs = check_invariants(idx.state)
        assert not errs, errs[:5]
        adj = np.asarray(idx.state.adj)
        radj = np.asarray(idx.state.radj)
        assert (np.sum(adj != NULL, axis=1) <= idx.state.d_out).all()
        assert (np.sum(radj != NULL, axis=1) <= idx.state.d_in).all()

    for step in range(3):
        alive_ids = np.flatnonzero(np.asarray(idx.state.alive))
        n_del = min(len(alive_ids), int(rng.integers(1, 12)))
        idx.delete(rng.choice(alive_ids, size=n_del, replace=False))
        assert_healthy()
        n_ins = int(rng.integers(1, 14))
        ids = idx.insert(rng.normal(size=(n_ins, 8)).astype(np.float32))
        assert (np.asarray(ids) != NULL).all()
        assert_healthy()


# ---------------------------------------------------------------------------
# post-consolidation states (DESIGN.md §8): freed slots, radj oracle, bounds
# ---------------------------------------------------------------------------

def _consolidated_index(seed, consolidate_strategy, n_del):
    """Mask-delete a random subset, then run the jitted compaction pass."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(48, 8)).astype(np.float32)
    idx = build_index(X, strategy="mask", capacity=96)
    victims = rng.choice(48, size=n_del, replace=False)
    idx.delete(victims)
    n = idx.consolidate(strategy=consolidate_strategy)
    assert n == n_del
    return idx, victims, rng


@pytest.mark.parametrize("consolidate_strategy", ["pure", "local", "global"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_post_consolidation_invariants(seed, consolidate_strategy):
    """After compaction: no edges into freed slots (I2), radj consistent
    with adj via the ``rebuild_radj_rows`` oracle, degree bounds hold, and
    the freed slots are genuinely reusable by subsequent inserts."""
    import jax.numpy as jnp
    from repro.core.graph import rebuild_radj_rows

    n_del = int(np.random.default_rng(seed).integers(5, 21))
    idx, victims, rng = _consolidated_index(seed, consolidate_strategy, n_del)
    state = idx.state
    errs = check_invariants(state)  # covers I1–I4 incl. freed-slot edges
    assert not errs, errs[:5]
    adj = np.asarray(state.adj)
    radj = np.asarray(state.radj)
    # no edges touch the freed slots, in either direction
    assert not np.isin(adj, victims).any()
    assert not np.isin(radj, victims).any()
    assert (adj[victims] == NULL).all() and (radj[victims] == NULL).all()
    # degree bounds
    assert (np.sum(adj != NULL, axis=1) <= state.d_out).all()
    assert (np.sum(radj != NULL, axis=1) <= state.d_in).all()
    # radj oracle: a full recompute from adj must agree row-for-row as sets
    # (the incremental patch preserves hole positions, not entry order) and
    # must not need to drop any forward edge
    rebuilt = rebuild_radj_rows(
        state, jnp.ones((state.capacity,), bool)
    )
    assert np.array_equal(np.asarray(rebuilt.adj), adj), \
        "recompute dropped forward edges — in-degree bound was violated"
    for v in range(state.capacity):
        got = set(radj[v][radj[v] != NULL].tolist())
        want = set(np.asarray(rebuilt.radj)[v]
                   [np.asarray(rebuilt.radj)[v] != NULL].tolist())
        assert got == want, v
    # freed slots reusable: the allocator hands them out lowest-first
    n_new = len(victims)
    new_ids = np.asarray(
        idx.insert(rng.normal(size=(n_new, 8)).astype(np.float32)))
    assert (new_ids != NULL).all()
    assert set(new_ids.tolist()) == set(np.sort(victims).tolist()), \
        "consolidated slots must be the first ones re-allocated"
    assert not check_invariants(idx.state)


# ---------------------------------------------------------------------------
# post-growth states (DESIGN.md §9): byte-stable prefix, empty new slots
# ---------------------------------------------------------------------------

def test_grow_state_preserves_graph_and_adds_empty_slots():
    """After ``grow_state``: old slots byte-identical, new slots edge-free
    and invisible (not present, zero vectors), radj consistent with the
    ``rebuild_radj_rows`` oracle at the new tier, invariants clean."""
    import jax.numpy as jnp

    from repro.core.graph import grow_state, rebuild_radj_rows

    rng = np.random.default_rng(7)
    X = rng.normal(size=(40, 8)).astype(np.float32)
    idx = build_index(X, strategy="global", capacity=48)
    st = idx.state
    grown = grow_state(st, 100)
    assert grown.capacity == 100
    for fld in ("vectors", "sqnorms", "adj", "radj", "alive", "present"):
        np.testing.assert_array_equal(
            np.asarray(getattr(grown, fld))[:48],
            np.asarray(getattr(st, fld)), err_msg=fld)
    assert (np.asarray(grown.adj)[48:] == NULL).all()
    assert (np.asarray(grown.radj)[48:] == NULL).all()
    assert not np.asarray(grown.present)[48:].any()
    assert not np.asarray(grown.alive)[48:].any()
    assert (np.asarray(grown.vectors)[48:] == 0).all()
    assert int(np.asarray(grown.size)) == int(np.asarray(st.size))
    errs = check_invariants(grown)
    assert not errs, errs[:5]
    rebuilt = rebuild_radj_rows(grown, jnp.ones((100,), bool))
    assert np.array_equal(np.asarray(rebuilt.adj), np.asarray(grown.adj))
    radj = np.asarray(grown.radj)
    reb = np.asarray(rebuilt.radj)
    for v in range(100):
        assert (set(radj[v][radj[v] != NULL].tolist())
                == set(reb[v][reb[v] != NULL].tolist())), v
    # no-op and shrink edges of the contract
    from repro.core.graph import grow_state as gs
    assert gs(st, 48) is st
    with pytest.raises(ValueError, match="shrink"):
        gs(st, 16)


def test_grown_index_keeps_invariants_under_updates():
    """Updates running at the grown tier (insert into the padded slots,
    delete across the old/new boundary) keep I1–I4 and degree bounds."""
    from repro.core.graph import grow_state

    rng = np.random.default_rng(11)
    X = rng.normal(size=(40, 8)).astype(np.float32)
    idx = build_index(X, strategy="global", capacity=48)
    idx.state = grow_state(idx.state, 96)
    ids = idx.insert(rng.normal(size=(40, 8)).astype(np.float32))
    assert (np.asarray(ids) != NULL).all()
    alive_ids = np.flatnonzero(np.asarray(idx.state.alive))
    idx.delete(rng.choice(alive_ids, size=20, replace=False))
    errs = check_invariants(idx.state)
    assert not errs, errs[:5]
    adj = np.asarray(idx.state.adj)
    radj = np.asarray(idx.state.radj)
    assert (np.sum(adj != NULL, axis=1) <= idx.state.d_out).all()
    assert (np.sum(radj != NULL, axis=1) <= idx.state.d_in).all()


def test_delete_then_reinsert_no_stale_edges():
    """Reused slots must not inherit stale in-edges (the ABA hazard)."""
    rng = np.random.default_rng(5)
    X = rng.normal(size=(30, 8)).astype(np.float32)
    idx = build_index(X, strategy="pure", capacity=40)
    idx.delete(np.arange(15))
    idx.insert(rng.normal(size=(15, 8)).astype(np.float32) + 100.0)
    errs = check_invariants(idx.state)
    assert not errs, errs[:5]
