"""Compressed scoring (DESIGN.md §10) — transactional int8 codes + two-stage
search.

The invariant under test is I5: for every *present* slot,
``(codes, scales) == quantize_rows(vectors)`` exactly, and freed slots hold
the zero encoding — maintained transactionally by every mutator (insert,
delete, consolidate, grow, bulk build) and therefore checkable at any flush
boundary of any stream. Plus the two-stage search semantics: quantized walk,
exact fp32 re-rank, bit-exact checkpoint round-trip.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    IndexParams,
    MaintenanceParams,
    SearchParams,
    Session,
    metrics,
    rebuild,
    search,
)
from repro.core.graph import NULL, grow_state
from repro.core.quantize import dequantize_rows, quantize_rows


def _assert_codes_consistent(state):
    """Invariant I5, checked bit-exactly from the host."""
    codes, scales = quantize_rows(state.vectors)
    present = np.asarray(state.present)
    got_c, got_s = np.asarray(state.codes), np.asarray(state.scales)
    want_c, want_s = np.asarray(codes), np.asarray(scales)
    np.testing.assert_array_equal(got_c[present], want_c[present])
    np.testing.assert_array_equal(got_s[present], want_s[present])
    assert (got_c[~present] == 0).all(), "freed slot kept stale codes"
    assert (got_s[~present] == 0.0).all(), "freed slot kept a stale scale"


def _params(capacity=128, dim=8, strategy="mask", **maint):
    return IndexParams(
        capacity=capacity, dim=dim, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2,
                            use_pallas=False),
        maintenance=MaintenanceParams(
            strategy=strategy, insert_chunk=16, delete_chunk=16, **maint),
    )


@pytest.mark.parametrize("strategy", ["mask", "global"])
def test_mixed_stream_codes_consistent(strategy):
    """Seeded insert/delete/consolidate/grow stream: I5 holds at every flush
    boundary, through tombstone scrubbing, slot reuse, and capacity growth."""
    dim = 8
    p = _params(
        capacity=64, dim=dim, strategy=strategy,
        consolidate_threshold=0.3, consolidate_strategy="global",
        max_capacity=512,
    )
    sess = Session(p, seed=3)
    rng = np.random.default_rng(17)
    alive = []
    for rnd in range(12):
        ids = sess.insert(rng.normal(size=(24, dim)).astype(np.float32))
        alive.extend(int(v) for v in np.asarray(ids.result()) if v != NULL)
        n_del = min(8, len(alive) - 4)
        pick = rng.choice(len(alive), size=n_del, replace=False)
        victims = np.asarray([alive[i] for i in pick], np.int32)
        for i in sorted(pick.tolist(), reverse=True):
            alive.pop(i)
        sess.delete(victims)
        sess.flush()
        _assert_codes_consistent(sess.state)   # every flush boundary
    assert sess.state.capacity > 64, "stream never exercised growth"
    if strategy == "mask":
        assert sess.timers.n_consolidations > 0, \
            "stream never exercised consolidation"
    # an explicit consolidation pass scrubs the remaining tombstones
    sess.consolidate()
    sess.flush()
    _assert_codes_consistent(sess.state)


def test_bulk_build_and_grow_pad_codes():
    """bulk_knn_build quantizes on construction; grow_state pads the new
    tier with the zero encoding on both capacity-axis layouts."""
    rng = np.random.default_rng(0)
    p = _params(capacity=48, dim=8)
    X = jnp.asarray(rng.normal(size=(48, 8)).astype(np.float32))
    valid = jnp.arange(48) < 40
    state = rebuild.bulk_knn_build(X, valid, p)
    _assert_codes_consistent(state)

    grown = grow_state(state, 97)
    assert grown.codes.shape == (97, 8) and grown.scales.shape == (97,)
    np.testing.assert_array_equal(
        np.asarray(grown.codes[:48]), np.asarray(state.codes))
    assert (np.asarray(grown.codes[48:]) == 0).all()
    assert (np.asarray(grown.scales[48:]) == 0.0).all()
    _assert_codes_consistent(grown)

    # stacked per-shard layout (ShardedSession): capacity axis is 1
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), state)
    stacked = dataclasses.replace(stacked)  # same meta, stacked data
    grown2 = grow_state(stacked, 97, axis=1)
    assert grown2.codes.shape == (2, 97, 8)
    assert grown2.scales.shape == (2, 97)
    np.testing.assert_array_equal(
        np.asarray(grown2.codes[:, :48]), np.asarray(stacked.codes))
    assert (np.asarray(grown2.codes[:, 48:]) == 0).all()


def test_quantized_checkpoint_roundtrip_bitexact(tmp_path):
    """save → restore → search on the quantized path is bit-exact: codes,
    scales, and the reported (ids, scores) of a quantized+rerank query."""
    p = dataclasses.replace(
        _params(capacity=128, dim=8),
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2,
                            use_pallas=False, quantized=True,
                            rerank_depth=16),
    )
    rng = np.random.default_rng(5)
    X = rng.normal(size=(90, 8)).astype(np.float32)
    Q = rng.normal(size=(12, 8)).astype(np.float32)

    sess = Session(p, seed=9, checkpoint_dir=tmp_path)
    ids = np.asarray(sess.insert(X).result())
    sess.delete(ids[:20])
    sess.flush()
    sess.save(step=1)
    a_ids, a_scores = sess.query(Q, k=10).result()

    other = Session(p, seed=9, checkpoint_dir=tmp_path)
    assert other.restore() == 1
    np.testing.assert_array_equal(
        np.asarray(sess.state.codes), np.asarray(other.state.codes))
    np.testing.assert_array_equal(
        np.asarray(sess.state.scales), np.asarray(other.state.scales))
    _assert_codes_consistent(other.state)
    b_ids, b_scores = other.query(Q, k=10).result()
    np.testing.assert_array_equal(np.asarray(a_ids), np.asarray(b_ids))
    np.testing.assert_array_equal(np.asarray(a_scores), np.asarray(b_scores))


def test_rerank_reports_exact_scores():
    """Stage-2 semantics: the reported scores of the quantized+rerank path
    are the EXACT fp32 similarities of the reported ids (not compressed)."""
    from repro.core import distances

    rng = np.random.default_rng(1)
    n, dim = 200, 12
    p = _params(capacity=n, dim=dim)
    X = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    state = rebuild.bulk_knn_build(X, jnp.ones((n,), bool), p)
    Q = jnp.asarray(rng.normal(size=(6, dim)).astype(np.float32))
    sp = SearchParams(pool_size=16, max_steps=48, num_starts=2,
                      use_pallas=False, quantized=True, rerank_depth=16)
    res = search.search_batch(state, Q, jax.random.PRNGKey(2), sp)
    ids, scores = np.asarray(res.ids), np.asarray(res.scores)
    for b in range(ids.shape[0]):
        for j in range(ids.shape[1]):
            if ids[b, j] == NULL:
                continue
            exact = float(distances.scores_vs_rows(
                state.vectors[ids[b, j]][None],
                state.sqnorms[ids[b, j]][None],
                Q[b], state.metric)[0])
            # jit vs eager accumulation order differs by a few ULP
            np.testing.assert_allclose(scores[b, j], exact, rtol=1e-4,
                                       atol=1e-4)
    # exact scores must be sorted descending per query (post-rerank order)
    finite = np.where(np.isfinite(scores), scores, -np.inf)
    assert (np.diff(finite, axis=1) <= 1e-6).all()


def test_quantized_rerank_recall_close_to_fp32():
    """The acceptance frontier in miniature: quantized walk + full-pool
    rerank holds recall@10 within 0.02 of the exact fp32 engine."""
    rng = np.random.default_rng(8)
    n, dim = 600, 16
    p = _params(capacity=n, dim=dim)
    X = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    state = rebuild.bulk_knn_build(X, jnp.ones((n,), bool), p)
    Q = jnp.asarray(rng.normal(size=(32, dim)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    _, true_ids = metrics.brute_force_topk(state, Q, 10)

    sp_fp = SearchParams(pool_size=24, max_steps=72, num_starts=2,
                         use_pallas=False)
    sp_q8 = dataclasses.replace(sp_fp, quantized=True, rerank_depth=24)
    rec_fp = float(metrics.recall_at_k(
        search.search_batch(state, Q, key, sp_fp).ids[:, :10], true_ids, 10))
    rec_q8 = float(metrics.recall_at_k(
        search.search_batch(state, Q, key, sp_q8).ids[:, :10], true_ids, 10))
    assert rec_q8 >= rec_fp - 0.02, (rec_fp, rec_q8)


def test_dequantized_rows_approximate_vectors():
    """End-to-end storage sanity: dequantize(state.codes) ≈ state.vectors
    within the per-row bound for every present slot."""
    rng = np.random.default_rng(13)
    p = _params(capacity=64, dim=8)
    sess = Session(p, seed=1)
    sess.insert(rng.normal(size=(50, 8)).astype(np.float32)).result()
    sess.flush()
    st = sess.state
    present = np.asarray(st.present)
    err = np.abs(np.asarray(dequantize_rows(st.codes, st.scales))
                 - np.asarray(st.vectors))[present]
    bound = np.asarray(st.scales)[present, None] * 0.5 + 1e-7
    assert (err <= bound).all()


def test_zero_vector_is_not_a_freed_slot():
    """Regression (v1 → v2 scheme): an exact-zero row used to quantize to
    (0 codes, 0.0 scale) — byte-identical to the freed-slot scrub, so I5
    could not tell a live zero vector from a dead slot. The v2 sentinel
    scale keeps the encodings disjoint without moving a single score."""
    from repro.core.quantize import ZERO_ROW_SCALE, scores_vs_codes

    rng = np.random.default_rng(0)
    X = rng.normal(size=(20, 8)).astype(np.float32)
    X[7] = 0.0  # a legitimately inserted zero vector
    codes, scales = quantize_rows(jnp.asarray(X))
    assert float(scales[7]) == float(ZERO_ROW_SCALE) > 0.0
    assert (np.asarray(codes[7]) == 0).all()
    # the sentinel is score-neutral: every metric sees similarity 0.0
    q = rng.normal(size=(8,)).astype(np.float32)
    for metric in ("l2", "ip", "cos"):
        s = scores_vs_codes(codes[7], scales[7], jnp.asarray(q), metric)
        assert float(s) == 0.0

    # end to end: the zero row stays present/searchable through a session,
    # and its encoding differs from slots the engine actually freed
    sess = Session(_params(capacity=64), seed=0)
    ids = sess.insert(X).result()
    sess.delete(ids[:3])
    sess.consolidate()
    sess.flush()
    st = sess.state
    zero_slot = int(ids[7])
    assert bool(np.asarray(st.present)[zero_slot])
    assert float(np.asarray(st.scales)[zero_slot]) == float(ZERO_ROW_SCALE)
    freed = np.asarray(st.scales)[np.asarray(ids[:3], int)]
    assert (freed == 0.0).all(), "freed slots keep the 0.0 scrub"
    _assert_codes_consistent(st)
    # the zero vector is exactly findable: an all-zero l2 query ranks it #1
    got, _ = sess.query(np.zeros((1, 8), np.float32), k=1).result()
    assert int(got[0, 0]) == zero_slot
