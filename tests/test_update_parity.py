"""Parity suite: vectorized update engine vs the sequential reference paths.

Pins the DESIGN.md §4 contract:
  · at B=1 with in-degree headroom, the batched insert pipeline is
    edge-set identical to ``insert_batch_reference`` (same slots, same
    adj/radj up to within-row permutation);
  · LOCAL/GLOBAL delete edge application matches the sequential reference
    appliers exactly when ``d_in`` is not under pressure (the repair *plans*
    are shared code, so this isolates the scatter-based application);
  · under in-degree pressure the paths may keep different edge subsets
    (scalar refusal vs truncation-by-rank) but both stay invariant-clean
    and within degree bounds;
  · batched inserts see the pre-batch snapshot + intra-batch candidates,
    and produce healthy, searchable graphs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from helpers import check_invariants, small_params
from repro.core import IPGMIndex, IndexParams, SearchParams
from repro.core import delete as delete_mod
from repro.core import insert as insert_mod
from repro.core.graph import NULL, init_graph


def _params(d_in=None, capacity=128, dim=8, d_out=6, pool=16):
    return IndexParams(
        capacity=capacity, dim=dim, d_out=d_out, d_in=d_in,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2),
    )


def _copy(state):
    return jax.tree.map(lambda x: x.copy() if hasattr(x, "copy") else x, state)


def _row_sets(arr):
    return [frozenset(int(v) for v in row if v != NULL) for row in np.asarray(arr)]


def _fresh(p):
    return init_graph(p.capacity, p.dim, d_out=p.d_out, d_in=p.eff_d_in,
                      metric=p.metric)


def _grow_pair(p, n, seed=0):
    """Build identical graphs through both insert paths, asserting parity."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p.dim)).astype(np.float32)
    st_new, st_ref = _fresh(p), _fresh(p)
    for i in range(n):
        key = jax.random.PRNGKey(1000 + i)
        v = jnp.asarray(X[i][None])
        val = jnp.ones((1,), bool)
        st_new, id_new = insert_mod.insert_batch(st_new, v, val, key, p)
        st_ref, id_ref = insert_mod.insert_batch_reference(
            st_ref, v, val, key, p
        )
        assert int(id_new[0]) == int(id_ref[0]), f"slot diverged at insert {i}"
        assert _row_sets(st_new.adj) == _row_sets(st_ref.adj), (
            f"adj diverged at insert {i}"
        )
        assert _row_sets(st_new.radj) == _row_sets(st_ref.radj), (
            f"radj diverged at insert {i}"
        )
    return st_new, st_ref, X


def test_insert_b1_parity_exact():
    """B=1, ample d_in: the pipelines are edge-set identical step by step."""
    p = _params(d_in=64)
    st_new, st_ref, _ = _grow_pair(p, 50)
    assert not check_invariants(st_new)
    assert not check_invariants(st_ref)


@pytest.mark.parametrize("strategy", ["local", "global", "rwalk"])
def test_delete_apply_parity_exact(strategy):
    """Shared repair plan + no d_in pressure ⇒ identical edge application."""
    p = _params(d_in=64)
    st, _, _ = _grow_pair(p, 50, seed=1)
    rng = np.random.default_rng(2)
    ids = jnp.asarray(rng.choice(50, size=16, replace=False).astype(np.int32))
    valid = jnp.ones((16,), bool)
    key = jax.random.PRNGKey(7)
    new = delete_mod._STRATEGY_FNS[strategy](_copy(st), ids, valid, key, p)
    ref = delete_mod._STRATEGY_FNS[strategy + "_reference"](
        _copy(st), ids, valid, key, p
    )
    assert _row_sets(new.adj) == _row_sets(ref.adj)
    assert _row_sets(new.radj) == _row_sets(ref.radj)
    assert not check_invariants(new)
    assert not check_invariants(ref)


@pytest.mark.parametrize("strategy", ["local", "global", "rwalk"])
def test_delete_apply_parity_b1_bit_exact(strategy):
    """B=1 with in-degree headroom: vectorized and reference appliers agree
    on every non-edge field bit-for-bit and on every row's edge set."""
    p = _params(d_in=64)
    st, _, _ = _grow_pair(p, 40, seed=5)
    for victim in (3, 17, 31):
        ids = jnp.asarray([victim], dtype=jnp.int32)
        valid = jnp.ones((1,), bool)
        key = jax.random.PRNGKey(100 + victim)
        new = delete_mod._STRATEGY_FNS[strategy](_copy(st), ids, valid, key, p)
        ref = delete_mod._STRATEGY_FNS[strategy + "_reference"](
            _copy(st), ids, valid, key, p
        )
        assert _row_sets(new.adj) == _row_sets(ref.adj)
        assert _row_sets(new.radj) == _row_sets(ref.radj)
        for field in ("alive", "present", "size", "stamps", "codes", "scales"):
            np.testing.assert_array_equal(
                np.asarray(getattr(new, field)), np.asarray(getattr(ref, field)),
                err_msg=f"{field} diverged deleting {victim}",
            )
        assert not check_invariants(new)
        assert not check_invariants(ref)


@pytest.mark.parametrize("strategy", ["local", "global", "rwalk"])
def test_delete_apply_under_pressure_bounded_deviation(strategy):
    """Tight d_in: refusal vs truncation-by-rank may keep different edges,
    but both sides stay invariant-clean and inside the degree bounds."""
    p = _params(d_in=8)  # tight: in-degree pressure guaranteed
    rng = np.random.default_rng(3)
    X = rng.normal(size=(60, p.dim)).astype(np.float32)
    st = _fresh(p)
    st, _ = insert_mod.insert_batch(
        st, jnp.asarray(X), jnp.ones((60,), bool), jax.random.PRNGKey(0), p
    )
    ids = jnp.asarray(rng.choice(60, size=20, replace=False).astype(np.int32))
    valid = jnp.ones((20,), bool)
    key = jax.random.PRNGKey(9)
    new = delete_mod._STRATEGY_FNS[strategy](_copy(st), ids, valid, key, p)
    ref = delete_mod._STRATEGY_FNS[strategy + "_reference"](
        _copy(st), ids, valid, key, p
    )
    assert not check_invariants(new)
    assert not check_invariants(ref)
    # bounded deviation: same number of repaired rows, in-degree ≤ d_in
    for state in (new, ref):
        in_deg = np.sum(np.asarray(state.radj) != NULL, axis=1)
        assert (in_deg <= p.eff_d_in).all()


def test_batched_insert_healthy_and_complete():
    """B=32 through the one-shot pipeline: everything lands, graph healthy,
    intra-batch members are reachable from each other."""
    p = _params(capacity=96)
    rng = np.random.default_rng(4)
    X = rng.normal(size=(64, p.dim)).astype(np.float32)
    st = _fresh(p)
    for lo in (0, 32):  # two micro-batches: second sees the first as snapshot
        st, ids = insert_mod.insert_batch(
            st, jnp.asarray(X[lo:lo + 32]), jnp.ones((32,), bool),
            jax.random.PRNGKey(lo), p,
        )
        assert (np.asarray(ids) != NULL).all()
    assert not check_invariants(st)
    assert int(st.size) == 64
    # every vertex has at least one out-edge (intra-batch candidates made
    # the very first, empty-snapshot batch connect to itself)
    out_deg = np.sum(np.asarray(st.adj)[:64] != NULL, axis=1)
    assert (out_deg > 0).all()


def test_batched_insert_capacity_refusal():
    """Lanes beyond capacity refuse deterministically (NULL ids)."""
    p = _params(capacity=20)
    st = _fresh(p)
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.normal(size=(32, p.dim)).astype(np.float32))
    st, ids = insert_mod.insert_batch(
        st, X, jnp.ones((32,), bool), jax.random.PRNGKey(0), p
    )
    arr = np.asarray(ids)
    assert (arr[:20] != NULL).all()
    assert (arr[20:] == NULL).all()
    assert not check_invariants(st)
    assert int(st.size) == 20


def test_batched_insert_masked_lanes_are_noops():
    """valid=False lanes must not allocate slots or touch the graph."""
    p = _params(capacity=64)
    st = _fresh(p)
    rng = np.random.default_rng(6)
    X = jnp.asarray(rng.normal(size=(16, p.dim)).astype(np.float32))
    valid = jnp.asarray([True, False] * 8)
    st, ids = insert_mod.insert_batch(
        st, X, valid, jax.random.PRNGKey(0), p
    )
    arr = np.asarray(ids)
    assert (arr[::2] != NULL).all()
    assert (arr[1::2] == NULL).all()
    assert int(st.size) == 8
    assert not check_invariants(st)


def test_incremental_radj_patch_matches_recompute_oracle():
    """After batched updates, the incrementally patched radj must equal a
    full ``rebuild_radj_rows`` recompute from adj (row-set identical) —
    pins the patch applier against the sort/segment recompute oracle."""
    from repro.core.graph import rebuild_radj_rows

    p = _params(capacity=96)
    st = _fresh(p)
    rng = np.random.default_rng(11)
    for lo in (0, 24):
        st, _ = insert_mod.insert_batch(
            st, jnp.asarray(rng.normal(size=(24, p.dim)).astype(np.float32)),
            jnp.ones((24,), bool), jax.random.PRNGKey(lo), p,
        )
    ids = jnp.asarray(rng.choice(48, size=12, replace=False).astype(np.int32))
    st = delete_mod.delete_global(
        _copy(st), ids, jnp.ones((12,), bool), jax.random.PRNGKey(5), p
    )
    oracle = rebuild_radj_rows(_copy(st), jnp.ones((p.capacity,), bool))
    assert _row_sets(st.radj) == _row_sets(oracle.radj)
    # no truncation happened (invariants already clean), so adj is untouched
    np.testing.assert_array_equal(np.asarray(st.adj), np.asarray(oracle.adj))


def test_insert_empty_batch_is_noop():
    p = small_params(capacity=32)
    idx = IPGMIndex(p, strategy="pure")
    rng = np.random.default_rng(12)
    idx.insert(rng.normal(size=(5, 8)).astype(np.float32))
    ids = idx.insert(np.zeros((0, 8), np.float32))
    assert ids.shape == (0,)
    assert idx.stats()["n_alive"] == 5


def test_reference_strategy_names_accepted_by_index():
    p = small_params(capacity=64)
    idx = IPGMIndex(p, strategy="global_reference")
    rng = np.random.default_rng(7)
    idx.insert(rng.normal(size=(30, 8)).astype(np.float32))
    idx.delete(np.arange(8))
    assert not check_invariants(idx.state)
    assert idx.stats()["n_alive"] == 22


def test_query_ragged_chunk_padding_matches_full():
    """Padded ragged chunks return the same ids as an unpadded query."""
    import dataclasses
    p = dataclasses.replace(small_params(capacity=128), query_chunk=16)
    idx = IPGMIndex(p, strategy="global", seed=3)
    rng = np.random.default_rng(8)
    idx.insert(rng.normal(size=(80, 8)).astype(np.float32))
    Q = rng.normal(size=(21, 8)).astype(np.float32)  # 16 + ragged 5
    ids, scores = idx.query(Q, k=5)
    assert ids.shape == (21, 5)
    # brute-force agreement on the top-1 for a healthy small graph
    _, true_ids = idx.ground_truth(Q, 5)
    agree = np.mean([
        t[0] in set(np.asarray(r).tolist()) for r, t in zip(ids, np.asarray(true_ids))
    ])
    assert agree > 0.8
