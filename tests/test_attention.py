"""Blockwise (flash-style) attention vs a naive softmax reference —
the most numerics-sensitive layer in the zoo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (
    apply_rope,
    blockwise_attention,
    decode_attention,
    softcap,
)


def naive_attention(q, k, v, *, causal, window, attn_softcap, scale=None):
    B, Sq, Hq, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, Hkv, g, dh)
    s = np.einsum("bqhgd,bkhd->bhgqk", np.asarray(qg, np.float32),
                  np.asarray(k, np.float32)) * scale
    if attn_softcap is not None:
        s = np.tanh(s / attn_softcap) * attn_softcap
    qpos = np.arange(Sq)[:, None]
    kpos = np.arange(Sk)[None, :]
    mask = np.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = np.where(mask[None, None, None], s, -np.inf)
    s = s - s.max(-1, keepdims=True)
    p = np.exp(s)
    p = p / np.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = np.einsum("bhgqk,bkhd->bhgqd", p, np.asarray(v, np.float32))
    return np.transpose(o, (0, 3, 1, 2, 4)).reshape(B, Sq, Hq, dh)


CASES = [
    dict(causal=True, window=None, attn_softcap=None),
    dict(causal=True, window=8, attn_softcap=None),
    dict(causal=True, window=16, attn_softcap=50.0),
    dict(causal=False, window=None, attn_softcap=None),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("shape", [(2, 32, 4, 2, 16), (1, 40, 6, 6, 8)])
def test_blockwise_matches_naive(case, shape):
    B, S, Hq, Hkv, dh = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)).astype(np.float32))
    got = blockwise_attention(q, k, v, block_q=8, block_kv=8, **case)
    want = naive_attention(q, k, v, **case)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_blockwise_grad_finite():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 16, 4, 8)).astype(np.float32))
    kv = jnp.asarray(rng.normal(size=(1, 16, 2, 8)).astype(np.float32))

    def loss(q, k, v):
        return jnp.sum(blockwise_attention(q, k, v, block_q=8, block_kv=8))

    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, kv, kv)
    for g in (gq, gk, gv):
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.sum(jnp.abs(g))) > 0


def test_decode_matches_last_row_of_full():
    """decode_attention(q_last, cache) == last row of full attention."""
    rng = np.random.default_rng(2)
    B, S, Hq, Hkv, dh = 2, 24, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, Hq, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)).astype(np.float32))
    full = blockwise_attention(q, k, v, causal=True, block_q=8, block_kv=8)
    dec = decode_attention(q[:, -1:], k, v, jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    # windowed decode
    dec_w = decode_attention(q[:, -1:], k, v, jnp.full((B,), S, jnp.int32),
                             window=6)
    full_w = blockwise_attention(q, k, v, causal=True, window=6,
                                 block_q=8, block_kv=8)
    np.testing.assert_allclose(np.asarray(dec_w[:, 0]),
                               np.asarray(full_w[:, -1]), rtol=2e-4, atol=2e-4)


def test_rope_properties():
    """RoPE preserves norms and is relative: <R(p)q, R(p+δ)k> depends on δ."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)).astype(np.float32))
    pos = jnp.arange(8)[None, :]
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relativity: dot of rotated pairs at equal offset is equal
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    def dot_at(pq, pk):
        qq = apply_rope(q, jnp.asarray([[pq]]), 10_000.0)
        kk = apply_rope(k, jnp.asarray([[pk]]), 10_000.0)
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(3, 5) - dot_at(10, 12)) < 1e-3


def test_softcap_bounds():
    x = jnp.asarray([-1e6, -3.0, 0.0, 3.0, 1e6])
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(float(y[2]), 0.0, atol=1e-7)
