"""DELETE-UPDATE-EDGES semantics per strategy (Alg 4–6 + RWALK)."""
import numpy as np
import pytest

from helpers import build_index, check_invariants
from repro.core import delete as delete_mod
from repro.core.graph import NULL


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return rng.normal(size=(240, 12)).astype(np.float32), rng


def _fresh(data, strategy):
    X, _ = data
    return build_index(X.copy(), strategy=strategy, capacity=320, d_out=8)


def test_pure_removes_all_incident_edges(data):
    idx = _fresh(data, "pure")
    dele = np.arange(0, 60)
    idx.delete(dele)
    adj = np.asarray(idx.state.adj)
    assert not np.isin(adj, dele).any()
    assert not check_invariants(idx.state)


def test_local_compensates_in_neighbors(data):
    X, rng = data
    pure = _fresh(data, "pure")
    local = _fresh(data, "local")
    dele = rng.choice(240, size=60, replace=False)
    pure.delete(dele)
    local.delete(dele)
    deg_pure = pure.stats()["avg_out_degree"]
    deg_local = local.stats()["avg_out_degree"]
    assert deg_local >= deg_pure, (
        "LOCAL must splice compensation edges that PURE drops"
    )
    assert not check_invariants(local.state)


def test_global_reconnects_with_fresh_candidates(data):
    X, rng = data
    idx = _fresh(data, "global")
    dele = rng.choice(240, size=60, replace=False)
    # record an in-neighbor of a deleted vertex
    radj = np.asarray(idx.state.radj)
    target = int(dele[0])
    in_nbrs = radj[target][radj[target] != NULL]
    in_nbrs = [u for u in in_nbrs if u not in dele]
    idx.delete(dele)
    assert not check_invariants(idx.state)
    adj = np.asarray(idx.state.adj)
    alive = np.asarray(idx.state.alive)
    for u in in_nbrs:
        row = adj[u][adj[u] != NULL]
        assert len(row) > 0, "repaired vertex must have edges"
        assert alive[row].all()


def test_rwalk_compensates_in_neighbors(data):
    """RWALK splices replacement edges the PURE drop leaves missing, and the
    replacements point only at surviving (alive) vertices."""
    X, rng = data
    pure = _fresh(data, "pure")
    rwalk = _fresh(data, "rwalk")
    dele = rng.choice(240, size=60, replace=False)
    radj = np.asarray(rwalk.state.radj)
    target = int(dele[0])
    in_nbrs = [u for u in radj[target][radj[target] != NULL] if u not in dele]
    pure.delete(dele)
    rwalk.delete(dele)
    assert not check_invariants(rwalk.state)
    deg_pure = pure.stats()["avg_out_degree"]
    deg_rwalk = rwalk.stats()["avg_out_degree"]
    assert deg_rwalk >= deg_pure, (
        "RWALK must splice compensation edges that PURE drops"
    )
    adj = np.asarray(rwalk.state.adj)
    alive = np.asarray(rwalk.state.alive)
    for u in in_nbrs:
        row = adj[u][adj[u] != NULL]
        assert alive[row].all(), "RWALK wired an edge into a deleted vertex"


@pytest.mark.parametrize(
    "strategy", delete_mod.STRATEGIES + delete_mod.REFERENCE_STRATEGIES
)
def test_duplicate_heavy_batch_keeps_size_exact(data, strategy):
    """Regression: the same slot id repeated within ONE delete batch passes
    _precheck on every lane (it checks the pre-batch alive); the size
    decrement must still count each distinct slot once, so ``size`` equals
    the true alive count afterwards — on every strategy."""
    idx = _fresh(data, strategy)
    rng = np.random.default_rng(42)
    victims = rng.choice(240, size=12, replace=False)
    dup = np.concatenate([victims, victims[::2], victims[:4], victims[:1]])
    rng.shuffle(dup)
    idx.delete(dup)
    alive = np.asarray(idx.state.alive)
    assert int(idx.state.size) == int(alive.sum()) == 240 - 12
    assert not alive[victims].any()
    assert not check_invariants(idx.state)


def test_strategies_preserve_recall_after_churn(data):
    """After delete+insert churn every repair strategy keeps usable recall."""
    X, rng = data
    Q = rng.normal(size=(48, 12)).astype(np.float32)
    for strategy in ("local", "global", "rwalk"):
        idx = _fresh(data, strategy)
        for _ in range(2):
            alive_ids = np.flatnonzero(np.asarray(idx.state.alive))
            idx.delete(rng.choice(alive_ids, size=40, replace=False))
            idx.insert(rng.normal(size=(40, 12)).astype(np.float32))
        r = idx.recall(Q, k=10)
        assert r > 0.55, f"{strategy}: recall collapsed to {r}"


def test_delete_nonexistent_is_noop(data):
    idx = _fresh(data, "global")
    before = idx.stats()
    idx.delete(np.asarray([300, 301]))  # never-inserted slots
    idx.delete(np.asarray([5]))
    idx.delete(np.asarray([5]))         # double delete
    after = idx.stats()
    assert after["n_alive"] == before["n_alive"] - 1
    assert not check_invariants(idx.state)
