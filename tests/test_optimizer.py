"""AdamW from scratch vs a literal numpy reference + schedule shape."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, schedule


def numpy_adamw(p, g, m, v, t, cfg):
    g = np.clip(1.0, None, cfg.grad_clip / max(np.linalg.norm(g), 1e-9)) * g
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1**t)
    vh = v / (1 - cfg.b2**t)
    lr = float(schedule(cfg, jnp.asarray(t)))
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), m, v


def test_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=1000,
                      weight_decay=0.1, grad_clip=1e9)
    rng = np.random.default_rng(0)
    p = rng.normal(size=(13,)).astype(np.float32)
    params = {"w": jnp.asarray(p)}
    opt = adamw_init(params)
    m = v = np.zeros_like(p)
    for t in range(1, 5):
        g = rng.normal(size=(13,)).astype(np.float32)
        params, opt, _ = adamw_update(params, {"w": jnp.asarray(g)}, opt, cfg)
        p, m, v = numpy_adamw(p, g, m, v, t, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), p, rtol=2e-4,
                                   atol=1e-6)


def test_grad_clip_engages():
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    _, _, metrics = adamw_update(
        params, {"w": jnp.full(4, 100.0)}, opt, cfg
    )
    assert float(metrics["grad_norm"]) > 100


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(schedule(cfg, jnp.asarray(100))) - 0.1) < 1e-3
    mid = float(schedule(cfg, jnp.asarray(55)))
    assert 0.1 < mid < 1.0


def test_loss_decreases_on_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 0.05 * l0
