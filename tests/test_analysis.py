"""Jaxpr cost analyzer: closed-form checks + agreement with XLA on loop-free
graphs (the basis of the §Roofline numbers)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.analysis import cost_of


def test_matmul_exact():
    A = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    B = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = cost_of(lambda a, b: a @ b, A, B, io_bytes=False)
    assert c.flops == 2 * 256 * 512 * 128
    assert c.hbm_bytes == 4 * (256 * 512 + 512 * 128 + 256 * 128)


def test_scan_multiplies_trip_count():
    A = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(a):
        def body(c, _):
            return c @ a, None
        c, _ = jax.lax.scan(body, a, None, length=7)
        return c

    c = cost_of(f, A, io_bytes=False)
    assert c.flops == 7 * 2 * 128**3


def test_agrees_with_xla_on_loop_free():
    """Sanity: analyzer within 2% of XLA cost_analysis for a plain matmul
    chain (no loops — the regime where XLA's number is trustworthy)."""
    A = jax.ShapeDtypeStruct((384, 384), jnp.float32)

    def f(a, b):
        return jnp.tanh(a @ b) @ b

    ours = cost_of(f, A, A, io_bytes=False).flops
    from repro import compat
    xla = compat.compiled_cost_analysis(jax.jit(f).lower(A, A).compile())["flops"]
    assert abs(ours - xla) / xla < 0.02


def test_gather_counts_bytes_not_flops():
    T = jax.ShapeDtypeStruct((1000, 64), jnp.float32)
    I = jax.ShapeDtypeStruct((32,), jnp.int32)
    c = cost_of(lambda t, i: t[i], T, I, io_bytes=False)
    assert c.gather_bytes == 32 * 64 * 4
    assert c.flops < 1e4


def test_lm_train_flops_close_to_6nd():
    """End-to-end: analyzer FLOPs for a smoke LM train step ≈ 6·N·D + attn."""
    from repro.configs import registry as reg
    from repro.models import transformer as tfm
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.steps import make_lm_train_step

    spec = reg.get_arch("qwen3-1.7b")
    cfg = spec.smoke_config()
    B, S = 4, 64
    params = jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg)
    )
    opt = jax.eval_shape(lambda: adamw_init(params))
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_),
    }
    c = cost_of(make_lm_train_step(cfg, AdamWConfig()), params, opt, batch)
    n_params = cfg.n_params()
    model_flops = 6 * n_params * B * S
    # causal blockwise attention wastes ≤2× on masked tiles; remat recomputes
    # ≤1 extra fwd; so expect 1× ≤ ratio ≤ ~3.5×
    ratio = c.flops / model_flops
    assert 0.9 < ratio < 4.0, ratio
