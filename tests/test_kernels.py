"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import (
    ref_gather_scores,
    ref_score_matrix,
    ref_score_topk,
)

SHAPES = [
    # (M, B, d, k)
    (300, 50, 200, 10),
    (512, 128, 128, 32),
    (1000, 17, 960, 5),
    (64, 8, 32, 4),
    (257, 33, 100, 16),
]
DTYPES = [jnp.float32, jnp.bfloat16]
METRICS = ["l2", "ip"]


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("metric", METRICS)
def test_score_matrix(shape, dtype, metric):
    M, B, d, _ = shape
    rng = np.random.default_rng(hash((shape, metric)) % 2**31)
    x = jnp.asarray(rng.normal(size=(M, d)), dtype)
    q = jnp.asarray(rng.normal(size=(B, d)), dtype)
    xsq = jnp.sum(x.astype(jnp.float32) ** 2, 1)
    got = ops.score_matrix(x, xsq, q, metric=metric)
    want = ref_score_matrix(x, xsq, q, metric)
    np.testing.assert_allclose(got, want, rtol=_tol(dtype), atol=_tol(dtype) * d)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("metric", METRICS)
def test_score_topk(shape, metric):
    M, B, d, k = shape
    rng = np.random.default_rng(hash((shape, metric, 1)) % 2**31)
    x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    xsq = jnp.sum(x * x, 1)
    gs, gi = ops.score_topk(x, xsq, q, k, metric=metric)
    ws, wi = ref_score_topk(x, xsq, q, k, metric)
    np.testing.assert_allclose(gs, ws, rtol=1e-4, atol=1e-3)
    assert (np.asarray(gi) == np.asarray(wi)).all()


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("metric", METRICS)
def test_gather_scores(shape, metric):
    M, B, d, _ = shape
    C = 24
    rng = np.random.default_rng(hash((shape, metric, 2)) % 2**31)
    x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    xsq = jnp.sum(x * x, 1)
    ids = jnp.asarray(rng.integers(-1, M, size=(B, C)).astype(np.int32))
    got = ops.gather_scores(x, xsq, ids, q, metric=metric)
    want = ref_gather_scores(x, xsq, jnp.maximum(ids, 0), q, metric)
    want = jnp.where(ids >= 0, want, -jnp.inf)
    g, w = np.asarray(got), np.asarray(want)
    assert ((g == -np.inf) == (w == -np.inf)).all()
    m = np.isfinite(g)
    np.testing.assert_allclose(g[m], w[m], rtol=1e-4, atol=1e-3)


def test_topk_all_negative_ip_padding():
    """Padded zero rows must not displace negative true scores (regression)."""
    rng = np.random.default_rng(3)
    M, B, d, k = 123, 9, 64, 7
    x = jnp.asarray(-np.abs(rng.normal(size=(M, d))).astype(np.float32))
    q = jnp.asarray(np.abs(rng.normal(size=(B, d))).astype(np.float32))
    xsq = jnp.sum(x * x, 1)
    gs, gi = ops.score_topk(x, xsq, q, k, metric="ip")
    ws, wi = ref_score_topk(x, xsq, q, k, "ip")
    assert (np.asarray(gi) == np.asarray(wi)).all()


def test_kernel_matches_core_search_scoring():
    """gather_scores == the scoring used inside beam expansion."""
    from repro.core import distances
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(100, 16)).astype(np.float32))
    tsq = distances.sqnorm(table)
    ids = jnp.asarray(rng.integers(0, 100, size=(4, 8)).astype(np.int32))
    q = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    got = ops.gather_scores(table, tsq, ids, q, metric="l2")
    want = jax.vmap(
        lambda i, qq: distances.scores_vs_rows(table[i], tsq[i], qq, "l2")
    )(ids, q)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
