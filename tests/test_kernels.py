"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import quantize_rows
from repro.kernels import ops
from repro.kernels.ref import (
    ref_gather_scores,
    ref_gather_scores_q8,
    ref_score_matrix,
    ref_score_topk,
)

SHAPES = [
    # (M, B, d, k)
    (300, 50, 200, 10),
    (512, 128, 128, 32),
    (1000, 17, 960, 5),
    (64, 8, 32, 4),
    (257, 33, 100, 16),
]
DTYPES = [jnp.float32, jnp.bfloat16]
METRICS = ["l2", "ip"]


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("metric", METRICS)
def test_score_matrix(shape, dtype, metric):
    M, B, d, _ = shape
    rng = np.random.default_rng(hash((shape, metric)) % 2**31)
    x = jnp.asarray(rng.normal(size=(M, d)), dtype)
    q = jnp.asarray(rng.normal(size=(B, d)), dtype)
    xsq = jnp.sum(x.astype(jnp.float32) ** 2, 1)
    got = ops.score_matrix(x, xsq, q, metric=metric)
    want = ref_score_matrix(x, xsq, q, metric)
    np.testing.assert_allclose(got, want, rtol=_tol(dtype), atol=_tol(dtype) * d)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("metric", METRICS)
def test_score_topk(shape, metric):
    M, B, d, k = shape
    rng = np.random.default_rng(hash((shape, metric, 1)) % 2**31)
    x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    xsq = jnp.sum(x * x, 1)
    gs, gi = ops.score_topk(x, xsq, q, k, metric=metric)
    ws, wi = ref_score_topk(x, xsq, q, k, metric)
    np.testing.assert_allclose(gs, ws, rtol=1e-4, atol=1e-3)
    assert (np.asarray(gi) == np.asarray(wi)).all()


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("metric", METRICS)
def test_gather_scores(shape, metric):
    M, B, d, _ = shape
    C = 24
    rng = np.random.default_rng(hash((shape, metric, 2)) % 2**31)
    x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    xsq = jnp.sum(x * x, 1)
    ids = jnp.asarray(rng.integers(-1, M, size=(B, C)).astype(np.int32))
    got = ops.gather_scores(x, xsq, ids, q, metric=metric)
    want = ref_gather_scores(x, xsq, jnp.maximum(ids, 0), q, metric)
    want = jnp.where(ids >= 0, want, -jnp.inf)
    g, w = np.asarray(got), np.asarray(want)
    assert ((g == -np.inf) == (w == -np.inf)).all()
    m = np.isfinite(g)
    np.testing.assert_allclose(g[m], w[m], rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("metric", METRICS)
def test_gather_scores_q8(shape, metric):
    """Compressed gather kernel vs its numpy-style oracle, including the
    invalid-id (-1 and >= M) → -inf contract shared with gather_scores."""
    M, B, d, _ = shape
    C = 24
    rng = np.random.default_rng(hash((shape, metric, 3)) % 2**31)
    x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    codes, scales = quantize_rows(x)
    ids = jnp.asarray(rng.integers(-1, M, size=(B, C)).astype(np.int32))
    got = ops.gather_scores_q8(codes, scales, ids, q, metric=metric)
    want = ref_gather_scores_q8(codes, scales, jnp.maximum(ids, 0), q, metric)
    want = jnp.where(ids >= 0, want, -jnp.inf)
    g, w = np.asarray(got), np.asarray(want)
    assert ((g == -np.inf) == (w == -np.inf)).all()
    m = np.isfinite(g)
    np.testing.assert_allclose(g[m], w[m], rtol=1e-4, atol=1e-3)


def test_gather_scores_q8_tracks_exact_scores():
    """Asymmetric distance on codes ≈ exact distance on the fp32 rows,
    within the quantization error bound (scale ≤ maxabs/127 per row)."""
    rng = np.random.default_rng(5)
    M, B, d, C = 300, 7, 48, 12
    x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    xsq = jnp.sum(x * x, 1)
    codes, scales = quantize_rows(x)
    ids = jnp.asarray(rng.integers(0, M, size=(B, C)).astype(np.int32))
    approx = np.asarray(ops.gather_scores_q8(codes, scales, ids, q))
    exact = np.asarray(ops.gather_scores(x, xsq, ids, q))
    # per-element dequant error ≤ scale/2 → score error is O(scale·(|q|₁+|x|₁))
    bound = np.asarray(scales)[np.asarray(ids)] * (
        2.0 * np.abs(np.asarray(q)).sum(1)[:, None]
        + np.abs(np.asarray(x)).sum(1)[np.asarray(ids)]
    )
    assert (np.abs(approx - exact) <= 0.5 * bound + 1e-4).all()


def test_topk_all_negative_ip_padding():
    """Padded zero rows must not displace negative true scores (regression)."""
    rng = np.random.default_rng(3)
    M, B, d, k = 123, 9, 64, 7
    x = jnp.asarray(-np.abs(rng.normal(size=(M, d))).astype(np.float32))
    q = jnp.asarray(np.abs(rng.normal(size=(B, d))).astype(np.float32))
    xsq = jnp.sum(x * x, 1)
    gs, gi = ops.score_topk(x, xsq, q, k, metric="ip")
    ws, wi = ref_score_topk(x, xsq, q, k, "ip")
    assert (np.asarray(gi) == np.asarray(wi)).all()


GROWN_TIERS = [2**5, 2**5 + 1, 3 * 2**5, 2**8, 2**8 + 1, 3 * 2**8]


@pytest.mark.parametrize("M", GROWN_TIERS)
def test_capacity_tier_sweep_masks_padded_tails(M):
    """Grown capacity tiers (DESIGN.md §9) hit non-power-of-two table sizes:
    {2^k, 2^k+1, 3·2^k} sweeps the block-grid padding of every kernel — no
    padded tail row may leak into scores, top-k results, or gathers."""
    d, B, k, C = 48, 13, 9, 17
    rng = np.random.default_rng(M)
    x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    xsq = jnp.sum(x * x, 1)

    got = ops.score_matrix(x, xsq, q)
    want = ref_score_matrix(x, xsq, q, "l2")
    assert got.shape == (B, M)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-2)

    gs, gi = ops.score_topk(x, xsq, q, k)
    ws, wi = ref_score_topk(x, xsq, q, k, "l2")
    assert (np.asarray(gi) == np.asarray(wi)).all()
    assert (np.asarray(gi) < M).all(), "padded tail row reported"

    ids = rng.integers(0, M, size=(B, C)).astype(np.int32)
    ids[0, 0] = M - 1  # the exact tier boundary
    ids[0, 1] = M      # one past it: must mask, not read the pad
    ids[0, 2] = -1
    ids = jnp.asarray(ids)
    got_g = ops.gather_scores(x, xsq, ids, q)
    want_g = ref_gather_scores(x, xsq, jnp.clip(ids, 0, M - 1), q, "l2")
    want_g = jnp.where((ids >= 0) & (ids < M), want_g, -jnp.inf)
    g, w = np.asarray(got_g), np.asarray(want_g)
    assert ((g == -np.inf) == (w == -np.inf)).all()
    m = np.isfinite(g)
    np.testing.assert_allclose(g[m], w[m], rtol=1e-4, atol=1e-3)

    # the compressed gather honors the same tier-boundary contract: id M-1
    # reads the last real row, ids M and -1 mask to -inf, no padded tail
    codes, scales = quantize_rows(x)
    got_q = ops.gather_scores_q8(codes, scales, ids, q)
    want_q = ref_gather_scores_q8(
        codes, scales, jnp.clip(ids, 0, M - 1), q, "l2")
    want_q = jnp.where((ids >= 0) & (ids < M), want_q, -jnp.inf)
    g, w = np.asarray(got_q), np.asarray(want_q)
    assert ((g == -np.inf) == (w == -np.inf)).all()
    m = np.isfinite(g)
    np.testing.assert_allclose(g[m], w[m], rtol=1e-4, atol=1e-3)


def test_kernel_matches_core_search_scoring():
    """gather_scores == the scoring used inside beam expansion."""
    from repro.core import distances
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(100, 16)).astype(np.float32))
    tsq = distances.sqnorm(table)
    ids = jnp.asarray(rng.integers(0, 100, size=(4, 8)).astype(np.int32))
    q = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    got = ops.gather_scores(table, tsq, ids, q, metric="l2")
    want = jax.vmap(
        lambda i, qq: distances.scores_vs_rows(table[i], tsq[i], qq, "l2")
    )(ids, q)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
