"""Fault tolerance: atomic checkpoints, exact resume, torn-write recovery."""
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "nested": {"b": jnp.arange(5), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(7, t, extra={"stream": {"step": 7}})
    got, extra = mgr.restore(None, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["stream"]["step"] == 7


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_torn_write_recovery(tmp_path):
    """A crash mid-save must not break restore (atomic publish)."""
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t)
    # simulate torn step: directory without manifest + stale LATEST
    bad = tmp_path / "step_000000000002"
    bad.mkdir()
    (tmp_path / "LATEST").write_text(bad.name)
    assert mgr.latest_step() == 1
    got, _ = mgr.restore(None, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    with pytest.raises(ValueError, match="mismatch"):
        mgr.restore(None, {"different": jnp.zeros(3)})


def _session_params():
    from repro.core import IndexParams, MaintenanceParams, SearchParams

    return IndexParams(
        capacity=128, dim=8, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(
            strategy="mask", insert_chunk=16, delete_chunk=16
        ),
    )


def _churn_then_consolidate(sess, rng):
    """The post-tombstone tail every run replays: consolidate, reuse the
    freed slots, query. Returns everything bit-comparable."""
    n = sess.consolidate()
    tail_ids = sess.insert(
        rng.normal(size=(10, 8)).astype(np.float32)).result()
    q_ids, q_scores = sess.query(
        rng.normal(size=(12, 8)).astype(np.float32), k=8).result()
    sess.flush()
    return n, tail_ids, q_ids, q_scores, np.asarray(sess.state.adj), \
        np.asarray(sess.state.present)


def test_consolidate_roundtrips_through_checkpoint(tmp_path):
    """Save mid-stream with pending tombstones → restore → consolidate is
    bit-exact vs never having checkpointed: graph, PRNG chains (op AND
    consolidation counters) and the freed-slot allocator all resume."""
    from repro.core import Session

    def build(ckpt_dir):
        rng = np.random.default_rng(6)
        sess = Session(_session_params(), seed=11, checkpoint_dir=ckpt_dir)
        X = rng.normal(size=(70, 8)).astype(np.float32)
        ids = sess.insert(X).result()
        sess.delete(ids[:25])  # tombstones pending at the checkpoint
        sess.flush()
        return sess, rng

    # run A: save mid-stream, then continue
    sess_a, rng_a = build(tmp_path / "a")
    sess_a.save(step=1)
    out_a = _churn_then_consolidate(sess_a, rng_a)

    # run B: the identical stream, never checkpointed — save must be pure
    sess_b, rng_b = build(tmp_path / "b")
    out_b = _churn_then_consolidate(sess_b, rng_b)
    for a, b in zip(out_a, out_b):
        np.testing.assert_array_equal(a, b)

    # run C: a fresh session restores A's checkpoint and replays the tail
    # (its host rng advanced to the same point A's was after the build)
    rng_c = np.random.default_rng(6)
    rng_c.normal(size=(70, 8))
    sess_c = Session(_session_params(), seed=11,
                     checkpoint_dir=tmp_path / "a")
    assert sess_c.restore() == 1
    out_c = _churn_then_consolidate(sess_c, rng_c)
    for a, c in zip(out_a, out_c):
        np.testing.assert_array_equal(a, c)
    assert out_c[0] == 25  # all pending tombstones consolidated post-restore


def test_checkpoint_rejects_consolidation_params_mismatch(tmp_path):
    """The params fingerprint covers the consolidation knobs: restoring a
    graph under a different trigger policy must be refused."""
    import dataclasses

    from repro.core import MaintenanceParams, Session

    p = _session_params()
    sess = Session(p, seed=0, checkpoint_dir=tmp_path)
    sess.insert(np.random.default_rng(0).normal(size=(20, 8))
                .astype(np.float32))
    sess.save(step=1)
    other = Session(
        dataclasses.replace(
            p, maintenance=dataclasses.replace(
                p.maintenance, consolidate_threshold=0.5)),
        seed=0, checkpoint_dir=tmp_path,
    )
    with pytest.raises(ValueError, match="fingerprint"):
        other.restore()


def _growth_params(capacity=32, max_capacity=512):
    from repro.core import IndexParams, MaintenanceParams, SearchParams

    return IndexParams(
        capacity=capacity, dim=8, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(
            strategy="mask", insert_chunk=16, delete_chunk=16,
            max_capacity=max_capacity,
        ),
    )


def test_checkpoint_capacity_roundtrip_across_growth(tmp_path):
    """Save at a grown tier C → restore into a fresh session configured at
    the *initial* tier → keep streaming (incl. a further growth): bit-exact
    vs never having checkpointed (DESIGN.md §9 capacity compatibility)."""
    from repro.core import Session

    def build(ckpt_dir):
        rng = np.random.default_rng(9)
        sess = Session(_growth_params(), seed=3, checkpoint_dir=ckpt_dir)
        X = rng.normal(size=(80, 8)).astype(np.float32)  # grows 32 → ≥ 80
        ids = sess.insert(X).result()
        sess.delete(ids[:10])  # tombstones pending at the checkpoint
        sess.flush()
        return sess, rng

    def tail(sess, rng):
        # forces the consolidate-then-grow arbitration and a further tier
        ids2 = sess.insert(
            rng.normal(size=(120, 8)).astype(np.float32)).result()
        q_ids, q_scores = sess.query(
            rng.normal(size=(12, 8)).astype(np.float32), k=8).result()
        sess.flush()
        return (np.asarray(ids2), q_ids, q_scores,
                np.asarray(sess.state.adj), np.asarray(sess.state.present),
                sess.state.capacity)

    sess_a, rng_a = build(tmp_path / "a")
    cap_saved = sess_a.state.capacity
    assert cap_saved > 32, "the build must have grown before saving"
    sess_a.save(step=1)
    out_a = tail(sess_a, rng_a)
    assert (out_a[0] != -1).all()
    assert out_a[5] > cap_saved, "the tail must have grown again"

    sess_b, rng_b = build(tmp_path / "b")  # never checkpointed
    out_b = tail(sess_b, rng_b)
    for a, b in zip(out_a, out_b):
        np.testing.assert_array_equal(a, b)

    # fresh session at the initial tier restores the grown checkpoint
    rng_c = np.random.default_rng(9)
    rng_c.normal(size=(80, 8))
    sess_c = Session(_growth_params(), seed=3, checkpoint_dir=tmp_path / "a")
    assert sess_c.restore() == 1
    assert sess_c.state.capacity == cap_saved
    out_c = tail(sess_c, rng_c)
    for a, c in zip(out_a, out_c):
        np.testing.assert_array_equal(a, c)


def test_checkpoint_capacity_shrink_rejected(tmp_path):
    """Geometry/policy fingerprints match ⇒ capacity is shrink-checked: a
    session whose initial tier exceeds the saved one must refuse (the
    allocator cannot shrink). A differing growth ceiling is a policy
    change → fingerprint mismatch, before any capacity check."""
    from repro.core import Session

    sess = Session(_growth_params(), seed=0, checkpoint_dir=tmp_path)
    rng = np.random.default_rng(0)
    sess.insert(rng.normal(size=(80, 8)).astype(np.float32)).result()
    assert sess.state.capacity > 32
    sess.save(step=1)

    bigger = Session(_growth_params(capacity=256), seed=0,
                     checkpoint_dir=tmp_path)
    with pytest.raises(ValueError, match="below this configuration"):
        bigger.restore()

    lower_ceiling = Session(_growth_params(max_capacity=64), seed=0,
                            checkpoint_dir=tmp_path)
    with pytest.raises(ValueError, match="fingerprint"):
        lower_ceiling.restore()  # ceiling is policy → fingerprinted


@pytest.mark.slow
def test_preempt_resume_exact(tmp_path):
    """Training 30 steps straight == train 20, preempt, resume 10 (bitwise
    stream + close losses)."""
    from repro.launch.train import train_lm

    full = train_lm("qwen3-1.7b", smoke=True, steps=30, batch=2, seq=16,
                    ckpt_dir=None, log_every=100)

    ck = tmp_path / "ck"
    train_lm("qwen3-1.7b", smoke=True, steps=30, batch=2, seq=16,
             ckpt_dir=str(ck), ckpt_every=10, preempt_at=20, log_every=100)
    resumed = train_lm("qwen3-1.7b", smoke=True, steps=30, batch=2, seq=16,
                       ckpt_dir=str(ck), resume=True, log_every=100)
    # the resumed run covers steps 20..29; compare final losses
    np.testing.assert_allclose(
        full["losses"][-1], resumed["losses"][-1], rtol=1e-4,
        err_msg="resume must reproduce the uninterrupted run",
    )


# ---------------------------------------------------------------------------
# corruption containment (DESIGN.md §11)
# ---------------------------------------------------------------------------

def test_keep_last_alias(tmp_path):
    from repro.checkpoint.manager import CheckpointManager as M

    mgr = M(tmp_path, keep=5, keep_last=2)  # keep_last wins
    t = _tree()
    for s in (1, 2, 3):
        mgr.save(s, t)
    assert mgr.all_steps() == [2, 3]


def test_corrupt_manifest_raises_typed(tmp_path):
    from repro.checkpoint.manager import CheckpointCorruptError

    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t, extra={"s": 1})
    mgr.save(2, t, extra={"s": 2})
    (tmp_path / "step_000000000002" / "manifest.json").write_text("{garbled")
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        mgr.restore(2, jax.tree.map(jnp.zeros_like, t))
    # restore(None) degrades to the previous complete step
    _, extra = mgr.restore(None, jax.tree.map(jnp.zeros_like, t))
    assert extra["s"] == 1


def test_truncated_shard_crc_detected(tmp_path):
    from repro.checkpoint.manager import CheckpointCorruptError

    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t, extra={"s": 1})
    mgr.save(2, t, extra={"s": 2})
    shard = tmp_path / "step_000000000002" / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:60])
    with pytest.raises(CheckpointCorruptError, match="crc|unreadable"):
        mgr.restore(2, jax.tree.map(jnp.zeros_like, t))
    got, extra = mgr.restore(None, jax.tree.map(jnp.zeros_like, t))
    assert extra["s"] == 1
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_flipped_shard_byte_crc_detected(tmp_path):
    """Same-length rot (a flipped bit, not a truncation): only the CRC can
    catch it — np.load may still parse the file."""
    from repro.checkpoint.manager import CheckpointCorruptError

    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(3, t)
    shard = tmp_path / "step_000000000003" / "shard_0.npz"
    data = bytearray(shard.read_bytes())
    data[len(data) // 2] ^= 0xFF
    shard.write_bytes(bytes(data))
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(3, jax.tree.map(jnp.zeros_like, t))


def test_all_steps_corrupt_aggregates(tmp_path):
    from repro.checkpoint.manager import CheckpointCorruptError

    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t)
    (tmp_path / "step_000000000001" / "shard_0.npz").unlink()
    with pytest.raises(CheckpointCorruptError, match="every checkpoint"):
        mgr.restore(None, jax.tree.map(jnp.zeros_like, t))


# ---------------------------------------------------------------------------
# cross-tier checkpoints (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _tiered_params():
    from repro.core import IndexParams, MaintenanceParams, SearchParams

    return IndexParams(
        capacity=128, dim=8, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(
            strategy="mask", insert_chunk=16, delete_chunk=16,
            max_capacity=512,
        ),
    )


def _tiered_tail(ts, rng):
    """Post-checkpoint tail: drain the fresh tier, reuse slots, query."""
    n = ts.merge()
    tail_ids = ts.insert(
        rng.normal(size=(10, 8)).astype(np.float32)).result()
    q_ids, q_scores = ts.query(
        rng.normal(size=(12, 8)).astype(np.float32), k=8).result()
    ts.flush()
    return (n, tail_ids, q_ids, q_scores,
            np.asarray(ts.main.state.adj), np.asarray(ts.main.state.present),
            np.asarray(ts.fresh.state.adj),
            np.asarray(ts.fresh.state.present),
            ts._fm.ext.copy(), ts._mm.ext.copy(), dict(ts._loc))


def test_tiered_checkpoint_roundtrip(tmp_path):
    """Save with both tiers populated and main tombstones pending → restore
    → merge is bit-exact vs never having checkpointed: both graphs, the
    slot→ext maps, the location table, and ALL key chains (per-tier op
    counters + the merge counter) resume."""
    from repro.core import TieredSession

    def build(ckpt_dir):
        rng = np.random.default_rng(9)
        ts = TieredSession(_tiered_params(), fresh_capacity=32, seed=5,
                           checkpoint_dir=ckpt_dir)
        ids = ts.insert(rng.normal(size=(30, 8)).astype(np.float32)).result()
        ts.merge()                   # main-resident now
        ts.delete(ids[:8])           # pending main tombstones
        ids2 = ts.insert(rng.normal(size=(12, 8))
                         .astype(np.float32)).result()
        ts.delete(ids2[:3])          # fresh hard-deletes
        ts.flush()
        return ts, rng

    ts_a, rng_a = build(tmp_path / "a")
    ts_a.save(step=1)
    out_a = _tiered_tail(ts_a, rng_a)

    ts_b, rng_b = build(tmp_path / "b")    # identical, never checkpointed
    out_b = _tiered_tail(ts_b, rng_b)
    for a, b in zip(out_a, out_b):
        if isinstance(a, dict):
            assert a == b
        else:
            np.testing.assert_array_equal(a, b)

    # fresh process restores A's checkpoint, replays the same tail
    rng_c = np.random.default_rng(9)
    rng_c.normal(size=(30, 8))
    rng_c.normal(size=(12, 8))
    ts_c = TieredSession(_tiered_params(), fresh_capacity=32, seed=5,
                         checkpoint_dir=tmp_path / "a")
    assert ts_c.restore() == 1
    ts_c.check_mirrors()            # mirrors rebuilt exactly from the ckpt
    out_c = _tiered_tail(ts_c, rng_c)
    for a, c in zip(out_a, out_c):
        if isinstance(a, dict):
            assert a == c
        else:
            np.testing.assert_array_equal(a, c)
    assert out_c[0] == 9            # 12 fresh - 3 deleted drained post-restore


def test_tiered_checkpoint_guards(tmp_path):
    """Fingerprint covers the tier split: a different fresh_capacity or a
    shrunk main capacity must refuse to restore."""
    import dataclasses

    from repro.core import TieredSession

    p = _tiered_params()
    ts = TieredSession(p, fresh_capacity=32, seed=0,
                       checkpoint_dir=tmp_path)
    ts.insert(np.random.default_rng(0).normal(size=(20, 8))
              .astype(np.float32))
    ts.save(step=1)
    other = TieredSession(p, fresh_capacity=64, seed=0,
                          checkpoint_dir=tmp_path)
    with pytest.raises(ValueError, match="fingerprint"):
        other.restore()
    # a saved main capacity below the configured initial capacity means the
    # checkpoint cannot host this configuration's graph — refused (the
    # other direction, saved >= configured, restores and re-pins, exactly
    # like Session's growth semantics)
    bigger = TieredSession(
        dataclasses.replace(p, capacity=512), fresh_capacity=32, seed=0,
        checkpoint_dir=tmp_path)
    with pytest.raises(ValueError, match="below this"):
        bigger.restore()
