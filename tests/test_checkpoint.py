"""Fault tolerance: atomic checkpoints, exact resume, torn-write recovery."""
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "nested": {"b": jnp.arange(5), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(7, t, extra={"stream": {"step": 7}})
    got, extra = mgr.restore(None, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["stream"]["step"] == 7


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_torn_write_recovery(tmp_path):
    """A crash mid-save must not break restore (atomic publish)."""
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t)
    # simulate torn step: directory without manifest + stale LATEST
    bad = tmp_path / "step_000000000002"
    bad.mkdir()
    (tmp_path / "LATEST").write_text(bad.name)
    assert mgr.latest_step() == 1
    got, _ = mgr.restore(None, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    with pytest.raises(ValueError, match="mismatch"):
        mgr.restore(None, {"different": jnp.zeros(3)})


@pytest.mark.slow
def test_preempt_resume_exact(tmp_path):
    """Training 30 steps straight == train 20, preempt, resume 10 (bitwise
    stream + close losses)."""
    from repro.launch.train import train_lm

    full = train_lm("qwen3-1.7b", smoke=True, steps=30, batch=2, seq=16,
                    ckpt_dir=None, log_every=100)

    ck = tmp_path / "ck"
    train_lm("qwen3-1.7b", smoke=True, steps=30, batch=2, seq=16,
             ckpt_dir=str(ck), ckpt_every=10, preempt_at=20, log_every=100)
    resumed = train_lm("qwen3-1.7b", smoke=True, steps=30, batch=2, seq=16,
                       ckpt_dir=str(ck), resume=True, log_every=100)
    # the resumed run covers steps 20..29; compare final losses
    np.testing.assert_allclose(
        full["losses"][-1], resumed["losses"][-1], rtol=1e-4,
        err_msg="resume must reproduce the uninterrupted run",
    )
