"""Elastic re-shard: shrink 4 shards → 3, recall survives, ids remap."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import IndexParams, SearchParams
from repro.core import search as search_mod
from repro.core.graph import NULL
from repro.distributed.elastic import gather_alive, reshard


def _stacked_index(n_shards, cap, dim, n_vecs, rng):
    """Build a stacked sharded state by hashing vectors to shards."""
    from repro.core import IPGMIndex
    params = IndexParams(
        capacity=cap, dim=dim, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
    )
    X = rng.normal(size=(n_vecs, dim)).astype(np.float32)
    shards = []
    for s in range(n_shards):
        idx = IPGMIndex(params, strategy="pure", seed=s)
        idx.insert(X[np.arange(n_vecs) % n_shards == s])
        shards.append(idx.state)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
    return stacked, params, X


def test_reshard_preserves_vectors_and_recall():
    rng = np.random.default_rng(0)
    stacked, params, X = _stacked_index(4, 64, 8, 120, rng)
    vecs_before, _ = gather_alive(stacked)
    assert vecs_before.shape[0] == 120

    new_params = IndexParams(
        capacity=64, dim=8, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
    )
    new_stacked, remap = reshard(stacked, params, new_params, 3)
    assert new_stacked.vectors.shape[0] == 3

    vecs_after, _ = gather_alive(new_stacked)
    assert vecs_after.shape[0] == 120
    # every original vector survives (set equality via sorted bytes)
    a = np.sort(vecs_before.round(5).view([("", vecs_before.dtype)] * 8), 0)
    b = np.sort(vecs_after.round(5).view([("", vecs_after.dtype)] * 8), 0)
    np.testing.assert_array_equal(a, b)

    # per-shard search still works: query shard 0 for one of its vectors
    shard0 = jax.tree.map(lambda x: x[0], new_stacked)
    q = jnp.asarray(vecs_after[:1])
    res = search_mod.search_one(
        shard0, q[0], jnp.asarray([0, 1], jnp.int32), new_params.search
    )
    assert int(res.ids[0]) != NULL


def test_reshard_growth_armed_stride():
    """Growth-armed sessions stride gids by max_capacity (DESIGN.md §9);
    the reshard remap must be keyed in that gid space on BOTH sides, or
    every id a caller held across the reshard translates wrongly."""
    from repro.core import MaintenanceParams

    rng = np.random.default_rng(2)
    stacked, _, _ = _stacked_index(2, 64, 8, 60, rng)
    armed = IndexParams(
        capacity=64, dim=8, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(max_capacity=256),
    )
    # the gids an armed session would have handed out for this state
    _, held_gids = gather_alive(stacked, stride=256)
    assert ((held_gids // 256) < 2).all() and ((held_gids % 256) < 64).all()
    assert held_gids.max() >= 256, "shard 1 gids must be stride-encoded"

    new_stacked, remap = reshard(stacked, armed, armed, 2)
    new_gids = remap[held_gids]
    assert (new_gids >= 0).all(), "every held gid must translate"
    # the emitted gids live in the new config's (armed) stride space and
    # match what gather_alive reads back off the new state
    _, readback = gather_alive(new_stacked, stride=256)
    assert set(new_gids.tolist()) == set(readback.tolist())


def test_reshard_capacity_guard():
    rng = np.random.default_rng(1)
    stacked, params, _ = _stacked_index(4, 64, 8, 120, rng)
    tiny = IndexParams(capacity=16, dim=8, d_out=6,
                       search=SearchParams(pool_size=8, max_steps=16,
                                           num_starts=2))
    with pytest.raises(ValueError, match="capacity"):
        reshard(stacked, params, tiny, 2)
