"""Kernel + search-engine + update-engine microbench — §6 analogue.

Three sections:

  · kernels — CPU wall-times for the XLA (jnp oracle) path at benchmark
    shapes + the structural properties of the Pallas kernels (VMEM working
    set per BlockSpec tile, HBM traffic model). Interpret-mode wall-clock is
    a Python emulation — meaningless as perf — so Pallas numbers reported
    here are the *derived* bytes/FLOPs per tile that the roofline uses, with
    allclose checked against the oracle (also enforced in tests).

  · search — the batched beam engine (core/search.py) vs the per-query
    reference path at serving batch sizes, beam_width ∈ {1, 4, 8}, Pallas
    gather on/off. Wall-clock QPS of the jnp path is the meaningful number
    on CPU; Pallas-on rows (interpret emulation) are recorded for
    correctness/recall only and timed at a reduced batch. Results land in
    BENCH_search.json so later PRs have a perf trajectory.

  · update — the vectorized update engine (DESIGN.md §4): inserts/s of the
    one-shot batched insert pipeline vs ``insert_batch_reference`` and
    deletes/s of the scatter-based LOCAL/GLOBAL edge appliers vs their
    sequential reference appliers, at streaming micro-batch sizes. Results
    land in BENCH_update.json (target: ≥3x on the insert path at batch 64).

  · recovery — the durability tax and the restart story (DESIGN.md §11):
    journal-on vs journal-off mixed-stream throughput (asserted ≤ 10%
    overhead at fsync="flush"), replay ops/s, recovery wall-time at three
    journal depths, and the crash-point matrix re-run end to end. Results
    land in BENCH_recover.json.

Usage: python benchmarks/kernel_bench.py [--smoke] [--out BENCH_search.json]
                                         [--update-out BENCH_update.json]
                                         [--recover-out BENCH_recover.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import gc
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import ref_score_matrix, ref_score_topk

SHAPES = [
    ("sift_1m_block", 8192, 256, 128, 10),
    ("glove_block", 8192, 256, 200, 10),
    ("gist_block", 4096, 128, 960, 10),
    ("retrieval_1m", 16384, 64, 64, 100),
]

SMOKE_SHAPES = [("smoke_block", 512, 32, 64, 10)]

_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_OUT = _ROOT / "BENCH_search.json"
DEFAULT_UPDATE_OUT = _ROOT / "BENCH_update.json"
DEFAULT_STREAM_OUT = _ROOT / "BENCH_stream.json"
DEFAULT_RECOVER_OUT = _ROOT / "BENCH_recover.json"
DEFAULT_TIER_OUT = _ROOT / "BENCH_tier.json"


def _time(f, *args, iters=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(shapes=SHAPES) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for name, M, B, d, k in shapes:
        x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
        xsq = jnp.sum(x * x, 1)
        f_mat = jax.jit(lambda x, s, q: ref_score_matrix(x, s, q, "l2"))
        f_top = jax.jit(lambda x, s, q: ref_score_topk(x, s, q, k, "l2"))
        t_mat = _time(lambda *a: (f_mat(*a),), x, xsq, q)
        t_top = _time(f_top, x, xsq, q)
        flops = 2.0 * M * B * d
        # Pallas tile model (block_b=128, block_m=256, block_d=128):
        vmem_tile = (256 * 128 + 128 * 128 + 128 * 256) * 4
        hbm_fused = (M * d + B * d) * 4 + B * k * 8       # fused top-k path
        hbm_unfused = (M * d + B * d + 2 * B * M) * 4     # matrix + topk read
        rows.append({
            "name": name,
            "us_per_call_xla_matrix": t_mat * 1e6,
            "us_per_call_xla_topk": t_top * 1e6,
            "gflops": flops / 1e9,
            "cpu_gflops_per_s": flops / t_mat / 1e9,
            "pallas_vmem_tile_bytes": vmem_tile,
            "hbm_bytes_fused": hbm_fused,
            "hbm_bytes_unfused": hbm_unfused,
            "fusion_traffic_saving": hbm_unfused / hbm_fused,
        })
        print(f"{name:16s} xla_matrix={t_mat*1e6:10.0f}us "
              f"xla_topk={t_top*1e6:10.0f}us "
              f"traffic_saving={hbm_unfused/hbm_fused:.2f}x")
    return rows


# ---------------------------------------------------------------------------
# batched beam engine vs per-query reference path
# ---------------------------------------------------------------------------

def _build_search_index(n, dim, d_out, pool, seed=0):
    """Bulk-built graph at benchmark scale (sequential insert would dominate
    the bench wall-clock; search QPS doesn't care how the graph was built)."""
    from repro.core import IndexParams, SearchParams, rebuild

    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, dim)).astype(np.float32)
    p = IndexParams(
        capacity=n, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2),
    )
    state = rebuild.bulk_knn_build(jnp.asarray(X), jnp.ones((n,), bool), p)
    jax.block_until_ready(state.adj)
    return state, rng


def _time_search(fn, state, q, key, sp, iters):
    fn(state, q, key, sp).ids.block_until_ready()  # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        res = fn(state, q, key, sp)
        jax.block_until_ready(res.ids)
        best = min(best, time.perf_counter() - t0)
    return best, res


def run_search(smoke: bool = False) -> dict:
    """Engine QPS rows + the headline batch-64 speedup (BENCH_search.json).

    The seed path carries a dense ``[B, capacity]`` visited bitmap per
    query batch, so its QPS degrades with index capacity; the batched
    beam engine's working set is capacity-independent (pool-membership
    dedup). The headline number is measured at a capacity where that
    difference is visible — exactly the regime the paper's workloads
    (100k–1M vertices) live in.
    """
    from repro.core import SearchParams
    from repro.core import metrics as metrics_mod
    from repro.core import search as search_mod
    from repro.kernels import ops

    n, dim, d_out, pool = (512, 16, 6, 16) if smoke else (8192, 64, 12, 32)
    batch = 16 if smoke else 64
    beams = (1, 4) if smoke else (1, 4, 8)
    iters = 2 if smoke else 5
    pallas_batch = 4 if smoke else 8  # interpret emulation: keep it tiny

    state, rng = _build_search_index(n, dim, d_out, pool)
    Q = jnp.asarray(rng.normal(size=(batch, dim)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    _, true_ids = ops.score_topk(state.vectors, state.sqnorms, Q, 10)

    def row(engine, fn, sp, q, tids, note=""):
        dt, res = _time_search(fn, state, q, key, sp, iters)
        rec = float(metrics_mod.recall_at_k(res.ids[:, :10], tids, 10))
        r = {
            "engine": engine,
            "beam_width": sp.beam_width,
            "use_pallas": bool(sp.use_pallas),
            "batch": int(q.shape[0]),
            "qps": q.shape[0] / dt,
            "recall_at_10": rec,
            "avg_hops": float(np.mean(np.asarray(res.n_expanded))),
        }
        if note:
            r["note"] = note
        print(f"{engine:22s} W={sp.beam_width} pallas={int(bool(sp.use_pallas))} "
              f"B={q.shape[0]:3d} qps={r['qps']:9.1f} recall@10={rec:.3f}")
        return r

    rows = []
    sp_ref = SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2)
    rows.append(row("reference_vmap", search_mod.search_batch_reference,
                    sp_ref, Q, true_ids))
    for w in beams:
        sp = SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2,
                          beam_width=w, use_pallas=False)
        rows.append(row("batched_beam", search_mod.search_batch, sp, Q, true_ids))

    # Pallas-on rows: interpret mode emulates the kernel grid in XLA loops —
    # wall-clock is NOT hardware-meaningful; recorded for correctness/recall
    # ONLY, and kept OUT of `rows` so trajectory tooling never averages the
    # QPS≈3 emulation numbers into the real engine trend
    Qp = Q[:pallas_batch]
    _, true_p = ops.score_topk(state.vectors, state.sqnorms, Qp, 10)
    interp_rows = []
    for w in beams[:2]:
        sp = SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2,
                          beam_width=w, use_pallas=True)
        interp_rows.append(row("batched_beam", search_mod.search_batch, sp,
                               Qp, true_p,
                               note="interpret emulation — not perf"))

    ref_qps = rows[0]["qps"]
    jnp_rows = [r for r in rows if r["engine"] == "batched_beam"
                and not r["use_pallas"]]
    best = max(jnp_rows, key=lambda r: r["qps"])
    record = {
        "config": {
            "n": n, "dim": dim, "d_out": d_out, "pool_size": pool,
            "batch": batch, "smoke": smoke, "backend": jax.default_backend(),
        },
        "rows": rows,
        "interpret_parity": {
            "note": "Pallas interpret-mode emulation: QPS is not perf, "
                    "recorded only as the gather-kernel parity/recall check",
            "rows": interp_rows,
        },
        "speedup_vs_reference": {
            "best_beam_width": best["beam_width"],
            "qps_reference": ref_qps,
            "qps_best": best["qps"],
            "speedup": best["qps"] / ref_qps,
        },
    }
    print(f"speedup@batch{batch}: {best['qps'] / ref_qps:.2f}x "
          f"(beam_width={best['beam_width']})")
    return record


# ---------------------------------------------------------------------------
# compressed two-stage search: recall-vs-QPS frontier at fixed memory
# (DESIGN.md §10) — appended to BENCH_search.json as "quantized_search"
# ---------------------------------------------------------------------------

def run_quantized_search(smoke: bool = False) -> dict:
    """The recall-vs-QPS frontier of the compressed scoring path.

    Same index / queries / beam settings across engines; the axes that move
    are hot-loop bytes per candidate (fp32 row + sqnorm vs int8 codes +
    scale) and the exact-rerank depth. Asserted (CI smoke runs this):

      · the quantized walk reads ≥ 3x fewer hot-loop bytes per candidate;
      · quantized + full-pool rerank holds recall@10 within 0.02 of the
        exact fp32 engine.

    CPU wall-clock caveat: the jnp fallback dequantizes in XLA, so int8
    QPS here measures engine overhead, not the bandwidth win — the bytes
    model is the hardware story, same convention as the kernel section.
    """
    from repro.core import SearchParams
    from repro.core import metrics as metrics_mod
    from repro.core import search as search_mod
    from repro.kernels import ops

    n, dim, d_out, pool = (512, 16, 6, 16) if smoke else (8192, 64, 12, 32)
    batch = 16 if smoke else 64
    iters = 2 if smoke else 5

    state, rng = _build_search_index(n, dim, d_out, pool)
    Q = jnp.asarray(rng.normal(size=(batch, dim)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    _, true_ids = ops.score_topk(state.vectors, state.sqnorms, Q, 10)

    def row(engine, sp):
        dt, res = _time_search(search_mod.search_batch, state, Q, key, sp,
                               iters)
        rec = float(metrics_mod.recall_at_k(res.ids[:, :10], true_ids, 10))
        r = {
            "engine": engine,
            "beam_width": sp.beam_width,
            "quantized": sp.quantized,
            "rerank_depth": sp.rerank_depth,
            "batch": batch,
            "qps": batch / dt,
            "recall_at_10": rec,
            "avg_hops": float(np.mean(np.asarray(res.n_expanded))),
        }
        print(f"{engine:22s} rerank={sp.rerank_depth:3d} "
              f"qps={r['qps']:9.1f} recall@10={rec:.3f}")
        return r

    sp0 = SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2,
                       beam_width=4, use_pallas=False)
    rows = [row("fp32_exact", sp0)]
    rows.append(row("quantized", dataclasses.replace(sp0, quantized=True)))
    depths = sorted({10, pool // 2, pool})
    rows += [
        row("quantized_rerank", dataclasses.replace(
            sp0, quantized=True, rerank_depth=r))
        for r in depths if r >= 10
    ]

    # hot-loop bytes per scored candidate: fp32 row + sqnorm cache vs int8
    # code row + scale (the rerank's exact reads are r per query, amortized
    # over the walk's ~hops·d_out candidates — reported separately)
    bytes_fp32 = dim * 4 + 4
    bytes_q8 = dim * 1 + 4
    ratio = bytes_fp32 / bytes_q8
    fp32_rec = rows[0]["recall_at_10"]
    best_rr = max((r for r in rows if r["rerank_depth"] > 0),
                  key=lambda r: r["recall_at_10"])
    assert ratio >= 3.0, (
        f"quantized path must move >= 3x fewer hot-loop bytes, got {ratio:.2f}x")
    assert best_rr["recall_at_10"] >= fp32_rec - 0.02, (
        f"quantized+rerank recall@10 {best_rr['recall_at_10']:.3f} fell more "
        f"than 0.02 below the fp32 engine {fp32_rec:.3f}")

    record = {
        "config": {
            "n": n, "dim": dim, "d_out": d_out, "pool_size": pool,
            "batch": batch, "beam_width": 4, "smoke": smoke,
            "backend": jax.default_backend(),
        },
        "rows": rows,
        "hot_loop_bytes_per_candidate": {
            "fp32": bytes_fp32, "int8": bytes_q8, "ratio": ratio,
        },
        "frontier": {
            "fp32_recall_at_10": fp32_rec,
            "best_rerank_recall_at_10": best_rr["recall_at_10"],
            "recall_delta": best_rr["recall_at_10"] - fp32_rec,
            "rerank_depth": best_rr["rerank_depth"],
        },
    }
    print(f"quantized_search bytes/candidate {bytes_fp32}->{bytes_q8} "
          f"({ratio:.2f}x) recall fp32={fp32_rec:.3f} "
          f"q8+rerank={best_rr['recall_at_10']:.3f}")
    return record


# ---------------------------------------------------------------------------
# vectorized update engine vs sequential reference paths (BENCH_update.json)
# ---------------------------------------------------------------------------

def _time_update(fn, *args, iters=3):
    out = fn(*args)           # compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run_update(smoke: bool = False) -> dict:
    """Insert/delete throughput of the vectorized update engine (DESIGN.md
    §4) vs the sequential reference paths, at the streaming micro-batch
    sizes of the paper's workloads.

    All benched functions are jitted *without* donation so the same
    pre-built state can be replayed every iteration (the timed op is pure).
    """
    from repro.core import IndexParams, SearchParams
    from repro.core import delete as delete_mod
    from repro.core import insert as insert_mod

    n, dim, d_out, pool = (256, 16, 6, 16) if smoke else (8192, 64, 12, 32)
    batch = 16 if smoke else 64
    iters = 2 if smoke else 3
    cap = n + 4 * batch

    params = IndexParams(
        capacity=cap, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2,
                            use_pallas=False),
    )
    state, rng = _build_update_index(n, dim, params)

    key = jax.random.PRNGKey(0)
    valid = jnp.ones((batch,), bool)
    vecs = jnp.asarray(rng.normal(size=(batch, dim)).astype(np.float32))

    jit_new = jax.jit(insert_mod.insert_batch_impl,
                      static_argnames=("params",))
    jit_ref = jax.jit(insert_mod.insert_batch_reference_impl,
                      static_argnames=("params",))
    t_new = _time_update(jit_new, state, vecs, valid, key, params, iters=iters)
    t_ref = _time_update(jit_ref, state, vecs, valid, key, params, iters=iters)
    insert_rows = [
        {"engine": "batched_pipeline", "batch": batch,
         "inserts_per_s": batch / t_new},
        {"engine": "sequential_reference", "batch": batch,
         "inserts_per_s": batch / t_ref},
    ]
    print(f"insert  batched={batch / t_new:9.1f}/s "
          f"reference={batch / t_ref:9.1f}/s speedup={t_ref / t_new:.2f}x")

    del_ids = jnp.asarray(
        rng.choice(n, size=batch, replace=False).astype(np.int32)
    )
    delete_rows = []
    for strategy in ("local", "global"):
        f_new = jax.jit(
            delete_mod._STRATEGY_FNS[strategy], static_argnames=("params",)
        )
        f_ref = jax.jit(
            delete_mod._STRATEGY_FNS[strategy + "_reference"],
            static_argnames=("params",),
        )
        td_new = _time_update(f_new, state, del_ids, valid, key, params,
                              iters=iters)
        td_ref = _time_update(f_ref, state, del_ids, valid, key, params,
                              iters=iters)
        delete_rows += [
            {"strategy": strategy, "engine": "scatter_apply", "batch": batch,
             "deletes_per_s": batch / td_new},
            {"strategy": strategy, "engine": "sequential_reference",
             "batch": batch, "deletes_per_s": batch / td_ref},
        ]
        print(f"delete/{strategy:6s} scatter={batch / td_new:9.1f}/s "
              f"reference={batch / td_ref:9.1f}/s "
              f"speedup={td_ref / td_new:.2f}x")

    record = {
        "config": {
            "n": n, "dim": dim, "d_out": d_out, "pool_size": pool,
            "batch": batch, "capacity": cap, "smoke": smoke,
            "backend": jax.default_backend(),
        },
        "insert_rows": insert_rows,
        "delete_rows": delete_rows,
        "notes": [
            "CPU wall-clock: the sequential LOCAL applier is us-level row "
            "surgery that XLA's CPU loop runs nearly for free, so the "
            "vectorized applier only breaks even on CPU (DESIGN.md §4); "
            "its win is on accelerators where each of the O(B*d_in) loop "
            "trips pays dispatch latency.",
        ],
        "speedup_vs_reference": {
            "insert": t_ref / t_new,
            "delete": {
                s: next(r["deletes_per_s"] for r in delete_rows
                        if r["strategy"] == s and r["engine"] == "scatter_apply")
                / next(r["deletes_per_s"] for r in delete_rows
                       if r["strategy"] == s
                       and r["engine"] == "sequential_reference")
                for s in ("local", "global")
            },
        },
    }
    print(f"update speedup@batch{batch}: insert "
          f"{record['speedup_vs_reference']['insert']:.2f}x")
    return record


def _build_update_index(n, dim, params):
    """Bulk-built graph with free-slot headroom for the insert bench."""
    from repro.core import rebuild

    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, dim)).astype(np.float32)
    padded = np.zeros((params.capacity, dim), np.float32)
    padded[:n] = X
    valid = jnp.arange(params.capacity) < n
    state = rebuild.bulk_knn_build(jnp.asarray(padded), valid, params)
    jax.block_until_ready(state.adj)
    return state, rng


# ---------------------------------------------------------------------------
# mixed-stream session API vs per-op facade (BENCH_stream.json)
# ---------------------------------------------------------------------------

def _stream_mix(rng, n, dim, batch, rounds, alive_ids):
    """§6-flavored serving mix: per round 8 query ops, 1 insert op, 1 delete
    op (batch items each), interleaved. Returns [(op, payload), ...]."""
    ops = []
    victims = rng.choice(alive_ids, size=(rounds, batch), replace=False)
    for r in range(rounds):
        qs = [rng.normal(size=(batch, dim)).astype(np.float32)
              for _ in range(8)]
        ins = rng.normal(size=(batch, dim)).astype(np.float32)
        ops += [("query", q) for q in qs[:4]]
        ops += [("insert", ins), ("query", qs[4]), ("query", qs[5]),
                ("delete", victims[r].astype(np.int32)),
                ("query", qs[6]), ("query", qs[7])]
    return ops


def run_stream(smoke: bool = False) -> dict:
    """Mixed-stream throughput: streaming Session (async, op IR, donated
    state) vs the per-op IPGMIndex facade (sync per op, ``query_chunk``
    padding) on the same op stream, parity-checked — the DESIGN.md §7
    acceptance number (target ≥ 1.5× items/s on the serving mix).
    """
    from repro.core import (
        IndexParams, IPGMIndex, MaintenanceParams, SearchParams, Session,
    )

    n, dim, d_out, pool = (256, 16, 6, 16) if smoke else (8192, 64, 12, 32)
    batch = 16 if smoke else 64
    rounds = 1 if smoke else 2
    strategies = ("mask", "local", "global")

    base_params = IndexParams(
        capacity=n + 4 * batch * rounds, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2,
                            use_pallas=False),
    )
    state0, rng = _build_update_index(n, dim, base_params)
    mix = _stream_mix(np.random.default_rng(7), n, dim, batch, rounds,
                      np.arange(n))
    n_items = sum(p.shape[0] for _, p in mix)

    def copy_state():
        return jax.tree.map(jnp.array, state0)

    def drive_facade(idx):
        out = []
        for op, payload in mix:
            if op == "query":
                out.append(idx.query(payload))
            elif op == "insert":
                idx.insert(payload)
            else:
                idx.delete(payload)
        return out

    def drive_session(sess):
        """Dispatch the whole stream, then consume every result — the timed
        region covers the same device-to-host materialization the facade
        pays inline, so the comparison is end-to-end on both sides."""
        handles = [
            (sess.query(p) if op == "query" else
             sess.insert(p) if op == "insert" else sess.delete(p))
            for op, p in mix
        ]
        results = [h.result() for h in handles]
        sess.flush()
        return results

    rows = []
    summaries = {}
    for strategy in strategies:
        params = dataclasses.replace(
            base_params, maintenance=MaintenanceParams(
                strategy=strategy, insert_chunk=batch, delete_chunk=batch)
        )
        # warm both paths (compile) on throwaway copies, then time fresh runs
        idx_w = IPGMIndex(params, seed=0, state=copy_state())
        drive_facade(idx_w)
        t0 = time.perf_counter()
        idx = IPGMIndex(params, seed=0, state=copy_state())
        f_results = drive_facade(idx)
        t_facade = time.perf_counter() - t0

        sess_w = Session(params, seed=0, state=copy_state())
        drive_session(sess_w)
        t0 = time.perf_counter()
        sess = Session(params, seed=0, state=copy_state())
        s_results = drive_session(sess)
        t_session = time.perf_counter() - t0

        # ---- parity: same query ids/scores at every stream position, same
        # graph after the stream
        parity_ids = parity_scores = True
        s_queries = [r for (op, _), r in zip(mix, s_results) if op == "query"]
        for (f_ids, f_scores), (s_ids, s_scores) in zip(f_results, s_queries):
            parity_ids &= bool(np.array_equal(np.asarray(f_ids), s_ids))
            parity_scores &= bool(
                np.allclose(f_scores, s_scores, rtol=1e-5, atol=1e-6))
        alive_equal = bool(np.array_equal(
            np.asarray(idx.state.alive), np.asarray(sess.state.alive)))
        adj_equal = bool(np.array_equal(
            np.asarray(idx.state.adj), np.asarray(sess.state.adj)))

        row = {
            "strategy": strategy,
            "n_ops": len(mix),
            "n_items": n_items,
            "facade_items_per_s": n_items / t_facade,
            "session_items_per_s": n_items / t_session,
            "speedup": t_facade / t_session,
            "parity": {
                "query_ids_equal": parity_ids,
                "query_scores_close": parity_scores,
                "alive_set_equal": alive_equal,
                "adj_equal": adj_equal,
            },
        }
        rows.append(row)
        summaries[strategy] = sess.timers.to_dict()
        print(f"stream/{strategy:6s} facade={row['facade_items_per_s']:9.1f}/s "
              f"session={row['session_items_per_s']:9.1f}/s "
              f"speedup={row['speedup']:.2f}x parity={parity_ids and alive_equal}")

    record = {
        "config": {
            "n": n, "dim": dim, "d_out": d_out, "pool_size": pool,
            "batch": batch, "rounds": rounds,
            "mix": "per round: 8 query / 1 insert / 1 delete ops",
            "smoke": smoke, "backend": jax.default_backend(),
        },
        "rows": rows,
        "session_timers": summaries,
        "speedup_vs_facade": {r["strategy"]: r["speedup"] for r in rows},
        "headline": max(
            ({"strategy": r["strategy"], "speedup": r["speedup"],
              "parity_ok": all(r["parity"].values())} for r in rows),
            key=lambda h: h["speedup"],
        ),
        "notes": [
            "facade = per-op IPGMIndex (sync per op; queries padded to "
            "query_chunk=256, its documented compile-shape contract since "
            "the seed/PR-2 API — part of what the session's right-sized "
            "op-IR chunks remove); session = streaming op-IR dispatch, "
            "every result materialized inside the timed region, one flush. "
            "GLOBAL rows are repair-search-bound (the delete op dominates "
            "device time), so the API-layer speedup is smallest there.",
        ],
    }
    return record


# ---------------------------------------------------------------------------
# long-stream sustainability: auto-consolidation on an effectively infinite
# 8q:1i:1d mask-delete stream (DESIGN.md §8) — appended to BENCH_stream.json
# ---------------------------------------------------------------------------

def run_long_stream(smoke: bool = False) -> dict:
    """Sustainability run: a long 8q:1i:1d stream under the MASK strategy
    with the auto-consolidation trigger armed.

    Without consolidation this stream is unservable: tombstones exhaust the
    fixed capacity after ``(capacity - n) / batch`` rounds (inserts refuse)
    and the masked fraction grows monotonically (the §5.2 memory issue). With
    ``consolidate_threshold`` set, the session compacts at trigger points and
    the stream runs forever. Asserted (CI smoke runs this):

      · the tombstone share returns below the threshold (+ one trigger
        window of slack) at every measurement window;
      · post-consolidation recall@10 stays within 1 point of the pre-delete
        baseline;
      · items/s does not decay across the stream (second half vs first).

    A short no-consolidation control documents the contrast: monotone
    masked-fraction growth and insert refusals once capacity exhausts.
    """
    from repro.core import (
        IndexParams, MaintenanceParams, SearchParams, Session,
    )
    from repro.core import metrics as metrics_mod
    from repro.core.graph import NULL

    n, dim, d_out, pool = 1024, 16, 12, 32
    batch = 8
    rounds = 60 if smoke else 2000
    window = 10 if smoke else 100
    threshold = 0.2
    cap = 2048
    params = IndexParams(
        capacity=cap, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2,
                            use_pallas=False),
        # ef_construction > ef_search (HNSW practice): insert wiring AND the
        # GLOBAL repair searches run at pool 64, which is what keeps graph
        # quality from drifting under indefinite churn (measured: pool-32
        # construction loses ~2 recall points by round 100; pool-64 holds
        # the baseline flat through 500+ rounds)
        insert_search=SearchParams(pool_size=64, max_steps=128, num_starts=2,
                                   use_pallas=False),
        maintenance=MaintenanceParams(
            strategy="mask", insert_chunk=batch, delete_chunk=batch,
            consolidate_threshold=threshold, consolidate_strategy="global",
            consolidate_chunk=32,
        ),
    )
    rng = np.random.default_rng(11)
    X = rng.normal(size=(n, dim)).astype(np.float32)
    probes = rng.normal(size=(64, dim)).astype(np.float32)

    def probe_recall(sess):
        ids, _ = sess.query(probes, k=10).result()
        _, true_ids = metrics_mod.brute_force_topk(
            sess.state, jnp.asarray(probes), 10)
        return float(metrics_mod.recall_at_k(jnp.asarray(ids), true_ids, 10))

    def drive(sess, rounds, rng, alive_pool, windows):
        t_win = time.perf_counter()
        items_win = 0
        refused_win = 0
        for r in range(rounds):
            for _ in range(8):
                sess.query(rng.normal(size=(batch, dim)).astype(np.float32))
            ins = sess.insert(
                rng.normal(size=(batch, dim)).astype(np.float32))
            # the no-consolidation control eventually drains its alive pool
            # (refused inserts stop replenishing it) — keep a floor so the
            # stream stays well-formed while the masked fraction runs away
            n_del = min(batch, max(len(alive_pool) - batch, 0))
            pick = rng.choice(len(alive_pool), size=n_del, replace=False)
            victims = np.asarray([alive_pool[i] for i in pick], np.int32)
            for i in sorted(pick.tolist(), reverse=True):
                alive_pool.pop(i)
            sess.delete(victims)
            new_ids = np.asarray(ins.result())
            alive_pool.extend(int(v) for v in new_ids if v != NULL)
            items_win += 10 * batch
            refused_win += int((new_ids == NULL).sum())
            if (r + 1) % window == 0:
                sess.flush()
                dt = time.perf_counter() - t_win
                st = sess.state
                n_masked = int(jnp.sum(st.masked))
                n_present = int(jnp.sum(st.present))
                windows.append({
                    "round": r + 1,
                    "items_per_s": items_win / dt,
                    "masked_fraction": n_masked / max(n_present, 1),
                    "n_refused_inserts": refused_win,
                    "recall_at_10": probe_recall(sess),
                    "n_consolidations": sess.timers.n_consolidations,
                })
                t_win = time.perf_counter()
                items_win = 0
                refused_win = 0
        return windows

    sess = Session(params, seed=0)
    alive_pool = [int(v) for v in np.asarray(sess.insert(X).result())]
    baseline_recall = probe_recall(sess)  # pre-delete baseline
    windows = drive(sess, rounds, rng, alive_pool, [])

    # ---- no-consolidation control: same mix, trigger disarmed. Short —
    # the point is the monotone masked growth until capacity exhausts
    # (one window beyond the exhaustion round documents the refusals).
    ctrl_rounds = min(rounds, (cap - n) // batch + window)
    ctrl_params = dataclasses.replace(
        params, maintenance=dataclasses.replace(
            params.maintenance, consolidate_threshold=None))
    ctrl = Session(ctrl_params, seed=0)
    ctrl_pool = [int(v) for v in np.asarray(ctrl.insert(X).result())]
    # advance the control rng past the base/probe draws so it replays the
    # armed run's exact stream data — the contrast is like-for-like
    ctrl_rng = np.random.default_rng(11)
    ctrl_rng.normal(size=(n, dim))
    ctrl_rng.normal(size=(64, dim))
    ctrl_windows = drive(ctrl, ctrl_rounds, ctrl_rng, ctrl_pool, [])

    # ---- acceptance asserts (ISSUE 4): bounded tombstones, recall held,
    # throughput sustained
    sess.consolidate()  # drain the in-flight tombstones, then measure
    sess.flush()
    final_recall = probe_recall(sess)
    tail_recall = float(np.mean([w["recall_at_10"] for w in windows[-3:]]))
    worst_fraction = max(w["masked_fraction"] for w in windows)
    half = len(windows) // 2
    ips_first = float(np.median([w["items_per_s"] for w in windows[:half]]))
    ips_second = float(np.median([w["items_per_s"] for w in windows[half:]]))
    # the flush closing every window is a trigger point, so a settled window
    # can never sit at/above the threshold
    assert worst_fraction <= threshold + 1e-6, (
        f"tombstone fraction {worst_fraction:.3f} escaped the "
        f"{threshold} threshold")
    assert final_recall >= baseline_recall - 0.01, (
        f"post-consolidation recall {final_recall:.3f} fell more than "
        f"1 point below the pre-delete baseline {baseline_recall:.3f}")
    assert ips_second >= 0.5 * ips_first, (
        f"items/s decayed: {ips_first:.1f} -> {ips_second:.1f}")
    ctrl_fracs = [w["masked_fraction"] for w in ctrl_windows]
    assert ctrl_fracs == sorted(ctrl_fracs), \
        "control masked fraction must grow monotonically"

    record = {
        "config": {
            "n": n, "dim": dim, "d_out": d_out, "pool_size": pool,
            "batch": batch, "capacity": cap, "rounds": rounds,
            "n_ops": rounds * 10,
            "mix": "per round: 8 query / 1 insert / 1 delete ops (mask)",
            "consolidate_threshold": threshold,
            "consolidate_strategy": "global", "consolidate_chunk": 32,
            "smoke": smoke, "backend": jax.default_backend(),
        },
        "baseline_recall_at_10": baseline_recall,
        "windows": windows,
        "control_no_consolidation": {
            "rounds": ctrl_rounds,
            "windows": ctrl_windows,
            "final_masked_fraction": ctrl_fracs[-1] if ctrl_fracs else 0.0,
        },
        "summary": {
            "final_recall_at_10": final_recall,
            "tail_windows_recall_at_10": tail_recall,
            "recall_delta_vs_baseline": final_recall - baseline_recall,
            "worst_masked_fraction": worst_fraction,
            "items_per_s_first_half": ips_first,
            "items_per_s_second_half": ips_second,
            "throughput_ratio": ips_second / max(ips_first, 1e-9),
            "n_consolidations": sess.timers.n_consolidations,
            "n_consolidated": sess.timers.n_consolidated,
            "timers": sess.timers.to_dict(),
        },
    }
    print(f"long_stream rounds={rounds} consolidations="
          f"{sess.timers.n_consolidations} "
          f"worst_masked={worst_fraction:.3f} "
          f"recall {baseline_recall:.3f}->{final_recall:.3f} "
          f"items/s {ips_first:.1f}->{ips_second:.1f}")
    return record


# ---------------------------------------------------------------------------
# net-growth sustainability: the dynamic capacity engine on an insert-heavy
# stream (DESIGN.md §9) — appended to BENCH_stream.json as "growth_stream"
# ---------------------------------------------------------------------------

def run_growth_stream(smoke: bool = False) -> dict:
    """Growth run: an insert-heavy 4i:4q:1d MASK stream with the growth
    gate armed, from capacity 1024 until the index has grown ≥ 8× and
    processed ≥ 20k stream items.

    Without growth this stream is unservable — net-positive insert traffic
    exhausts any fixed capacity and ``insert`` starts refusing. With
    ``max_capacity`` armed the session moves through geometric capacity
    tiers at insert-dispatch boundaries. Asserted (CI smoke runs this):

      · ZERO insert refusals across the whole stream (``timers.n_refused``);
      · ≤ ceil(log2(final/initial)) growth recompiles (geometric tiers);
      · terminal recall@10 within 1 point of a control session built
        statically at the final capacity and driven through the identical
        logical stream.
    """
    import math

    from repro.core import (
        IndexParams, MaintenanceParams, SearchParams, Session,
    )
    from repro.core import metrics as metrics_mod
    from repro.core.graph import NULL

    n0, dim, d_out, pool = 512, 16, 12, 24
    batch = 16
    init_cap = 1024
    growth_target = 8 * init_cap
    min_items = 20_160 if smoke else 40_320
    max_rounds = 400 if smoke else 800  # safety stop, never the exit path
    threshold = 0.25
    params = IndexParams(
        capacity=init_cap, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2,
                            use_pallas=False),
        # ef_construction > ef_search (§8's churn-resistance note): wiring
        # quality is what keeps the grown and static graphs comparable
        insert_search=SearchParams(pool_size=48, max_steps=96, num_starts=2,
                                   use_pallas=False),
        maintenance=MaintenanceParams(
            strategy="mask", insert_chunk=batch, delete_chunk=batch,
            consolidate_threshold=threshold, consolidate_strategy="global",
            consolidate_chunk=batch,
            growth_factor=2.0, max_capacity=16 * init_cap,
        ),
    )
    rng0 = np.random.default_rng(23)
    X = rng0.normal(size=(n0, dim)).astype(np.float32)
    probes = rng0.normal(size=(64, dim)).astype(np.float32)

    def probe_recall(sess):
        ids, _ = sess.query(probes, k=10).result()
        _, true_ids = metrics_mod.brute_force_topk(
            sess.state, jnp.asarray(probes), 10)
        return float(metrics_mod.recall_at_k(jnp.asarray(ids), true_ids, 10))

    def drive(sess, rng):
        """4i:4q:1d rounds until the growth+items targets are both met.

        Deletes address *positions* in the session's own alive pool, so a
        control run replaying the same rng performs the identical logical
        stream even where physical slot assignment diverges."""
        alive_pool = [int(v) for v in np.asarray(sess.insert(X).result())]
        items, rounds, windows = 0, 0, []
        t_win = time.perf_counter()
        items_win = 0
        while True:
            ins_handles = []
            for _ in range(4):
                sess.query(rng.normal(size=(batch, dim)).astype(np.float32))
                ins_handles.append(sess.insert(
                    rng.normal(size=(batch, dim)).astype(np.float32)))
            n_del = min(batch, max(len(alive_pool) - batch, 0))
            pick = rng.choice(len(alive_pool), size=n_del, replace=False)
            victims = np.asarray([alive_pool[i] for i in pick], np.int32)
            for i in sorted(pick.tolist(), reverse=True):
                alive_pool.pop(i)
            sess.delete(victims)
            for h in ins_handles:
                alive_pool.extend(
                    int(v) for v in np.asarray(h.result()) if v != NULL)
            items += 9 * batch
            items_win += 9 * batch
            rounds += 1
            done = (sess.state.capacity >= growth_target
                    and items >= min_items) or rounds >= max_rounds
            if rounds % 25 == 0 or done:
                sess.flush()
                windows.append({
                    "round": rounds,
                    "items": items,
                    "items_per_s": items_win / max(
                        time.perf_counter() - t_win, 1e-9),
                    "capacity": sess.state.capacity,
                    "n_alive": len(alive_pool),
                    "n_grows": sess.timers.n_grows,
                    "n_refused": sess.timers.n_refused,
                    "n_consolidations": sess.timers.n_consolidations,
                })
                t_win = time.perf_counter()
                items_win = 0
            if done:
                break
        sess.flush()
        return items, rounds, windows

    sess = Session(params, seed=0)
    items, rounds, windows = drive(sess, np.random.default_rng(29))
    final_cap = sess.state.capacity
    grown_recall = probe_recall(sess)

    # ---- control: statically sized at the final tier, identical logical
    # stream — the recall yardstick growth must stay within 1 point of
    ctrl_params = dataclasses.replace(
        params, capacity=final_cap,
        maintenance=dataclasses.replace(params.maintenance,
                                        max_capacity=None))
    ctrl = Session(ctrl_params, seed=0)
    ctrl_items, _, _ = drive(ctrl, np.random.default_rng(29))
    static_recall = probe_recall(ctrl)

    # ---- acceptance asserts (ISSUE 5): zero refusals, bounded recompiles,
    # growth-path recall within 1 point of the static control
    recompile_bound = math.ceil(math.log2(final_cap / init_cap))
    assert sess.timers.n_refused == 0, (
        f"{sess.timers.n_refused} inserts refused on an armed session")
    assert final_cap >= growth_target and items >= min_items, (
        f"stream stopped early: capacity {final_cap}, items {items}")
    assert sess.timers.n_grows <= recompile_bound, (
        f"{sess.timers.n_grows} growth recompiles exceed the "
        f"ceil(log2({final_cap}/{init_cap})) = {recompile_bound} bound")
    assert ctrl.timers.n_refused == 0 and ctrl.timers.n_grows == 0
    assert grown_recall >= static_recall - 0.01, (
        f"grown-index recall {grown_recall:.3f} fell more than 1 point "
        f"below the statically-sized control {static_recall:.3f}")

    record = {
        "config": {
            "n0": n0, "dim": dim, "d_out": d_out, "pool_size": pool,
            "batch": batch, "initial_capacity": init_cap,
            "growth_target": growth_target, "max_capacity": 16 * init_cap,
            "growth_factor": 2.0, "consolidate_threshold": threshold,
            "mix": "per round: 4 insert / 4 query / 1 delete ops (mask)",
            "min_items": min_items, "smoke": smoke,
            "backend": jax.default_backend(),
        },
        "rounds": rounds,
        "items": items,
        "windows": windows,
        "summary": {
            "final_capacity": final_cap,
            "n_grows": sess.timers.n_grows,
            "recompile_bound": recompile_bound,
            "n_refused": sess.timers.n_refused,
            "n_consolidations": sess.timers.n_consolidations,
            "grown_recall_at_10": grown_recall,
            "static_control_recall_at_10": static_recall,
            "recall_delta_vs_static": grown_recall - static_recall,
            "timers": sess.timers.to_dict(),
        },
    }
    print(f"growth_stream rounds={rounds} items={items} "
          f"capacity {init_cap}->{final_cap} "
          f"grows={sess.timers.n_grows}(<= {recompile_bound}) "
          f"refused={sess.timers.n_refused} "
          f"recall grown={grown_recall:.3f} static={static_recall:.3f}")
    return record


# ---------------------------------------------------------------------------
# continuous background refinement: OP_REFINE pins graph-quality drift
# (DESIGN.md §15) — appended to BENCH_stream.json as "refine_stream"
# ---------------------------------------------------------------------------

def run_refine(smoke: bool = False) -> dict:
    """Background-refinement bench (DESIGN.md §15): recall drift under
    repair-free churn, with and without OP_REFINE.

    The drift generator is deliberately hostile to graph quality: mask
    deletes with ``consolidate_strategy="pure"`` — compaction scrubs every
    edge into the victims but never repairs the survivors, so out-degrees
    erode monotonically under churn. Three arms over the same logical
    stream (refine draws its keys from the registered REFINE stream, so
    arming it cannot shift the op keys — the arms see identical ids):

      · control  — refinement disarmed; quality drifts;
      · refined  — auto OP_REFINE armed (wear-triggered from ``flush``);
      · oracle   — a fresh ``bulk_knn_build`` over the control's alive
        vectors at every measurement window: the quality a periodic full
        rebuild would buy, i.e. the upper bound refinement chases.

    Asserted over the tail half of the windows (CI smoke runs this):

      · the control's recall@10 drifts ≥ 2 points below the oracle;
      · the refined arm's recall@10 stays within 1 point of the oracle —
        continuous refinement buys back the rebuild's quality without
        ever taking the index offline.
    """
    from repro.core import (
        IndexParams, MaintenanceParams, SearchParams, Session, rebuild,
    )
    from repro.core import metrics as metrics_mod
    from repro.core import search as search_mod
    from repro.core.graph import NULL

    n, dim, d_out = 256, 16, 10
    batch = 16
    rounds = 48 if smoke else 160
    window = 8 if smoke else 20
    pool = 16
    cap = 2 * n
    base_kw = dict(
        capacity=cap, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2,
                            use_pallas=False),
        # construction quality must be able to MATCH the exact-kNN rebuild
        # oracle or the 1pt pin is unreachable by definition (measured:
        # pool-96/4-start insert wiring at d_out=10 builds to ~1pt above
        # the oracle on this workload; pool-32 sits 3pt below it)
        insert_search=SearchParams(pool_size=96, max_steps=192, num_starts=4,
                                   use_pallas=False),
    )
    maint_kw = dict(strategy="mask", insert_chunk=batch, delete_chunk=batch,
                    consolidate_threshold=0.2, consolidate_strategy="pure",
                    consolidate_chunk=32)
    ctrl_params = IndexParams(
        **base_kw, maintenance=MaintenanceParams(**maint_kw))
    # wear counts dispatched update rows; one round is 2*batch rows, so a
    # 2*batch threshold fires a pass at every round's flush. Each scrub
    # burst damages up to d_in incoming rows per victim, so the pass must
    # cycle the whole index every few rounds to keep up — chunk 96 over
    # ~256 alive slots does (measured: chunk 32 every other round loses
    # 4pt to the oracle; chunk 96 every round pins within 0.2pt)
    ref_params = IndexParams(**base_kw, maintenance=MaintenanceParams(
        **maint_kw, refine_threshold=2 * batch, refine_chunk=96))

    rng0 = np.random.default_rng(21)
    X = rng0.normal(size=(n, dim)).astype(np.float32)
    probes = jnp.asarray(rng0.normal(size=(64, dim)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    sp = base_kw["search"]

    def graph_recall(state):
        # raw-engine probe (no session ops): apples-to-apples across the
        # live arms and the rebuilt oracle state
        res = search_mod.search_batch(state, probes, key, sp)
        _, true_ids = metrics_mod.brute_force_topk(state, probes, 10)
        return float(metrics_mod.recall_at_k(res.ids[:, :10], true_ids, 10))

    def drive(params, windows, with_oracle=False):
        sess = Session(params, seed=5)
        alive_pool = [int(v) for v in np.asarray(sess.insert(X).result())]
        rng = np.random.default_rng(33)
        for r in range(rounds):
            n_del = min(batch, max(len(alive_pool) - batch, 0))
            pick = rng.choice(len(alive_pool), size=n_del, replace=False)
            victims = np.asarray([alive_pool[i] for i in pick], np.int32)
            for i in sorted(pick.tolist(), reverse=True):
                alive_pool.pop(i)
            sess.delete(victims)
            ins = sess.insert(rng.normal(size=(batch, dim)).astype(np.float32))
            new_ids = np.asarray(ins.result())
            alive_pool.extend(int(v) for v in new_ids if v != NULL)
            sess.flush()
            if (r + 1) % window == 0:
                w = {"round": r + 1,
                     "recall_at_10": graph_recall(sess.state),
                     "n_refines": sess.timers.n_refines}
                if with_oracle:
                    # the arms' alive sets are identical (timing
                    # invariance), so one oracle upper-bounds both
                    ost = rebuild.bulk_knn_build(
                        sess.state.vectors, sess.state.alive, params)
                    w["oracle_recall_at_10"] = graph_recall(ost)
                windows.append(w)
        return sess

    ctrl = drive(ctrl_params, ctrl_windows := [], with_oracle=True)
    refined = drive(ref_params, ref_windows := [])
    assert refined.timers.n_refines >= 1, "auto refine trigger never fired"

    half = len(ctrl_windows) // 2
    oracle_recall = float(np.mean(
        [w["oracle_recall_at_10"] for w in ctrl_windows[half:]]))
    ctrl_tail = float(np.mean(
        [w["recall_at_10"] for w in ctrl_windows[half:]]))
    ref_tail = float(np.mean(
        [w["recall_at_10"] for w in ref_windows[half:]]))
    drift = oracle_recall - ctrl_tail
    gap = oracle_recall - ref_tail
    assert drift >= 0.02, (
        f"control only drifted {drift:.3f} below the fresh-rebuild oracle "
        f"({ctrl_tail:.3f} vs {oracle_recall:.3f}) — the ≥2pt drift floor "
        f"is not met; the generator is not hostile enough")
    assert gap <= 0.01, (
        f"refined recall {ref_tail:.3f} is {gap:.3f} below the "
        f"fresh-rebuild oracle {oracle_recall:.3f} — the 1pt pin is blown")

    record = {
        "config": {
            "n": n, "dim": dim, "d_out": d_out, "pool_size": pool,
            "batch": batch, "capacity": cap, "rounds": rounds,
            "mix": "per round: 1 delete / 1 insert op + flush (mask, "
                   "pure-scrub consolidation)",
            "consolidate_threshold": 0.2, "consolidate_strategy": "pure",
            "refine_threshold": 2 * batch, "refine_chunk": 96,
            "smoke": smoke, "backend": jax.default_backend(),
        },
        "control_windows": ctrl_windows,
        "refined_windows": ref_windows,
        "summary": {
            "oracle_tail_recall_at_10": oracle_recall,
            "control_tail_recall_at_10": ctrl_tail,
            "refined_tail_recall_at_10": ref_tail,
            "control_drift_vs_oracle": drift,
            "refined_gap_vs_oracle": gap,
            "drift_floor": 0.02,
            "gap_budget": 0.01,
            "n_refines": refined.timers.n_refines,
            "n_refined": refined.timers.n_refined,
            "refine_s": refined.timers.refine_s,
            "timers": refined.timers.to_dict(),
        },
    }
    print(f"refine_stream rounds={rounds} oracle={oracle_recall:.3f} "
          f"control={ctrl_tail:.3f} (drift {drift:+.3f}, floor 0.02) "
          f"refined={ref_tail:.3f} (gap {gap:+.3f}, budget 0.01) "
          f"passes={refined.timers.n_refines}")
    return record


def run_recovery(smoke: bool = False) -> dict:
    """Durability bench (DESIGN.md §11): journal overhead, replay speed,
    recovery wall-time vs journal depth, and the crash-point matrix.

    The headline number is the journal tax on the mixed-stream hot path —
    the write-ahead append rides every dispatched op, so it must cost
    ≤ 10% of plain throughput (asserted). Replay speed and the per-depth
    recovery wall-times size the restart story; the matrix re-checks that
    a kill at every registered session crash point recovers bit-exact.
    """
    import shutil
    import tempfile

    from repro.core import IndexParams, MaintenanceParams, SearchParams, \
        Session
    from repro.testing import faults

    dim, pool = 16, 16
    rounds = 6 if smoke else 20
    ins_b, del_b, q_b = 32, 8, 16
    cap = 64 + rounds * ins_b
    params = IndexParams(
        capacity=cap, dim=dim, d_out=8,
        search=SearchParams(pool_size=pool, max_steps=3 * pool, num_starts=2),
        maintenance=MaintenanceParams(
            strategy="mask", insert_chunk=32, delete_chunk=16,
            consolidate_threshold=0.3, max_capacity=4 * cap),
    )

    def drive(sess, save_at=None):
        t0 = time.perf_counter()
        for r in range(rounds):
            rng = np.random.default_rng(100 + r)
            sess.insert(rng.normal(size=(ins_b, dim)).astype(np.float32))
            sess.delete(rng.integers(0, cap, size=del_b).astype(np.int32))
            sess.query(rng.normal(size=(q_b, dim)).astype(np.float32), k=10)
            sess.flush()
            if save_at is not None and r == save_at:
                sess.save(r)
        return time.perf_counter() - t0

    items = rounds * (ins_b + del_b + q_b)
    drive(Session(params, seed=0))  # compile warmup, untimed

    def best_of(mk_sess, n=5):
        best = float("inf")
        for _ in range(n):
            sess, cleanup = mk_sess()
            best = min(best, drive(sess))
            cleanup()
        return best

    def plain():
        return Session(params, seed=0), (lambda: None)

    def journaled(fsync):
        d = tempfile.mkdtemp(prefix="bench_jrnl_")
        s = Session(params, seed=0, checkpoint_dir=d, journal_fsync=fsync)
        return s, (lambda: shutil.rmtree(d, ignore_errors=True))

    t_plain = best_of(plain)
    t_flush = best_of(lambda: journaled("flush"))
    t_always = best_of(lambda: journaled("always"))
    plain_ips = items / t_plain
    flush_ips = items / t_flush
    overhead = 1.0 - flush_ips / plain_ips
    assert flush_ips >= 0.9 * plain_ips, (
        f"journal (fsync=flush) costs {overhead:.1%} of mixed-stream "
        f"throughput — the ≤10% budget is blown")

    # replay speed + recovery wall-time at three journal depths: the whole
    # stream, the post-midpoint-save suffix, and the post-final-save residue
    depths = {}
    for name, save_at in (("full_stream", None),
                          ("half_stream", rounds // 2 - 1),
                          ("tail_only", rounds - 1)):
        d = tempfile.mkdtemp(prefix="bench_recover_")
        drive(Session(params, seed=0, checkpoint_dir=d), save_at=save_at)
        t0 = time.perf_counter()
        rec = Session.recover(d, params, seed=0)
        wall = time.perf_counter() - t0
        info = rec.recovery_info
        depths[name] = {
            "n_replayed": info["n_replayed"],
            "replay_s": info["replay_s"],
            "recover_wall_s": wall,
            "replay_ops_per_s": info["n_replayed"] / max(info["replay_s"],
                                                         1e-9),
        }
        shutil.rmtree(d, ignore_errors=True)

    # crash matrix: kill at the middle occurrence of every registered
    # session crash point over a small deterministic stream; recovery must
    # land bit-identical to the uninterrupted control
    mcap, mdim, n_ops = 96, 8, 60
    mparams = IndexParams(
        capacity=mcap, dim=mdim, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        # refine armed so the registry's refine-begin/refine-step crash
        # points actually fire in the matrix (~18 update rows per "iidiq"
        # cycle → passes from the second flush on)
        maintenance=MaintenanceParams(
            strategy="mask", insert_chunk=16, delete_chunk=16,
            consolidate_threshold=0.3, max_capacity=4 * mcap,
            refine_threshold=30, refine_chunk=8),
    )

    def m_run(sess, start=0):
        def events(t):
            if (t + 1) % 7 == 0:
                sess.flush()
            if (t + 1) % 20 == 0:
                sess.save(t + 1)
        if start > 0:
            events(start - 1)
        for t in range(start, n_ops):
            kind = "iidiq"[t % 5]
            rng = np.random.default_rng(1000 + t)
            if kind == "i":
                sess.insert(rng.normal(size=(5, mdim)).astype(np.float32))
            elif kind == "d":
                sess.delete(rng.integers(0, mcap, size=3).astype(np.int32))
            else:
                sess.query(rng.normal(size=(2, mdim)).astype(np.float32))
            events(t)
        sess.flush()

    def m_summary(sess):
        st = sess.state
        return (np.asarray(st.adj), np.asarray(st.vectors),
                np.asarray(st.alive), np.asarray(st.present),
                st.capacity, sess._op_counter)

    probe = faults.FaultPlan()
    with tempfile.TemporaryDirectory() as d, faults.inject(probe):
        ctrl = Session(mparams, seed=3, checkpoint_dir=d)
        m_run(ctrl)
        want = m_summary(ctrl)
        del ctrl
    matrix = {}
    for point in faults.SESSION_CRASH_POINTS:
        n_hits = probe.hits.get(point, 0)
        if n_hits == 0:
            matrix[point] = None  # the stream never reaches this site
            continue
        d = tempfile.mkdtemp(prefix="bench_crash_")
        try:
            plan = faults.crash_once(point, hit=(n_hits + 1) // 2)
            sess = Session(mparams, seed=3, checkpoint_dir=d)
            try:
                with faults.inject(plan):
                    m_run(sess)
                matrix[point] = None  # armed hit never fired (unexpected)
                continue
            except faults.SimulatedCrash:
                pass
            del sess
            rec = Session.recover(d, mparams, seed=3)
            m_run(rec, start=rec._op_counter)
            got = m_summary(rec)
            matrix[point] = all(
                np.array_equal(g, w) for g, w in zip(got, want))
        finally:
            shutil.rmtree(d, ignore_errors=True)
    assert all(ok for ok in matrix.values() if ok is not None), matrix
    assert any(ok for ok in matrix.values()), "matrix never crashed at all"

    record = {
        "config": {
            "dim": dim, "pool_size": pool, "rounds": rounds,
            "capacity": cap, "items_per_run": items,
            "mix": f"per round: insert {ins_b} / delete {del_b} / "
                   f"query {q_b}, one flush",
            "smoke": smoke, "backend": jax.default_backend(),
        },
        "journal_overhead": {
            "plain_items_per_s": plain_ips,
            "journal_flush_items_per_s": flush_ips,
            "journal_always_items_per_s": items / t_always,
            "overhead_fraction_fsync_flush": overhead,
            "budget": 0.10,
        },
        "recovery_depths": depths,
        "crash_matrix": matrix,
    }
    print(f"recovery: plain={plain_ips:.0f} items/s "
          f"journaled(flush)={flush_ips:.0f} ({overhead:+.1%} overhead, "
          f"budget 10%) replay={depths['full_stream']['replay_ops_per_s']:.0f} "
          f"ops/s matrix={sum(bool(v) for v in matrix.values())}"
          f"/{sum(v is not None for v in matrix.values())} bit-exact")
    return record


def run_tiered(smoke: bool = False) -> dict:
    """Two-tier index bench (DESIGN.md §12): fan-out tax vs one big session.

    The same mixed 4i:4q:1d stream (by item count: 32 inserts, 32 query
    rows, 8 deletes per round) drives a ``TieredSession`` and a single
    big mask-strategy ``Session`` over the same logical items. Asserted
    (CI smoke runs this):

      · tiered query throughput ≥ 0.95x the single session's — the
        price of the two-tier fan-out + dedup union stays under 5%;
      · tiered recall@10 within 0.02 of the single session's;
      · a kill in the middle of a streaming merge (drain phase) recovers
        bit-exact from checkpoint + journal replay.

    Also recorded: p50/p99 fan-out query latency, merge counters, and the
    merge-time share of the run.
    """
    import shutil
    import tempfile

    from repro.core import IndexParams, MaintenanceParams, SearchParams, \
        Session, TieredSession
    from repro.core.graph import NULL
    from repro.testing import faults

    dim, pool, k = 64, 64, 10
    rounds = 8 if smoke else 24
    ins_b, del_b, q_rows, q_ops = 32, 8, 16, 2   # 4i : 4q : 1d per round
    base_n = 512 if smoke else 2048
    cap = base_n + rounds * ins_b + 64
    fresh_cap = 64

    def mk_maintenance(tiered):
        if tiered:
            return MaintenanceParams(
                strategy="mask", insert_chunk=32, delete_chunk=16,
                merge_fresh_threshold=0.5, merge_tombstone_threshold=0.25,
                merge_chunk=32, max_capacity=2 * cap)
        return MaintenanceParams(
            strategy="mask", insert_chunk=32, delete_chunk=16,
            consolidate_threshold=0.3, max_capacity=2 * cap)

    def mk_params(tiered):
        return IndexParams(
            capacity=cap, dim=dim, d_out=8,
            search=SearchParams(pool_size=pool, max_steps=3 * pool,
                                num_starts=2),
            maintenance=mk_maintenance(tiered))

    rng0 = np.random.default_rng(0)
    base = rng0.normal(size=(base_n, dim)).astype(np.float32)
    evalq = rng0.normal(size=(64, dim)).astype(np.float32)

    def build(tiered):
        if tiered:
            s = TieredSession(mk_params(True), fresh_capacity=fresh_cap,
                              seed=0)
            for lo in range(0, base_n, fresh_cap):   # fresh-sized waves
                s.insert(base[lo:lo + fresh_cap]).result()
        else:
            s = Session(mk_params(False), seed=0)
            s.insert(base).result()
        s.flush()
        return s

    def mk_driver(s):
        """Split stream driver: (mutate_fn, query_fn, query-latency list)."""
        id_log = [i for i in range(base_n)]
        q_lat = []

        def mutate(r):
            rng = np.random.default_rng(500 + r)
            ids = s.insert(
                rng.normal(size=(ins_b, dim)).astype(np.float32)).result()
            id_log.extend(int(i) for i in np.asarray(ids) if i != NULL)
            pos = rng.integers(0, len(id_log), size=del_b)
            s.delete(np.asarray([id_log[p] for p in pos], np.int32))
            # settle the round's mutation + merge device work before the
            # timed queries: the floor is about the *fan-out tax* on query
            # service, not about merge work parked in the async dispatch
            # queue (that cost is reported separately as merge_s/n_merges)
            s.flush()

        def one_query(r, j):
            rng = np.random.default_rng(700 + 10 * r + j)
            q = rng.normal(size=(q_rows, dim)).astype(np.float32)
            t0 = time.perf_counter()
            s.query(q, k=k).result()
            q_lat.append(time.perf_counter() - t0)

        return mutate, one_query, q_lat

    # The two streams run interleaved — mutations round-by-round, then the
    # round's query ops in adjacent tiered/single pairs with alternating
    # order — so machine drift (frequency scaling, background load, GC)
    # hits both equally. The asserted ratio is the median of the paired
    # per-op ratios; a back-to-back layout regularly skews the pair by
    # 10-20% either way on a busy host.
    warm_t = mk_driver(build(True))        # compile warmup, untimed
    warm_b = mk_driver(build(False))
    for r in range(rounds):
        for d in (warm_t, warm_b):
            d[0](r)
            for j in range(q_ops):
                d[1](r, j)

    tier_sess = build(True)
    big_sess = build(False)
    tier_mut, tier_q, tier_lat = mk_driver(tier_sess)
    big_mut, big_q, big_lat = mk_driver(big_sess)
    gc.collect()
    gc_was_on = gc.isenabled()
    gc.disable()
    try:
        for r in range(rounds):
            tier_mut(r)
            big_mut(r)
            for j in range(q_ops):
                if (r + j) % 2 == 0:
                    tier_q(r, j)
                    big_q(r, j)
                else:
                    big_q(r, j)
                    tier_q(r, j)
    finally:
        if gc_was_on:
            gc.enable()
    tier_lat = np.asarray(tier_lat)
    big_lat = np.asarray(big_lat)

    tier_recall = tier_sess.recall(evalq, k=k)
    tier_stats = tier_sess.stats()
    tier_timers = tier_sess.timers.to_dict()
    big_recall = big_sess.recall(evalq, k=k)

    n_q = rounds * q_ops * q_rows
    tier_qps = n_q / float(tier_lat.sum())
    big_qps = n_q / float(big_lat.sum())
    ratio = float(np.median(big_lat / tier_lat))
    assert ratio >= 0.95, (
        f"tiered query throughput {tier_qps:.0f} q/s is {ratio:.2f}x the "
        f"single session's {big_qps:.0f} q/s — the ≥0.95x floor is blown")
    assert tier_recall >= big_recall - 0.02, (
        f"tiered recall@{k} {tier_recall:.3f} more than 0.02 below the "
        f"single session's {big_recall:.3f}")

    # mid-merge crash: kill in the drain phase of a live merge, recover
    # from checkpoint + journal, resume — both tiers must land bit-exact
    # vs the uninterrupted control (the §12 acceptance check)
    mdim, m_ops = 8, 24
    mp = IndexParams(
        capacity=96, dim=mdim, d_out=6,
        search=SearchParams(pool_size=16, max_steps=48, num_starts=2),
        maintenance=MaintenanceParams(
            strategy="mask", insert_chunk=16, delete_chunk=16,
            merge_fresh_threshold=0.5, merge_tombstone_threshold=0.25,
            merge_chunk=8, max_capacity=384))

    def m_run(ts, start=0):
        for t in range(start, m_ops):
            kind = "iidiq"[t % 5]
            rng = np.random.default_rng(9000 + t)
            if kind == "i":
                ts.insert(rng.normal(size=(5, mdim)).astype(np.float32))
            elif kind == "d":
                hi = max(5 * (1 + 3 * t // 5), 1)
                ts.delete(rng.integers(0, hi, size=3).astype(np.int32))
            else:
                ts.query(rng.normal(size=(2, mdim)).astype(np.float32), k=8)
            if (t + 1) % 7 == 0:
                ts.flush()
        ts.flush()

    def m_summary(ts):
        out = []
        for sess in (ts._fresh, ts._main):
            st = sess.state
            out += [np.asarray(st.adj), np.asarray(st.vectors),
                    np.asarray(st.present), np.asarray(st.masked)]
        return out, dict(ts._loc), (ts._op_counter, ts._merge_counter,
                                    ts._merges_done)

    probe = faults.FaultPlan()
    with tempfile.TemporaryDirectory() as d, faults.inject(probe):
        ctrl = TieredSession(mp, fresh_capacity=32, seed=3,
                             checkpoint_dir=d)
        m_run(ctrl)
        want = m_summary(ctrl)
        del ctrl
    n_hits = probe.hits.get("merge-drain-step", 0)
    assert n_hits > 0, "the crash stream never reached a drain step"
    d = tempfile.mkdtemp(prefix="bench_tier_crash_")
    try:
        plan = faults.crash_once("merge-drain-step", hit=(n_hits + 1) // 2)
        ts = TieredSession(mp, fresh_capacity=32, seed=3, checkpoint_dir=d)
        try:
            with faults.inject(plan):
                m_run(ts)
            raise AssertionError("armed mid-merge crash never fired")
        except faults.SimulatedCrash:
            pass
        del ts
        rec = TieredSession.recover(d, mp, fresh_capacity=32, seed=3)
        m_run(rec, start=rec._op_counter)
        got = m_summary(rec)
        mid_merge_ok = (
            all(np.array_equal(g, w) for g, w in zip(got[0], want[0]))
            and got[1] == want[1] and got[2] == want[2])
    finally:
        shutil.rmtree(d, ignore_errors=True)
    assert mid_merge_ok, "mid-merge crash recovery diverged from control"

    record = {
        "config": {
            "dim": dim, "pool_size": pool, "k": k, "rounds": rounds,
            "mix": f"per round: insert {ins_b} / query {q_ops}x{q_rows} "
                   f"rows / delete {del_b}, one flush",
            "base_n": base_n, "capacity": cap, "fresh_capacity": fresh_cap,
            "smoke": smoke, "backend": jax.default_backend(),
        },
        "query_throughput": {
            "tiered_q_per_s": tier_qps,
            "single_session_q_per_s": big_qps,
            "ratio": ratio,
            "floor": 0.95,
        },
        "fanout_latency_s": {
            "p50": float(np.percentile(tier_lat, 50)),
            "p99": float(np.percentile(tier_lat, 99)),
            "max": float(tier_lat.max()),
            "single_session_p99": float(np.percentile(big_lat, 99)),
        },
        "recall_at_k": {
            "tiered": float(tier_recall),
            "single_session": float(big_recall),
            "budget": 0.02,
        },
        "merge": {
            "n_merges": tier_stats["n_merges"],
            "n_merged": tier_stats["n_merged"],
            "merge_s": tier_timers["merge_s"],
            "n_refused": tier_stats["n_refused"],
        },
        "mid_merge_crash_bit_exact": bool(mid_merge_ok),
    }
    print(f"tiered: {tier_qps:.0f} q/s vs single {big_qps:.0f} q/s "
          f"({ratio:.2f}x, floor 0.95) recall {tier_recall:.3f} vs "
          f"{big_recall:.3f} p99 fan-out {record['fanout_latency_s']['p99'] * 1e3:.1f}ms "
          f"merges={tier_stats['n_merges']} mid-merge crash "
          f"{'bit-exact' if mid_merge_ok else 'DIVERGED'}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / 1 iter (CI)")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT,
                    help="where to write the search-engine record")
    ap.add_argument("--update-out", type=pathlib.Path,
                    default=DEFAULT_UPDATE_OUT,
                    help="where to write the update-engine record")
    ap.add_argument("--stream-out", type=pathlib.Path,
                    default=DEFAULT_STREAM_OUT,
                    help="where to write the mixed-stream session record")
    ap.add_argument("--recover-out", type=pathlib.Path,
                    default=DEFAULT_RECOVER_OUT,
                    help="where to write the durability/recovery record")
    ap.add_argument("--tier-out", type=pathlib.Path,
                    default=DEFAULT_TIER_OUT,
                    help="where to write the two-tier index record")
    args = ap.parse_args(argv)
    kernel_rows = run(SMOKE_SHAPES if args.smoke else SHAPES)
    record = run_search(smoke=args.smoke)
    record["kernel_rows"] = kernel_rows
    record["quantized_search"] = run_quantized_search(smoke=args.smoke)
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(record, indent=2) + "\n")
    print(f"wrote {args.out}")
    update_record = run_update(smoke=args.smoke)
    args.update_out.parent.mkdir(parents=True, exist_ok=True)
    args.update_out.write_text(json.dumps(update_record, indent=2) + "\n")
    print(f"wrote {args.update_out}")
    stream_record = run_stream(smoke=args.smoke)
    stream_record["long_stream"] = run_long_stream(smoke=args.smoke)
    stream_record["growth_stream"] = run_growth_stream(smoke=args.smoke)
    stream_record["refine_stream"] = run_refine(smoke=args.smoke)
    args.stream_out.parent.mkdir(parents=True, exist_ok=True)
    args.stream_out.write_text(json.dumps(stream_record, indent=2) + "\n")
    print(f"wrote {args.stream_out}")
    recover_record = run_recovery(smoke=args.smoke)
    args.recover_out.parent.mkdir(parents=True, exist_ok=True)
    args.recover_out.write_text(json.dumps(recover_record, indent=2) + "\n")
    print(f"wrote {args.recover_out}")
    tier_record = run_tiered(smoke=args.smoke)
    args.tier_out.parent.mkdir(parents=True, exist_ok=True)
    args.tier_out.write_text(json.dumps(tier_record, indent=2) + "\n")
    print(f"wrote {args.tier_out}")


if __name__ == "__main__":
    main()
