"""Kernel microbench — §6 "Implementation" analogue.

CPU wall-times for the XLA (jnp oracle) path at benchmark shapes + the
structural properties of the Pallas kernels (VMEM working set per BlockSpec
tile, HBM traffic model). Interpret-mode wall-clock is a Python emulation —
meaningless as perf — so Pallas numbers reported here are the *derived*
bytes/FLOPs per tile that the roofline uses, with allclose checked against
the oracle (also enforced in tests/test_kernels.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import ref_score_matrix, ref_score_topk

SHAPES = [
    ("sift_1m_block", 8192, 256, 128, 10),
    ("glove_block", 8192, 256, 200, 10),
    ("gist_block", 4096, 128, 960, 10),
    ("retrieval_1m", 16384, 64, 64, 100),
]


def _time(f, *args, iters=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for name, M, B, d, k in SHAPES:
        x = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
        xsq = jnp.sum(x * x, 1)
        f_mat = jax.jit(lambda x, s, q: ref_score_matrix(x, s, q, "l2"))
        f_top = jax.jit(lambda x, s, q: ref_score_topk(x, s, q, k, "l2"))
        t_mat = _time(lambda *a: (f_mat(*a),), x, xsq, q)
        t_top = _time(f_top, x, xsq, q)
        flops = 2.0 * M * B * d
        # Pallas tile model (block_b=128, block_m=256, block_d=128):
        vmem_tile = (256 * 128 + 128 * 128 + 128 * 256) * 4
        hbm_fused = (M * d + B * d) * 4 + B * k * 8       # fused top-k path
        hbm_unfused = (M * d + B * d + 2 * B * M) * 4     # matrix + topk read
        rows.append({
            "name": name,
            "us_per_call_xla_matrix": t_mat * 1e6,
            "us_per_call_xla_topk": t_top * 1e6,
            "gflops": flops / 1e9,
            "cpu_gflops_per_s": flops / t_mat / 1e9,
            "pallas_vmem_tile_bytes": vmem_tile,
            "hbm_bytes_fused": hbm_fused,
            "hbm_bytes_unfused": hbm_unfused,
            "fusion_traffic_saving": hbm_unfused / hbm_fused,
        })
        print(f"{name:16s} xla_matrix={t_mat*1e6:10.0f}us "
              f"xla_topk={t_top*1e6:10.0f}us "
              f"traffic_saving={hbm_unfused/hbm_fused:.2f}x")
    return rows


if __name__ == "__main__":
    run()
