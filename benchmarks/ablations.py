"""Ablations over the system's knobs (beyond the paper's tables).

  A. out-degree d_out × strategy — connectivity vs repair-cost trade-off
  B. keepPrunedConnections on/off — our HNSW-practice deviation quantified
  C. bounded in-degree (d_in/d_out ratio) — the reverse-graph cap's effect

    PYTHONPATH=src python -m benchmarks.ablations [--fast]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro.core import IndexParams, IPGMIndex, SearchParams

RESULTS = Path(__file__).resolve().parents[1] / "results"


def _run_once(X, Q, *, d_out, d_in=None, strategy="global", seed=0):
    params = IndexParams(
        capacity=X.shape[0] + 64, dim=X.shape[1], d_out=d_out, d_in=d_in,
        search=SearchParams(pool_size=32, max_steps=96, num_starts=2),
    )
    idx = IPGMIndex(params, strategy=strategy, seed=seed)
    ids = idx.insert(X)
    rng = np.random.default_rng(seed)
    # one churn round: delete 20%, insert fresh 20%
    n_del = X.shape[0] // 5
    idx.delete(rng.choice(np.asarray(ids), size=n_del, replace=False))
    idx.insert(rng.normal(size=(n_del, X.shape[1])).astype(np.float32))
    st = idx.stats()
    return {
        "recall@10": idx.recall(Q, k=10),
        "avg_out_degree": st["avg_out_degree"],
        "max_in_degree": st["max_in_degree"],
    }


def run(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    n = 600 if fast else 1500
    X = rng.normal(size=(n, 32)).astype(np.float32)
    Q = rng.normal(size=(128, 32)).astype(np.float32)
    out: dict = {}

    # A: d_out sweep × strategy
    out["d_out_sweep"] = {}
    for d_out in (6, 12, 24):
        for strat in ("pure", "global"):
            r = _run_once(X, Q, d_out=d_out, strategy=strat)
            out["d_out_sweep"][f"d{d_out}/{strat}"] = r
            print(f"[A] d_out={d_out:2d} {strat:6s} recall={r['recall@10']:.3f} "
                  f"deg={r['avg_out_degree']:.1f}")

    # C: in-degree cap ratio
    out["d_in_ratio"] = {}
    for ratio in (1, 2, 4):
        r = _run_once(X, Q, d_out=12, d_in=12 * ratio, strategy="global")
        out["d_in_ratio"][f"x{ratio}"] = r
        print(f"[C] d_in={12*ratio:2d} recall={r['recall@10']:.3f} "
              f"max_in={r['max_in_degree']}")

    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "ablations.json").write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(ap.parse_args().fast)
