"""Benchmark entry point — one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention and
writes JSON artifacts under results/. Scaled to single-core CPU budgets
(--fast shrinks further for CI-style runs).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller workloads (sanity run)")
    ap.add_argument("--only", default=None,
                    choices=[None, "fig2", "fig3", "fig4", "kernels",
                             "summary", "roofline"])
    args = ap.parse_args()

    rows: list[tuple[str, float, str]] = []

    def record(name: str, seconds: float, derived: str):
        rows.append((name, seconds * 1e6, derived))

    scale = (
        dict(n_base=1200, n_steps=3, batch_size=150, n_queries=256)
        if args.fast else
        dict(n_base=3000, n_steps=5, batch_size=300, n_queries=512)
    )

    if args.only in (None, "fig2"):
        from benchmarks import fig2_random_updates as fig2
        t0 = time.perf_counter()
        out = fig2.run(datasets=("sift", "glove200"), **scale)
        for ds, per in out.items():
            for strat, recs in per.items():
                qps = recs[-1]["qps"]
                record(f"fig2/{ds}/{strat}", 1.0 / max(qps, 1e-9),
                       f"recall={recs[-1]['recall']:.3f}")
        print(f"[fig2 done in {time.perf_counter()-t0:.0f}s]")

    if args.only in (None, "fig3"):
        from benchmarks import fig3_clustered_updates as fig3
        t0 = time.perf_counter()
        out = fig3.run(**scale)
        for ds, per in out.items():
            for strat, recs in per.items():
                qps = recs[-1]["qps"]
                record(f"fig3/{ds}/{strat}", 1.0 / max(qps, 1e-9),
                       f"recall={recs[-1]['recall']:.3f}")
        print(f"[fig3 done in {time.perf_counter()-t0:.0f}s]")

    if args.only in (None, "fig4"):
        from benchmarks import fig4_total_time as fig4
        out = fig4.run(
            n_base=scale["n_base"] // 2, n_steps=3,
            batch_size=scale["batch_size"] // 2,
        )
        for ratio, per in out.items():
            for strat, curve in per.items():
                record(f"fig4/ratio{ratio}/{strat}",
                       curve[-1]["total_s"] / max(curve[-1]["n_ops"], 1),
                       f"total_s={curve[-1]['total_s']:.2f}")

    if args.only in (None, "kernels"):
        from benchmarks import kernel_bench
        for r in kernel_bench.run():
            record(f"kernel/{r['name']}",
                   r["us_per_call_xla_matrix"] / 1e6,
                   f"traffic_saving={r['fusion_traffic_saving']:.2f}x")

    if args.only in (None, "summary"):
        from benchmarks.summary import summarize
        summarize()

    if args.only == "roofline":
        from benchmarks import roofline
        roofline.run()

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
