"""Figure 3: same as Fig 2 under the clustered update pattern — whole
k-means clusters expire together (the hard case for edge repair)."""
from __future__ import annotations

from benchmarks import fig2_random_updates as fig2


def run(**kw):
    kw.setdefault("pattern", "clustered")
    kw.setdefault("out_name", "fig3_clustered.json")
    kw.setdefault("datasets", ("sift", "glove200"))
    return fig2.run(**kw)


if __name__ == "__main__":
    run()
