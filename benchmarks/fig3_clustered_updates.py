"""Figure 3: same as Fig 2 under the clustered update pattern — whole
k-means clusters expire together (the hard case for edge repair).

The same pattern drives the ``clustered`` scenario of
``benchmarks/adversarial_delete.py``, which tracks recall-over-time and
connectivity per strategy instead of QPS-vs-ReBuild; this figure keeps the
paper's relative-QPS presentation."""
from __future__ import annotations

from benchmarks import fig2_random_updates as fig2


def run(**kw):
    kw.setdefault("pattern", "clustered")
    kw.setdefault("out_name", "fig3_clustered.json")
    kw.setdefault("datasets", ("sift", "glove200"))
    return fig2.run(**kw)


if __name__ == "__main__":
    run()
