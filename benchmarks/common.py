"""Shared benchmark driver: the §6 protocol with QPS-at-recall measurement.

Scaled to CPU budgets (defaults ~3k base vs the paper's 900k) — relative
orderings are the claims under test, and hop counts (hardware-independent)
are reported alongside wall-clock QPS.

QPS at 0.8 recall follows the paper: per batch, walk a pool-size ladder
until recall@10 ≥ 0.8, then report QPS at that setting (compiled fns are
cached per pool size across batches/strategies).

The driver runs directly on the streaming ``Session`` API (the seed
``IPGMIndex`` facade is gone from the benchmark path): ops dispatch through
the unified op IR and the strategy sweep covers all five delete strategies,
including the random-walk repair (``rwalk``, DESIGN.md §13).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import (IndexParams, MaintenanceParams, SearchParams,
                        Session)
from repro.core import metrics as metrics_mod
from repro.core import search as search_mod
from repro.data.workload import UpdateWorkload, make_workload

POOL_LADDER = (8, 16, 24, 32, 48, 64, 96)
RECALL_TARGET = 0.8
K = 10

STRATEGIES = ("pure", "mask", "local", "global", "rwalk")


@dataclasses.dataclass
class BatchRecord:
    step: int
    strategy: str
    recall: float
    qps: float
    pool_used: int
    avg_hops: float
    update_s: float
    query_s: float


def _copy_state(state):
    return jax.tree.map(lambda x: x.copy() if hasattr(x, "copy") else x, state)


def measure_query_at_recall(
    index, queries: np.ndarray, true_ids, *, ladder=POOL_LADDER,
    target=RECALL_TARGET,
) -> tuple[float, float, int, float]:
    """(recall, qps, pool_used, avg_hops) at the first ladder rung hitting
    the target (or the last rung)."""
    import jax.numpy as jnp

    q = jnp.asarray(queries)
    for pool in ladder:
        sp = SearchParams(pool_size=max(pool, K), max_steps=3 * pool,
                          num_starts=2)
        key = jax.random.PRNGKey(0)
        res = search_mod.search_batch(index.state, q, key, sp)
        jax.block_until_ready(res.ids)
        t0 = time.perf_counter()
        res = search_mod.search_batch(index.state, q, key, sp)
        jax.block_until_ready(res.ids)
        dt = time.perf_counter() - t0
        rec = float(metrics_mod.recall_at_k(res.ids, true_ids, K))
        hops = float(np.mean(np.asarray(res.n_expanded)))
        if rec >= target or pool == ladder[-1]:
            return rec, queries.shape[0] / dt, pool, hops
    raise AssertionError


def run_strategy_workload(
    wl: UpdateWorkload,
    strategy: str,
    *,
    d_out: int = 12,
    seed: int = 0,
    rebuild_each_batch: bool = False,
    query_subset: int = 256,
) -> list[BatchRecord]:
    dim = wl.base.shape[1]
    total = wl.base.shape[0] + sum(x.shape[0] for x in wl.step_inserts) + 16
    params = IndexParams(
        capacity=total, dim=dim, d_out=d_out,
        search=SearchParams(pool_size=32, max_steps=96, num_starts=2),
        maintenance=MaintenanceParams(strategy=strategy,
                                      insert_chunk=64, delete_chunk=64),
    )
    index = Session(params, seed=seed)
    ids = index.insert(wl.base).result()
    id_map = list(np.asarray(ids))
    queries = wl.queries[:query_subset]

    records = []
    # batch 0: base set, no updates (the paper's common starting point)
    _, true_ids = index.ground_truth(queries, K)
    rec, qps, pool, hops = measure_query_at_recall(index, queries, true_ids)
    records.append(BatchRecord(0, strategy, rec, qps, pool, hops, 0.0, 0.0))

    for step in range(wl.n_steps):
        t0 = time.perf_counter()
        gids = [id_map[p] for p in wl.step_deletes[step]]
        if rebuild_each_batch:
            # ReBuild baseline: drop (cheap PURE) + full reconstruction
            index.strategy = "pure"
            index.delete(np.asarray(gids))
            new = index.insert(wl.step_inserts[step]).result()
            id_map.extend(np.asarray(new))
            alive_before = np.flatnonzero(np.asarray(index.state.alive))
            index.rebuild_from_alive()  # compacts alive slots → 0..n-1
            remap = {int(old): new_id
                     for new_id, old in enumerate(alive_before)}
            id_map = [remap.get(int(g), -1) if g is not None else -1
                      for g in id_map]
        else:
            index.delete(np.asarray(gids))
            new = index.insert(wl.step_inserts[step]).result()
            id_map.extend(np.asarray(new))
        update_s = time.perf_counter() - t0

        _, true_ids = index.ground_truth(queries, K)
        t0 = time.perf_counter()
        rec, qps, pool, hops = measure_query_at_recall(index, queries, true_ids)
        query_s = time.perf_counter() - t0
        records.append(
            BatchRecord(step + 1, strategy, rec, qps, pool, hops,
                        update_s, query_s)
        )
    return records
