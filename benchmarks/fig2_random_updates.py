"""Figure 2: relative QPS (vs ReBuild) at 0.8 recall per update batch —
random update pattern. One curve per strategy (incl. ``rwalk``), per
dataset surrogate. Runs on the streaming Session API via
``benchmarks.common``; for hostile (clustered / bursty / rolling-window)
deletion patterns with recall-over-time curves see
``benchmarks/adversarial_delete.py``."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import STRATEGIES, run_strategy_workload
from repro.data.workload import make_workload

RESULTS = Path(__file__).resolve().parents[1] / "results"


def run(
    *,
    datasets=("sift", "glove200"),
    n_base=3000,
    n_steps=5,
    batch_size=300,
    n_queries=512,
    pattern="random",
    out_name="fig2_random.json",
    dim_override=None,
) -> dict:
    out = {}
    for ds in datasets:
        wl = make_workload(ds, n_base=n_base, n_steps=n_steps,
                           batch_size=batch_size, n_queries=n_queries,
                           pattern=pattern, dim=dim_override)
        ds_out = {}
        rebuild = run_strategy_workload(wl, "pure", rebuild_each_batch=True)
        ds_out["rebuild"] = [r.__dict__ for r in rebuild]
        for strat in STRATEGIES:
            recs = run_strategy_workload(wl, strat)
            ds_out[strat] = [r.__dict__ for r in recs]
            rel = [
                r.qps / max(b.qps, 1e-9)
                for r, b in zip(recs, rebuild)
            ]
            print(f"[{pattern}:{ds}] {strat:7s} rel-QPS/batch: "
                  + " ".join(f"{x:.2f}" for x in rel)
                  + f" | recall last={recs[-1].recall:.3f}"
                  + f" hops last={recs[-1].avg_hops:.1f}")
        out[ds] = ds_out
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / out_name).write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    run()
