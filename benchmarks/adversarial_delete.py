"""Adversarial deletion evaluation harness (ROADMAP item 1, DESIGN.md §13).

Steady-state random churn — the stream-fuzz suites and the fig2 protocol —
hides the delete-repair failure modes ("How Should We Evaluate Data Deletion
in Graph-Based ANN Indexes?", 2025, PAPERS.md). This harness drives each
delete strategy through three hostile scenarios and records what the
averages hide:

  clustered — whole k-means regions vanish per round (absorbs the seed's
              fig3 pattern via ``make_workload(pattern="clustered")``): a
              vector AND its nearest neighbors expire together, so repair
              candidates local to the deleted region are themselves dying.
  bursty    — delete a random batch, then immediately reinsert the same
              vectors: the graph must re-absorb points whose old
              neighborhoods were just torn out.
  rolling   — rolling window: the oldest ``evict_frac`` of the index is
              evicted every round and replaced with fresh arrivals, so every
              vertex is eventually deleted and edge quality must survive
              full turnover.

Per (scenario, strategy) the harness records a recall@10-over-time curve,
per-round update wall time (the repair-cost axis), and graph-connectivity
stats (fraction of alive vertices reachable from a live entry point, average
out-degree, tombstone share). Everything lands in ``BENCH_delete.json``.

``--smoke`` runs a CI-sized config and asserts a per-strategy recall@10
floor on the clustered scenario (the hard case) — a repair regression fails
the CI step, not just a curve in an artifact.

Usage: python benchmarks/adversarial_delete.py [--smoke] [--out PATH]
       [--scenarios clustered bursty rolling]
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from repro.core import IndexParams, MaintenanceParams, SearchParams, Session
from repro.core.graph import NULL
from repro.data.synthetic import make_dataset
from repro.data.workload import make_workload

K = 10
STRATEGIES = ("pure", "mask", "local", "global", "rwalk")
SCENARIOS = ("clustered", "bursty", "rolling")

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[1] / "bench-artifacts" \
    / "BENCH_delete.json"

# CI smoke gate: final-round clustered-scenario recall@10 floor per strategy.
# Calibrated ~0.15 under observed smoke-config finals (pure/local/global/
# rwalk ≈0.97, mask ≈0.94) so only a genuine repair regression — not
# measurement noise — trips them. MASK floors lowest: tombstones dilute the
# search pool as the masked share grows.
CLUSTERED_RECALL_FLOOR = {
    "pure": 0.80,
    "mask": 0.75,
    "local": 0.80,
    "global": 0.80,
    "rwalk": 0.80,
}


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    n_base: int
    n_rounds: int
    batch: int          # delete/insert batch per round (clustered/bursty)
    n_queries: int
    dim: int
    d_out: int = 12
    seed: int = 0
    evict_frac: float = 0.01   # rolling window: share evicted per round


SMOKE_CFG = ScenarioConfig(n_base=600, n_rounds=4, batch=100, n_queries=64,
                           dim=16)
FULL_CFG = ScenarioConfig(n_base=3000, n_rounds=8, batch=300, n_queries=256,
                          dim=32)


def connectivity_stats(state) -> dict:
    """Host-side graph health: directed BFS (through *present* vertices —
    tombstones are traversable) from one alive entry point, reporting the
    fraction of alive vertices reached, plus degree/tombstone shares."""
    adj = np.asarray(state.adj)
    alive = np.asarray(state.alive)
    present = np.asarray(state.present)
    n_alive = int(alive.sum())
    if n_alive == 0:
        return {"reachable_frac": 1.0, "avg_out_degree": 0.0,
                "masked_frac": 0.0, "n_alive": 0}
    start = int(np.flatnonzero(alive)[0])
    seen = np.zeros(len(alive), bool)
    seen[start] = True
    frontier = [start]
    while frontier:
        rows = adj[frontier].reshape(-1)
        rows = rows[rows != NULL]
        rows = rows[present[rows] & ~seen[rows]]
        seen[rows] = True
        frontier = np.unique(rows).tolist()
    out_deg = (adj[alive] != NULL).sum(axis=1)
    n_present = int(present.sum())
    return {
        "reachable_frac": float(seen[alive].mean()),
        "avg_out_degree": float(out_deg.mean()),
        "masked_frac": float((n_present - n_alive) / max(n_present, 1)),
        "n_alive": n_alive,
    }


def _mk_session(strategy: str, capacity: int, cfg: ScenarioConfig) -> Session:
    params = IndexParams(
        capacity=capacity, dim=cfg.dim, d_out=cfg.d_out,
        search=SearchParams(pool_size=32, max_steps=96, num_starts=2),
        maintenance=MaintenanceParams(strategy=strategy,
                                      insert_chunk=64, delete_chunk=64),
    )
    return Session(params, seed=cfg.seed)


def _measure(sess: Session, queries: np.ndarray, rnd: int,
             update_s: float) -> dict:
    t0 = time.perf_counter()
    recall = float(sess.recall(queries, K))
    query_s = time.perf_counter() - t0
    rec = {"round": rnd, "recall": recall, "update_s": round(update_s, 4),
           "query_s": round(query_s, 4)}
    rec.update(connectivity_stats(sess.state))
    return rec


def run_clustered(strategy: str, cfg: ScenarioConfig) -> list[dict]:
    """Whole k-means regions vanish per round (the seed fig3 pattern)."""
    wl = make_workload("sift", n_base=cfg.n_base, n_steps=cfg.n_rounds,
                       batch_size=cfg.batch, n_queries=cfg.n_queries,
                       pattern="clustered", seed=cfg.seed, dim=cfg.dim)
    total = cfg.n_base + cfg.n_rounds * cfg.batch + 16
    sess = _mk_session(strategy, total, cfg)
    id_map = list(np.asarray(sess.insert(wl.base).result()))
    queries = wl.queries
    rounds = [_measure(sess, queries, 0, 0.0)]
    for step in range(wl.n_steps):
        t0 = time.perf_counter()
        gids = np.asarray([id_map[p] for p in wl.step_deletes[step]])
        sess.delete(gids)
        id_map.extend(np.asarray(sess.insert(wl.step_inserts[step]).result()))
        sess.flush()
        rounds.append(_measure(sess, queries, step + 1,
                               time.perf_counter() - t0))
    return rounds


def run_bursty(strategy: str, cfg: ScenarioConfig) -> list[dict]:
    """Delete a random batch, immediately reinsert the same vectors."""
    rng = np.random.default_rng(cfg.seed + 10)
    X = make_dataset("sift", cfg.n_base + cfg.n_queries, seed=cfg.seed + 1,
                     dim=cfg.dim)
    base, queries = X[:cfg.n_base], X[cfg.n_base:]
    # every round re-adds the burst, so MASK's dead slots accumulate
    total = cfg.n_base + cfg.n_rounds * cfg.batch + 16
    sess = _mk_session(strategy, total, cfg)
    live = list(np.asarray(sess.insert(base).result()))
    vec_of = {int(s): base[i] for i, s in enumerate(live)}
    rounds = [_measure(sess, queries, 0, 0.0)]
    for rnd in range(cfg.n_rounds):
        pick = rng.choice(len(live), size=cfg.batch, replace=False)
        burst_ids = np.asarray([live[i] for i in pick])
        burst_vecs = np.stack([vec_of[int(s)] for s in burst_ids])
        t0 = time.perf_counter()
        sess.delete(burst_ids)
        new_ids = np.asarray(sess.insert(burst_vecs).result())
        sess.flush()
        update_s = time.perf_counter() - t0
        # two-phase: drop ALL old ids before adding the new ones — with
        # hard-delete strategies a freed slot is recycled within the same
        # burst, so a new id can collide with another vector's old id
        for s in burst_ids:
            vec_of.pop(int(s), None)
        for i, (p, s) in enumerate(zip(pick, new_ids)):
            live[p] = int(s)
            vec_of[int(s)] = burst_vecs[i]
        rounds.append(_measure(sess, queries, rnd + 1, update_s))
    return rounds


def run_rolling(strategy: str, cfg: ScenarioConfig) -> list[dict]:
    """Rolling window: evict the oldest ``evict_frac`` share per round."""
    evict = max(1, int(cfg.n_base * cfg.evict_frac))
    n_rounds = cfg.n_rounds * 2  # small per-round batches: run longer
    rng = np.random.default_rng(cfg.seed + 20)
    X = make_dataset("sift", cfg.n_base + n_rounds * evict + cfg.n_queries,
                     seed=cfg.seed + 2, dim=cfg.dim)
    base = X[:cfg.n_base]
    fresh = X[cfg.n_base:cfg.n_base + n_rounds * evict]
    queries = X[cfg.n_base + n_rounds * evict:]
    total = cfg.n_base + n_rounds * evict + 16
    sess = _mk_session(strategy, total, cfg)
    fifo = collections.deque(np.asarray(sess.insert(base).result()).tolist())
    rounds = [_measure(sess, queries, 0, 0.0)]
    for rnd in range(n_rounds):
        oldest = np.asarray([fifo.popleft() for _ in range(evict)])
        t0 = time.perf_counter()
        sess.delete(oldest)
        arr = fresh[rnd * evict:(rnd + 1) * evict]
        fifo.extend(np.asarray(sess.insert(arr).result()).tolist())
        sess.flush()
        rounds.append(_measure(sess, queries, rnd + 1,
                               time.perf_counter() - t0))
    del rng
    return rounds


_SCENARIO_FNS = {
    "clustered": run_clustered,
    "bursty": run_bursty,
    "rolling": run_rolling,
}


def run_all(*, smoke: bool = False,
            scenarios: tuple[str, ...] = SCENARIOS,
            strategies: tuple[str, ...] = STRATEGIES) -> dict:
    cfg = SMOKE_CFG if smoke else FULL_CFG
    record: dict = {
        "smoke": smoke,
        "backend": jax.default_backend(),
        "config": dataclasses.asdict(cfg),
        "k": K,
        "scenarios": {},
    }
    for scen in scenarios:
        record["scenarios"][scen] = {}
        for strat in strategies:
            rounds = _SCENARIO_FNS[scen](strat, cfg)
            total_update = sum(r["update_s"] for r in rounds)
            record["scenarios"][scen][strat] = {
                "rounds": rounds,
                "recall_curve": [r["recall"] for r in rounds],
                "total_update_s": round(total_update, 4),
                "final_reachable_frac": rounds[-1]["reachable_frac"],
            }
            curve = " ".join(f"{r['recall']:.2f}" for r in rounds)
            print(f"[{scen}] {strat:7s} recall/round: {curve} | "
                  f"update {total_update:.2f}s | "
                  f"reach {rounds[-1]['reachable_frac']:.2f} "
                  f"deg {rounds[-1]['avg_out_degree']:.1f}")
    if smoke and "clustered" in record["scenarios"]:
        record["clustered_recall_floor"] = CLUSTERED_RECALL_FLOOR
        for strat, res in record["scenarios"]["clustered"].items():
            floor = CLUSTERED_RECALL_FLOOR.get(strat)
            if floor is None:
                continue
            final = res["recall_curve"][-1]
            assert final >= floor, (
                f"clustered-scenario recall floor: {strat} finished at "
                f"{final:.3f} < {floor} — delete repair regressed")
    return record


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run + clustered recall-floor assertions")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT,
                    help="where to write the adversarial-delete record")
    ap.add_argument("--scenarios", nargs="*", default=list(SCENARIOS),
                    choices=list(SCENARIOS))
    ap.add_argument("--strategies", nargs="*", default=list(STRATEGIES),
                    choices=list(STRATEGIES))
    args = ap.parse_args(argv)
    record = run_all(smoke=args.smoke, scenarios=tuple(args.scenarios),
                     strategies=tuple(args.strategies))
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(record, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
