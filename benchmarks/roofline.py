"""Roofline assembler (deliverable g).

Per (arch × shape) on the single-pod mesh:
    compute    = jaxpr_FLOPs / (chips × 197e12)           [s]
    memory     = jaxpr_HBM_bytes / (chips × 819e9)        [s]
    collective = analytic collective bytes / (chips × 50e9) [s]
plus the dominant term, MODEL_FLOPS / HLO_FLOPs utilization ratio, and the
per-device fit from the dry-run manifest.

FLOPs/bytes come from the trip-count-aware jaxpr walker
(launch/analysis.py) — XLA CPU's cost_analysis counts loop bodies once and
is only used as a cross-check on loop-free cells. Collective bytes come
from the sharding-rule model (launch/collectives.py); the manifest's
one-shot HLO counts bound the non-looped part.

Writes results/roofline.json + a markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link
CHIPS = 256              # single-pod 16×16

RESULTS = Path(__file__).resolve().parents[1] / "results"
MANIFEST = RESULTS / "dryrun_manifest.json"


def analyze_cell(arch_id: str, shape: str, mesh) -> dict:
    import jax

    from repro.configs import registry as reg
    from repro.launch.analysis import cost_of
    from repro.launch.cells import build_cell
    from repro.launch.collectives import collectives_for

    spec = reg.get_arch(arch_id)
    cell_meta = spec.shapes[shape]
    with jax.set_mesh(mesh):
        cell = build_cell(arch_id, shape, mesh)
        # raw python callable behind the jit wrapper
        raw = getattr(cell.fn, "__wrapped__", cell.fn)
        while_trip = 1
        if spec.family == "ipgm":
            while_trip = spec.config_for_shape(shape).search.max_steps
        cost = cost_of(raw, *cell.args, while_trip=while_trip, io_bytes=False)
        cfg = spec.config_for_shape(shape)
        params_sds = cell.args[0]
        from repro.launch import sharding as shr
        p_specs = cell.param_specs
        coll = collectives_for(spec.family, cfg, cell_meta, mesh,
                               params_sds=params_sds, p_specs=p_specs)
        # program-IO per device at the ACTUAL sharding (params replicated
        # over an axis cost full reads; FSDP params cost 1/chips)
        import numpy as np

        def _b(x):
            try:
                return float(np.prod(x.shape, dtype=np.float64)) * np.dtype(
                    x.dtype).itemsize
            except TypeError:
                return float(np.prod(x.shape, dtype=np.float64)) * 4

        io_per_dev = shr.sharded_bytes_per_dev(params_sds, p_specs, mesh)
        param_global = sum(_b(x) for x in jax.tree.leaves(params_sds))
    # shard_map (ipgm) jaxprs carry PER-SHARD shapes: costs are already
    # per-device; pjit jaxprs carry GLOBAL shapes: divide by chip count
    per_dev = 1.0 if spec.family == "ipgm" else float(CHIPS)
    comm_total = sum(coll.values()) + cost.comm_bytes / per_dev

    # the jaxpr walker counts weight reads at global shapes (≈ /chips when
    # fully sharded); correct for replicated/TP-only placements
    io_correction = max(0.0, io_per_dev - param_global / per_dev)
    compute_s = cost.flops / per_dev / PEAK_FLOPS
    memory_s = (cost.hbm_bytes / per_dev + io_correction) / HBM_BW
    collective_s = comm_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    model_flops = cell.meta.get("model_flops", 0)
    if spec.family != "ipgm":
        model_flops = model_flops / CHIPS  # per-chip useful work
    return {
        "arch": arch_id,
        "shape": shape,
        "kind": cell.kind,
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "collective_bytes_per_dev": comm_total,
        "collectives_detail": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_per_dev": model_flops,
        "useful_ratio": (
            model_flops / (cost.flops / per_dev) if cost.flops else 0.0
        ),
        "step_s_bound": max(terms.values()),
        "roofline_fraction": (
            model_flops / PEAK_FLOPS / max(terms.values())
            if max(terms.values()) > 0 else 0.0
        ),
    }


def run(arch: str | None = None, shape: str | None = None) -> list[dict]:
    import os
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
    )
    from repro.configs import registry as reg
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    rows = []
    existing = {}
    out_path = RESULTS / "roofline.json"
    if out_path.exists():
        existing = {(r["arch"], r["shape"]): r
                    for r in json.loads(out_path.read_text())}
    for arch_id, spec in reg.all_archs().items():
        if arch and arch_id != arch:
            continue
        for shape_name, cell in spec.shapes.items():
            if shape and shape_name != shape:
                continue
            if cell.skip:
                rows.append({"arch": arch_id, "shape": shape_name,
                             "skipped": cell.skip})
                continue
            try:
                r = analyze_cell(arch_id, shape_name, mesh)
            except Exception as e:  # pragma: no cover
                r = {"arch": arch_id, "shape": shape_name,
                     "error": f"{type(e).__name__}: {e}"}
            rows.append(r)
            if "error" not in r and "skipped" not in r:
                print(f"{arch_id:25s} {shape_name:14s} "
                      f"C={r['compute_s']*1e3:9.2f}ms "
                      f"M={r['memory_s']*1e3:9.2f}ms "
                      f"X={r['collective_s']*1e3:9.2f}ms "
                      f"dom={r['dominant']:10s} "
                      f"useful={r['useful_ratio']:.2f} "
                      f"roofline={r['roofline_fraction']:.2f}")
            else:
                print(f"{arch_id:25s} {shape_name:14s} "
                      f"{r.get('error', r.get('skipped'))}")
    merged = {**existing, **{(r["arch"], r["shape"]): r for r in rows}}
    RESULTS.mkdir(exist_ok=True)
    out_path.write_text(json.dumps(list(merged.values()), indent=1))
    return rows


def to_markdown(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | kind | compute (ms) | memory (ms) | collective (ms)"
        " | dominant | useful FLOP ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped |"
                f" {r['skipped'][:40]}… | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — |"
                         f" — | {r['error'][:40]} | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    a = sys.argv[1] if len(sys.argv) > 1 else None
    s = sys.argv[2] if len(sys.argv) > 2 else None
    rows = run(a, s)
    print()
    print(to_markdown(rows))
