"""§6.3 summary table: strategy ordering + GLOBAL-vs-ReBuild factors,
computed from the Fig2/Fig3 result JSONs."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"


def summarize() -> dict:
    out = {}
    for name, path in (("random", "fig2_random.json"),
                       ("clustered", "fig3_clustered.json")):
        p = RESULTS / path
        if not p.exists():
            continue
        data = json.loads(p.read_text())
        pattern_out = {}
        for ds, per_strat in data.items():
            reb = per_strat["rebuild"]
            stats = {}
            for strat, recs in per_strat.items():
                if strat == "rebuild":
                    continue
                # skip batch 0 (identical starting point)
                rel = [r["qps"] / max(b["qps"], 1e-9)
                       for r, b in zip(recs[1:], reb[1:])]
                hops_rel = [b["avg_hops"] / max(r["avg_hops"], 1e-9)
                            for r, b in zip(recs[1:], reb[1:])]
                stats[strat] = {
                    "mean_rel_qps": sum(rel) / len(rel),
                    "max_rel_qps": max(rel),
                    "mean_rel_hops_advantage": sum(hops_rel) / len(hops_rel),
                    "final_recall": recs[-1]["recall"],
                }
            pattern_out[ds] = stats
        out[name] = pattern_out

    print(f"{'pattern':10s} {'dataset':10s} {'strategy':8s} "
          f"{'rel-QPS µ':>10s} {'rel-QPS max':>12s} {'recall':>7s}")
    for pat, per_ds in out.items():
        for ds, stats in per_ds.items():
            for strat, s in stats.items():
                print(f"{pat:10s} {ds:10s} {strat:8s} "
                      f"{s['mean_rel_qps']:10.2f} {s['max_rel_qps']:12.2f} "
                      f"{s['final_recall']:7.3f}")
    (RESULTS / "summary.json").write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    summarize()
