"""Figure 4: accumulated execution time vs #ops at growing query:update
ratios — the amortization claim (§6.2): GLOBAL's repair cost pays for
itself once queries dominate."""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import STRATEGIES
from repro.core import IPGMIndex, IndexParams, SearchParams
from repro.data.workload import make_workload

RESULTS = Path(__file__).resolve().parents[1] / "results"


def run(
    *,
    n_base=2000,
    n_steps=3,
    batch_size=200,
    query_ratios=(1, 5, 25),   # queries per update op (paper: 200k/1M/20M vs 20k)
    dim=32,
    out_name="fig4_total_time.json",
) -> dict:
    out = {}
    for ratio in query_ratios:
        n_queries = batch_size * 2 * ratio
        wl = make_workload("sift", n_base=n_base, n_steps=n_steps,
                           batch_size=batch_size, n_queries=min(n_queries, 4096),
                           pattern="random", dim=dim)
        dup = max(1, n_queries // wl.queries.shape[0])
        ratio_out = {}
        for strat in list(STRATEGIES) + ["rebuild"]:
            params = IndexParams(
                capacity=n_base + n_steps * batch_size + 16, dim=dim, d_out=12,
                search=SearchParams(pool_size=32, max_steps=96, num_starts=2),
            )
            index = IPGMIndex(
                params, strategy="pure" if strat == "rebuild" else strat,
                delete_chunk=64,
            )
            ids = index.insert(wl.base)
            id_map = list(np.asarray(ids))
            # warm the jit caches with the exact shapes the timed loop uses
            # (insert batch, padded delete chunk, query chunk, bulk rebuild)
            warm = IPGMIndex(params, strategy=index.strategy, delete_chunk=64)
            warm.insert(wl.step_inserts[0])
            warm.delete(np.arange(64))
            warm.query(wl.queries, k=10)
            if strat == "rebuild":
                warm.rebuild_from_alive()
            t_total = 0.0
            curve = []
            n_ops = 0
            for step in range(n_steps):
                t0 = time.perf_counter()
                gids = [id_map[p] for p in wl.step_deletes[step]]
                index.delete(np.asarray(gids))
                new = index.insert(wl.step_inserts[step])
                id_map.extend(np.asarray(new))
                if strat == "rebuild":
                    alive_before = np.flatnonzero(np.asarray(index.state.alive))
                    index.rebuild_from_alive()
                    remap = {int(o): n for n, o in enumerate(alive_before)}
                    id_map = [remap.get(int(g), -1) if g is not None else -1
                              for g in id_map]
                for _ in range(dup):
                    index.query(wl.queries, k=10)
                t_total += time.perf_counter() - t0
                n_ops += 2 * batch_size + dup * wl.queries.shape[0]
                curve.append({"n_ops": n_ops, "total_s": t_total})
            ratio_out[strat] = curve
            print(f"[fig4 ratio={ratio}] {strat:8s} total={t_total:.2f}s "
                  f"({n_ops} ops)")
        out[str(ratio)] = ratio_out
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / out_name).write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    run()
